"""Shared benchmark helpers: the wall-clock harness and the modeled-HBM-byte
primitives previously copy-pasted across decode_bench / ffn_bench.

The byte model is the metric EdgeLLM optimizes (HBM bandwidth utilization):
every bench reports bytes a step STREAMS from device memory, with
context-independent terms both sides share omitted only when each module
says so explicitly.
"""

from __future__ import annotations

import time

import jax
import numpy as np

SCALE_BYTES = 4  # one f32 absmax scale per token per head (int8-KV), per k/v


def timeit_us(fn, *args, iters: int = 10, repeats: int = 3) -> float:
    """us/call: best of ``repeats`` rounds of ``iters`` calls (min damps
    scheduler noise on shared CI runners; the benched steps are
    deterministic)."""
    jax.block_until_ready(fn(*args))  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e6


def act_bytes(tokens: int, d: int, elt: int = 2) -> int:
    """One activation pass of ``tokens`` rows of width ``d``."""
    return tokens * d * elt


def kv_stream_bytes(tokens, hkv: int, d: int, quant: bool,
                    elt: int = 2) -> int:
    """Bytes one attention step streams to read ``tokens`` cached positions
    (K and V, all KV heads; int8 adds the per-token scales)."""
    kv_elt = 1 if quant else elt
    tok = int(np.sum(tokens))
    return int(hkv * (2 * tok * d * kv_elt +
                      (2 * tok * SCALE_BYTES if quant else 0)))


def kv_cache_bytes(tokens: int, hkv: int, d: int, quant: bool,
                   elt: int = 2) -> int:
    """Resident HBM footprint of ``tokens`` cache positions per layer — the
    capacity side of the same model (serving_bench's paged-vs-slot cut
    reports it alongside the token counts)."""
    return kv_stream_bytes(tokens, hkv, d, quant, elt)
