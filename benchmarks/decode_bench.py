"""Decode-attention roofline benchmark: dense ref vs length-blocked XLA vs
Pallas flash-decode, with modeled HBM bytes/step.

Decode is bandwidth-bound (≈1 FLOP/byte), so the metric that matters is the
one EdgeLLM optimizes: bytes moved per step.  Three implementations of the
same ``ops.decode_attention`` contract are swept over (B, context, kv_quant):

* ``dense``   — the seed's oracle: full MAX-token cache einsum every step;
  with int8 KV it also materialized a full-precision dequantized copy
  (int8 read + fp write + fp read = 5x the int8 bytes).
* ``blocked`` — while_loop over KV blocks bounded by max(lengths); int8
  dequant fused (scale-after-dot), GQA grouped (no repeat).
* ``pallas``  — the flash-decoding kernel: per-row block skipping with DMA
  elision, so bytes track each row's own context.  On CPU it runs in
  interpret mode — its *time* is meaningless there (Python-looped grid), but
  its numerics and modeled bytes are the TPU story.

``--smoke`` writes BENCH_decode.json (tokens/s + modeled bytes/step + the
dense/blocked byte ratios) so CI records the perf trajectory per commit.

Run: PYTHONPATH=src python benchmarks/decode_bench.py [--smoke]
"""

from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.decode_flash import DEFAULT_BLOCK_KV, kv_block_size
from repro.kernels.xla_attention import DEFAULT_DECODE_BLOCK_KV

try:                       # module run (python -m benchmarks.decode_bench)
    from benchmarks.common import kv_stream_bytes, timeit_us as _timeit
except ImportError:        # direct script run (python benchmarks/...)
    from common import kv_stream_bytes, timeit_us as _timeit


def modeled_bytes_per_step(impl: str, B: int, hkv: int, d: int, S: int,
                           lengths, quant: bool, elt: int = 2) -> int:
    """Modeled KV bytes one decode step streams from HBM (per layer).

    q/output traffic (B·hq·d·elt, context-independent) is omitted — it is
    identical across impls and orders of magnitude below the cache term.
    Paged variants stream the same bytes as their contiguous twins (the
    table adds 4·n_pages bytes/row — noise); paging buys CAPACITY, which
    ``serving_bench --paged-capacity`` measures.
    """
    lens = np.minimum(np.asarray(lengths, np.int64).reshape(-1), S)
    lens = np.broadcast_to(lens, (B,))
    if impl == "dense":
        base = kv_stream_bytes(B * S, hkv, d, quant, elt)
        if quant:
            # the seed's dequantized copy: full-precision write + read
            base += 2 * kv_stream_bytes(B * S, hkv, d, False, elt)
        return base
    if impl == "blocked":
        bk = min(DEFAULT_DECODE_BLOCK_KV, S)
        nblk = int(np.ceil(lens.max() / bk))  # trip count = batch max
        tok = B * nblk * bk
    elif impl == "blocked-paged":
        bk = kv_block_size(S, DEFAULT_BLOCK_KV)   # KV tile = page size
        nblk = int(np.ceil(lens.max() / bk))
        tok = B * nblk * bk
    elif impl in ("pallas", "pallas-paged"):
        bk = kv_block_size(S, DEFAULT_BLOCK_KV)
        tok = int(np.ceil(np.maximum(lens, 1) / bk).sum()) * bk  # per row
    else:
        raise ValueError(impl)
    return kv_stream_bytes(tok, hkv, d, quant, elt)


def _decode_call(q, k, v, lengths, ks, vs, *, impl):
    return ops.decode_attention(q, k, v, lengths, k_scale=ks, v_scale=vs,
                                impl=impl)


def _paged_decode_call(q, k, v, lengths, table, ks, vs, *, impl):
    return ops.decode_attention(q, k, v, lengths, k_scale=ks, v_scale=vs,
                                impl=impl, page_table=table)


def make_operands(B, hq, hkv, S, d, quant, seed=0):
    from repro.models.attention import quantize_kv
    rng = np.random.default_rng(seed)
    def r(shape):
        return jnp.asarray(rng.normal(0, 1, shape).astype(np.float32)
                           ).astype(jnp.bfloat16)
    q, k, v = r((B, hq, 1, d)), r((B, hkv, S, d)), r((B, hkv, S, d))
    ks = vs = None
    if quant:
        k, ks = quantize_kv(k)
        v, vs = quantize_kv(v)
    return q, k, v, ks, vs


def bench_cells(B=4, hq=8, hkv=2, S=2048, d=64, contexts=(128, 512, 2048),
                impls=("dense", "blocked", "pallas"), iters=10,
                pallas_iters=2) -> list[dict]:
    if "pallas" in impls and kv_block_size(S, DEFAULT_BLOCK_KV) < 8:
        # mirror the ops.decode_attention gate: the kernel would silently
        # fall back to the blocked path, mislabeling the cell's time/bytes
        print(f"# max_len={S} has no kv tile >= 8: skipping pallas cells")
        impls = tuple(i for i in impls if i != "pallas")
    # one jit wrapper per impl, shared across cells: lengths is a traced
    # operand, so every (quant, context) cell after the first is a cache hit
    fns = {impl: jax.jit(functools.partial(
        _decode_call, impl={"dense": "ref", "blocked": "xla",
                            "pallas": "pallas"}[impl])) for impl in impls}
    cells = []
    for quant in (False, True):
        ops_ = make_operands(B, hq, hkv, S, d, quant)
        for ctx in contexts:
            lengths = jnp.full((B,), ctx, jnp.int32)
            for impl in impls:
                it = pallas_iters if impl == "pallas" else iters
                us = _timeit(fns[impl], *ops_[:3], lengths, *ops_[3:],
                             iters=it)
                cells.append({
                    "B": B, "context": ctx, "max_len": S,
                    "kv_quant": "int8" if quant else "none", "impl": impl,
                    "us_per_step": round(us, 1),
                    "tokens_per_s": round(B / (us / 1e6), 1),
                    "modeled_bytes_per_step": modeled_bytes_per_step(
                        impl, B, hkv, d, S, lengths, quant),
                })
    return cells


def _scramble_to_pool(arrs, B, S, bs, seed=0):
    """Scatter contiguous (B, h, S, ...) caches into shared pools under one
    random fragmented block assignment; returns (pools, page_table)."""
    rng = np.random.default_rng(seed)
    n_pages = S // bs
    total = B * n_pages
    table = rng.permutation(total).reshape(B, n_pages).astype(np.int32)
    pools = []
    for a in arrs:
        if a is None:
            pools.append(None)
            continue
        a = np.asarray(a)
        pool = np.zeros((total + 1,) + a.shape[1:2] + (bs,) + a.shape[3:],
                        a.dtype)
        for b in range(B):
            for p in range(n_pages):
                pool[table[b, p]] = a[b, :, p * bs:(p + 1) * bs]
        pools.append(jnp.asarray(pool))
    return pools, jnp.asarray(table)


def paged_cells(B=4, hq=8, hkv=2, S=2048, d=64, contexts=(128, 2048),
                iters=5, pallas_iters=1) -> list[dict]:
    """Paged-layout step time/bytes on a deliberately fragmented pool: the
    gather/index-translate overhead of paging on the decode hot path (its
    capacity upside is serving_bench's cut)."""
    bs = kv_block_size(S, DEFAULT_BLOCK_KV)
    cells = []
    fns = {
        "blocked-paged": jax.jit(functools.partial(_paged_decode_call,
                                                   impl="xla")),
        "pallas-paged": jax.jit(functools.partial(_paged_decode_call,
                                                  impl="pallas")),
    }
    for quant in (False, True):
        q, k, v, ks, vs = make_operands(B, hq, hkv, S, d, quant)
        (pk, pv, pks, pvs), table = _scramble_to_pool([k, v, ks, vs],
                                                      B, S, bs)
        for ctx in contexts:
            lengths = jnp.full((B,), ctx, jnp.int32)
            for impl, fn in fns.items():
                it = pallas_iters if impl.startswith("pallas") else iters
                us = _timeit(fn, q, pk, pv, lengths, table, pks, pvs,
                             iters=it)
                cells.append({
                    "B": B, "context": ctx, "max_len": S, "block_size": bs,
                    "kv_quant": "int8" if quant else "none", "impl": impl,
                    "us_per_step": round(us, 1),
                    "tokens_per_s": round(B / (us / 1e6), 1),
                    "modeled_bytes_per_step": modeled_bytes_per_step(
                        impl, B, hkv, d, S, lengths, quant),
                })
    return cells


def byte_ratios(cells: list[dict]) -> dict[str, float]:
    """dense-vs-{blocked,pallas} byte ratios at the shortest swept context."""
    ctx = min(c["context"] for c in cells)
    pick = {(c["kv_quant"], c["impl"]): c["modeled_bytes_per_step"]
            for c in cells if c["context"] == ctx}
    out = {}
    for qn, tag in (("none", "fp16"), ("int8", "int8")):
        for impl in ("blocked", "pallas"):
            if (qn, impl) in pick and (qn, "dense") in pick:
                out[f"bytes_dense_over_{impl}_{tag}"] = round(
                    pick[(qn, "dense")] / pick[(qn, impl)], 2)
    return out


def serving_e2e(kv_quant: str = "int8") -> dict:
    """End-to-end tokens/s through the slot engine with the fused path."""
    from repro.configs import get_smoke_config
    from repro.core.compiler import quantize_model
    from repro.models import api
    try:
        from benchmarks.serving_bench import _workload, bench_batched
    except ImportError:  # direct script execution: python benchmarks/...
        from serving_bench import _workload, bench_batched
    cfg = get_smoke_config("qwen3-8b", kv_quant=kv_quant)
    params = quantize_model(api.init_params(cfg, jax.random.PRNGKey(0)),
                            "dense")
    r = bench_batched(cfg, params, _workload(cfg, 6, 8), batch=4, max_len=64)
    return {"kv_quant": kv_quant, "batch": 4,
            "tokens_per_s": round(r["tokens_per_s"], 1),
            "occupancy": round(r["occupancy"], 3)}


def run_smoke(path: str = "BENCH_decode.json") -> dict:
    """CI entry: small sweep + end-to-end engine number -> one JSON."""
    cells = bench_cells(contexts=(128, 2048), iters=5, pallas_iters=1)
    cells += paged_cells(contexts=(128,), iters=3, pallas_iters=1)
    report = {
        "bench": "decode_attention",
        "cells": cells,
        "ratios": byte_ratios(cells),
        "serving_e2e": [serving_e2e("none"), serving_e2e("int8")],
    }
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report["ratios"], indent=2))
    short = {(c["kv_quant"], c["impl"]): c["us_per_step"]
             for c in cells if c["context"] == 128}
    print(f"ctx=128/2048 step us: dense={short[('none', 'dense')]} "
          f"blocked={short[('none', 'blocked')]}")
    print(f"wrote {path}")
    return report


def rows() -> list[tuple[str, float, str]]:
    """benchmarks.run driver entry."""
    cells = bench_cells(contexts=(128, 2048), impls=("dense", "blocked"),
                        iters=5)
    out = []
    for c in cells:
        name = (f"decode/{c['impl']}_ctx{c['context']}"
                f"{'_int8' if c['kv_quant'] == 'int8' else ''}")
        out.append((name, c["us_per_step"],
                    f"tok_s={c['tokens_per_s']:.0f} "
                    f"bytes={c['modeled_bytes_per_step']}"))
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep -> BENCH_decode.json (CI trend record)")
    ap.add_argument("--out", default="BENCH_decode.json")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=2048)
    ap.add_argument("--contexts", default="128,512,2048")
    ap.add_argument("--paged", action="store_true",
                    help="also sweep the paged (fragmented-pool) layout")
    args = ap.parse_args(argv)
    if args.smoke:
        run_smoke(args.out)
        return
    contexts = tuple(int(c) for c in args.contexts.split(","))
    cells = bench_cells(B=args.batch, S=args.max_len, contexts=contexts)
    if args.paged:
        cells += paged_cells(B=args.batch, S=args.max_len, contexts=contexts)
    print(f"{'quant':>6} {'ctx':>6} {'impl':>8} {'us/step':>9} "
          f"{'tok/s':>9} {'bytes/step':>12}")
    for c in cells:
        print(f"{c['kv_quant']:>6} {c['context']:>6} {c['impl']:>8} "
              f"{c['us_per_step']:>9.1f} {c['tokens_per_s']:>9.1f} "
              f"{c['modeled_bytes_per_step']:>12}")
    print(json.dumps(byte_ratios(cells), indent=2))


if __name__ == "__main__":
    main()
