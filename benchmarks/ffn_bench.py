"""FFN datapath benchmark: unfused 3-matmul MLP vs the fused FFN operator,
with modeled HBM bytes per step.

The FFN is the weight-bound half of decode (the attention half was rebuilt
in PR 2): at small token counts every step streams the full gate/up/down
weights, and the unfused composition additionally bounces two full
``(tokens, d_ff)`` intermediates plus the activation product through memory
and re-streams the activations per projection.  The fused operator
(``ops.ffn_w4a16``) moves ``W + x + out`` bytes — the hidden state never
leaves VMEM — and with a tile-uniform sparse down projection it skips
dropped hidden tiles *and their gate/up weight streams* entirely (§III-C's
compute-and-bytes-shrink-together property).

Swept: tokens × strategy ∈ {dense-w4, sparse-0.5, sparse-0.25} × {unfused,
fused}.  Wall time on CPU measures the blocked-XLA twin (the CPU/dry-run
hot path) against the unfused oracle composition; modeled bytes carry the
TPU story (the Pallas kernel's DMA schedule).

``--smoke`` writes BENCH_ffn.json (CI trend record, uploaded next to
BENCH_decode.json / BENCH_serving.json).

Run: PYTHONPATH=src python benchmarks/ffn_bench.py [--smoke]
"""

from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import GROUP_SIZE, quantize
from repro.core.sparsity import block_sparsify_quantize
from repro.kernels import ops

try:                       # module run (python -m benchmarks.ffn_bench)
    from benchmarks.common import act_bytes, timeit_us as _timeit
except ImportError:        # direct script run (python benchmarks/...)
    from common import act_bytes, timeit_us as _timeit

STRATEGIES = ("dense-w4", "sparse-0.5", "sparse-0.25")


def make_weights(d: int, f: int, strategy: str, seed: int = 0):
    """gate/up (d, f), down (f, d) packed per the sweep strategy.

    Sparse strategies prune gate/up per-out-tile (the standalone kernel's
    layout) and down tile-uniform (the fused kernel's down-gather layout)."""
    rng = np.random.default_rng(seed)

    def r(shape):
        return jnp.asarray(rng.normal(0, 0.03, shape).astype(np.float32))

    wg, wu, wd = r((d, f)), r((d, f)), r((f, d))
    if strategy == "dense-w4":
        return quantize(wg), quantize(wu), quantize(wd)
    density = float(strategy.split("-")[1])

    def sparsify(w, tile_uniform=False):
        n_blocks = w.shape[0] // 128
        for m in (8, 4, 2):  # largest group the contraction axis tiles
            if n_blocks % m == 0 and round(density * m) >= 1:
                return block_sparsify_quantize(
                    w, density, blocks_per_group=m, tile_uniform=tile_uniform)
        raise ValueError(f"in_features {w.shape[0]} untileable at {density}")

    return sparsify(wg), sparsify(wu), sparsify(wd, tile_uniform=True)


def modeled_bytes_per_step(tokens: int, d: int, f: int, gate, up, down,
                           fused: bool, elt: int = 2) -> int:
    """Modeled HBM bytes one FFN application moves.

    unfused: weights + x streamed twice (gate and up each read it) + the
    hidden-state round trips (write h_gate, write h_up, read both for the
    activation product, write h, read h for down = 6·tokens·d_ff·elt) + out.

    fused: weights + x once (resident block) + out — no hidden traffic.
    With a tile-uniform sparse down, only the down-kept fraction of the
    gate/up weight stream (and of the hidden compute) exists at all."""
    x_bytes = act_bytes(tokens, d, elt)
    out_bytes = act_bytes(tokens, d, elt)
    w_gate_up = gate.nbytes_model + up.nbytes_model
    w_down = down.nbytes_model
    if not fused:
        hidden = 6 * act_bytes(tokens, f, elt)
        return w_gate_up + w_down + 2 * x_bytes + hidden + out_bytes
    keep = 1.0
    if getattr(down, "tile_uniform", False):
        keep = down.kept_blocks / (f // GROUP_SIZE)
    return int(w_gate_up * keep) + w_down + x_bytes + out_bytes


def bench_cells(d: int = 1024, f: int = 4096, tokens=(1, 8, 64),
                strategies=STRATEGIES, iters: int = 10) -> list[dict]:
    cells = []
    fns = {
        "unfused": jax.jit(functools.partial(
            ops.ffn_w4a16, activation="swiglu", impl="ref")),
        "fused": jax.jit(functools.partial(
            ops.ffn_w4a16, activation="swiglu", impl="xla")),
    }
    rng = np.random.default_rng(1)
    for strategy in strategies:
        gate, up, down = make_weights(d, f, strategy)
        for t in tokens:
            x = jnp.asarray(rng.normal(0, 1, (t, d)).astype(np.float32)
                            ).astype(jnp.bfloat16)
            for impl in ("unfused", "fused"):
                us = _timeit(fns[impl], x, gate, up, down, iters=iters)
                cells.append({
                    "tokens": t, "d_model": d, "d_ff": f,
                    "strategy": strategy, "impl": impl,
                    "us_per_step": round(us, 1),
                    "modeled_bytes_per_step": modeled_bytes_per_step(
                        t, d, f, gate, up, down, fused=(impl == "fused")),
                })
    return cells


def byte_and_time_ratios(cells: list[dict]) -> dict[str, float]:
    """unfused/fused ratios at the decode shape (tokens = min swept)."""
    t = min(c["tokens"] for c in cells)
    pick = {(c["strategy"], c["impl"]): c for c in cells if c["tokens"] == t}
    out = {}
    for s in {c["strategy"] for c in cells}:
        u, fu = pick[(s, "unfused")], pick[(s, "fused")]
        out[f"bytes_unfused_over_fused_{s}"] = round(
            u["modeled_bytes_per_step"] / fu["modeled_bytes_per_step"], 3)
        out[f"time_unfused_over_fused_{s}"] = round(
            u["us_per_step"] / fu["us_per_step"], 3)
    return out


def run_smoke(path: str = "BENCH_ffn.json") -> dict:
    """CI entry: small sweep -> one JSON trend record."""
    cells = bench_cells(d=512, f=2048, tokens=(1, 8), iters=5)
    report = {
        "bench": "ffn_fused",
        "cells": cells,
        "ratios": byte_and_time_ratios(cells),
    }
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2)
    print(json.dumps(report["ratios"], indent=2))
    print(f"wrote {path}")
    return report


def rows() -> list[tuple[str, float, str]]:
    """benchmarks.run driver entry."""
    cells = bench_cells(d=512, f=2048, tokens=(1, 64), iters=5)
    out = []
    for c in cells:
        name = f"ffn/{c['strategy']}_{c['impl']}_t{c['tokens']}"
        out.append((name, c["us_per_step"],
                    f"bytes={c['modeled_bytes_per_step']}"))
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep -> BENCH_ffn.json (CI trend record)")
    ap.add_argument("--out", default="BENCH_ffn.json")
    ap.add_argument("--d-model", type=int, default=1024)
    ap.add_argument("--d-ff", type=int, default=4096)
    ap.add_argument("--tokens", default="1,8,64")
    args = ap.parse_args(argv)
    if args.smoke:
        run_smoke(args.out)
        return
    tokens = tuple(int(t) for t in args.tokens.split(","))
    cells = bench_cells(d=args.d_model, f=args.d_ff, tokens=tokens)
    print(f"{'strategy':>12} {'tok':>5} {'impl':>8} {'us/step':>9} "
          f"{'bytes/step':>12}")
    for c in cells:
        print(f"{c['strategy']:>12} {c['tokens']:>5} {c['impl']:>8} "
              f"{c['us_per_step']:>9.1f} {c['modeled_bytes_per_step']:>12}")
    print(json.dumps(byte_and_time_ratios(cells), indent=2))


if __name__ == "__main__":
    main()
