"""Fig. 11 reproduction: decode/prefill latency scaling with token count.

Paper: decode speed ~flat (~90 tok/s) below 512 tokens, then MHA's quadratic
KV term takes over; FFN runtime is context-independent; prefill scales
~linearly with prompt length.  We reproduce the curves from the op-graph
model (VCU128 constants) and report the latency *breakdown* (MHA / FFN /
other) that Fig. 11(b) plots.
"""

from __future__ import annotations

from repro.configs import get_config
from repro.core import opgraph

HBM_BW = 460e9
DDR_BW = 60e9
FPGA_FLOPS = 2.294e12


def _split(graph):
    mha = [op for op in graph if op.kind in ("mha", "cache_write", "softmax",
                                             "rope")]
    ffn = [op for op in graph if op.kind == "vmm" and
           ("h->4h" in op.name or "4h->h" in op.name or "step14" in op.name
            or "step16" in op.name)]
    other = [op for op in graph if op not in mha and op not in ffn]
    return mha, ffn, other


def run(arch: str = "chatglm-6b") -> dict:
    cfg = get_config(arch)
    t = lambda ops_: sum(op.ideal_time_s(hbm_bw=HBM_BW, ddr_bw=DDR_BW,
                                         compute_flops=FPGA_FLOPS)
                         for op in ops_) * cfg.n_layers

    decode_rows = []
    for ctx in (128, 256, 512, 1024, 2048, 4096):
        g = opgraph.block_graph(cfg, tokens=1, context=ctx)
        mha, ffn, other = _split(g)
        total = t(g) + 1e-4  # + epilogue ballpark
        decode_rows.append({
            "context": ctx,
            "tokens_per_s": round(1.0 / total, 1),
            "mha_ms": round(t(mha) * 1e3, 3),
            "ffn_ms": round(t(ffn) * 1e3, 3),
            "other_ms": round(t(other) * 1e3, 3),
        })

    prefill_rows = []
    for tokens in (128, 256, 512, 1024):
        g = opgraph.block_graph(cfg, tokens=tokens, context=tokens)
        prefill_rows.append({
            "tokens": tokens,
            "latency_ms": round(t(g) * 1e3, 2),
        })
    return {"decode": decode_rows, "prefill": prefill_rows}


def rows() -> list[tuple[str, float, str]]:
    r = run()
    out = []
    for row in r["decode"]:
        out.append((f"fig11/decode_ctx{row['context']}", 0.0,
                    f"{row['tokens_per_s']}tok/s mha={row['mha_ms']}ms "
                    f"ffn={row['ffn_ms']}ms"))
    for row in r["prefill"]:
        out.append((f"fig11/prefill_{row['tokens']}", row["latency_ms"] * 1e3,
                    f"{row['latency_ms']}ms"))
    return out


if __name__ == "__main__":
    r = run()
    for k, v in r.items():
        print(k)
        for row in v:
            print("  ", row)
