"""Fig. 5 reproduction: weight-package cost vs log-scale sparsity.

Effective bit-width and performance-enhancement ratio for the paper's five
packing cases (dense / 50% one-hot / 75% addr / 87.5% one-hot / 87.5% addr).
Expected (paper): 4.125 / 3.125 / 1.875 / 1.625 / 1.125 bits and
1 / 1.32x / 2.2x / 2.54x / 3.67x.
"""

from __future__ import annotations

from repro.core.sparsity import packing_cost

CASES = [
    ("dense", 1.0, "dense"),
    ("50pct_one-hot", 0.5, "one-hot"),
    ("75pct_addr", 0.25, "addr-in-block"),
    ("87.5pct_one-hot", 0.125, "one-hot"),
    ("87.5pct_addr", 0.125, "addr-in-block"),
]


def run() -> list[dict]:
    dense_bits = packing_cost(1.0).total_bits
    out = []
    for name, density, enc in CASES:
        c = packing_cost(density, enc)
        out.append({
            "case": name,
            "scale_bits": c.scale_bits,
            "mask_bits": c.mask_bits,
            "wt_bits": c.wt_bits,
            "total_bits": c.total_bits,
            "effective_bitwidth": c.effective_bitwidth(),
            "enhancement": dense_bits / c.total_bits,
        })
    return out


def rows() -> list[tuple[str, float, str]]:
    return [(f"fig5/{r['case']}", 0.0,
             f"eff_bits={r['effective_bitwidth']:.3f} enh={r['enhancement']:.2f}x")
            for r in run()]


if __name__ == "__main__":
    for r in run():
        print(r)
