"""Kernel micro-benchmarks: wall time of the jitted ops on this host (CPU)
+ derived model quantities, with the executed impl labeled explicitly.

Two impl rows per W4A16 op:

* ``[xla]``              — the pure-XLA path (the CPU/dry-run hot path);
  its wall time is the meaningful one on this host.
* ``[pallas-interpret]`` — the Pallas kernel under the interpreter (the
  numerics path CI exercises; the grid runs as a Python loop, so the wall
  time is NOT a TPU prediction — the derived v5e memory-bound projection
  carries the TPU story for both rows).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import quantize
from repro.core.sparsity import block_sparsify_quantize
from repro.kernels import ops


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # compile + warm
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def rows() -> list[tuple[str, float, str]]:
    out = []
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (16, 2048)).astype(np.float32)).astype(jnp.bfloat16)
    w = jnp.asarray(rng.normal(0, 1, (2048, 2048)).astype(np.float32))
    qt = quantize(w)
    st = block_sparsify_quantize(w, 0.25)

    # TPU v5e projection: memory-bound decode time = bytes / 819 GB/s
    t_mem = qt.nbytes_model / 819e9 * 1e6
    derived = f"v5e_mem_bound={t_mem:.2f}us int4_bytes={qt.nbytes_model}"
    us = _time(jax.jit(lambda a, q: ops.w4a16_matmul(a, q, impl="xla")), x, qt)
    out.append(("kernel/w4a16_matmul_2048x2048[xla]", us, derived))
    us = _time(jax.jit(lambda a, q: ops.w4a16_matmul(a, q, impl="pallas")),
               x, qt, iters=2)
    out.append(("kernel/w4a16_matmul_2048x2048[pallas-interpret]", us,
                derived + " interpret=1"))

    t_mem_s = st.nbytes_model / 819e9 * 1e6
    derived_s = (f"v5e_mem_bound={t_mem_s:.2f}us bytes={st.nbytes_model} "
                 f"vs_dense={qt.nbytes_model / st.nbytes_model:.2f}x")
    us = _time(jax.jit(lambda a, s: ops.sparse_w4a16_matmul(a, s, impl="xla")), x, st)
    out.append(("kernel/sparse_w4a16_d0.25[xla]", us, derived_s))
    us = _time(jax.jit(lambda a, s: ops.sparse_w4a16_matmul(a, s, impl="pallas")),
               x, st, iters=2)
    out.append(("kernel/sparse_w4a16_d0.25[pallas-interpret]", us,
                derived_s + " interpret=1"))

    # whole-FFN operator: unfused oracle vs fused twin (decode shape)
    x1 = x[:1]
    gq, uq, dq = quantize(w), quantize(w), quantize(w)
    ffn_bytes = gq.nbytes_model + uq.nbytes_model + dq.nbytes_model
    derived_f = f"w_bytes={ffn_bytes} v5e_mem_bound={ffn_bytes / 819e9 * 1e6:.2f}us"
    us = _time(jax.jit(lambda a, g, u, d: ops.ffn_w4a16(
        a, g, u, d, activation="swiglu", impl="ref")), x1, gq, uq, dq)
    out.append(("kernel/ffn_w4a16_2048_t1[unfused-xla]", us, derived_f))
    us = _time(jax.jit(lambda a, g, u, d: ops.ffn_w4a16(
        a, g, u, d, activation="swiglu", impl="xla")), x1, gq, uq, dq)
    out.append(("kernel/ffn_w4a16_2048_t1[fused-xla]", us, derived_f))

    q = jnp.asarray(rng.normal(0, 1, (1, 8, 2048, 128)).astype(np.float32)).astype(jnp.bfloat16)
    us = _time(jax.jit(lambda a: ops.attention(a, a, a, causal=True, impl="xla")), q)
    flops = 4 * 8 * 2048 * 2048 * 128 / 2
    out.append(("kernel/attention_2k_causal[xla]", us,
                f"v5e_compute_bound={flops / 197e12 * 1e6:.2f}us"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r)
