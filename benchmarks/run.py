"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
Run: PYTHONPATH=src python -m benchmarks.run [--only table1,...]
"""

from __future__ import annotations

import argparse
import sys

MODULES = [
    "table1_mixed_precision",
    "fig5_packing",
    "table2_sparse_strategies",
    "table3_hbm_vs_ddr",
    "fig11_scaling",
    "table5_efficiency",
    "kernel_bench",
    "serving_bench",
    "decode_bench",
    "ffn_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings")
    ap.add_argument("--smoke", action="store_true",
                    help="perf smoke -> BENCH_decode.json + BENCH_serving.json"
                         " + BENCH_ffn.json, then exit (the CI trend records)")
    args = ap.parse_args()

    if args.smoke:
        from benchmarks.decode_bench import run_smoke
        from benchmarks.ffn_bench import run_smoke as ffn_smoke
        from benchmarks.serving_bench import run_smoke as serving_smoke
        run_smoke()
        serving_smoke()
        ffn_smoke()
        return

    selected = MODULES
    if args.only:
        keys = args.only.split(",")
        selected = [m for m in MODULES if any(k in m for k in keys)]

    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["rows"])
            for row_name, us, derived in mod.rows():
                print(f"{row_name},{us:.2f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
