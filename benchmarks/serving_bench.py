"""Serving benchmark: chunked-prefill mixed batching vs stall-prefill, and
batched continuous decode vs the seed's per-request loop.

Two cuts:

* **Throughput** (``--mode throughput``): decode tokens/s as a function of
  slot-batch size and queue depth — the slot engine's ONE dispatch per tick
  vs the seed's per-request batch-1 loop (``reference_decode``).

* **Mixed load** (``--mode mixed``, the default): a resident decode load
  plus a burst of prompt admissions, measured under two admission policies
  of the SAME engine:

  - ``stall``  — the seed's schedule: while any prompt is mid-prefill only
    it advances; decode rows stall (head-of-line blocking), and queued
    prompts serialize behind it.
  - ``mixed``  — chunked-prefill admission fused into the decode tick
    (Sarathi-style): every mid-prefill row advances one chunk bucket per
    tick WHILE decode rows keep emitting, and multiple admissions chunk
    together in one dispatch.

  Reported: TTFT p50/p99 over the admission burst, inter-token latency p99
  over the resident decoders, decode tokens/s.  Both policies share one
  compile cache, so the delta isolates the schedule — the serving analogue
  of EdgeLLM keeping the FPGA saturated with one fixed executable set.

``--smoke`` writes BENCH_serving.json (the CI trend record, uploaded next
to BENCH_decode.json).

Run:  PYTHONPATH=src python benchmarks/serving_bench.py [--mode mixed]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache, TokenBuckets, quantize_model
from repro.models import api
from repro.serving.engine import Engine, Request

try:                       # module run (python -m benchmarks.serving_bench)
    from benchmarks.common import kv_cache_bytes
except ImportError:        # direct script run (python benchmarks/...)
    from common import kv_cache_bytes


def _workload(cfg, n_requests: int, max_new: int, seed: int = 0,
              lo: int = 4, hi: int = 28):
    rng = np.random.default_rng(seed)
    return [
        (rng.integers(0, cfg.vocab_size,
                      int(rng.integers(lo, hi))).astype(np.int32), max_new)
        for _ in range(n_requests)
    ]


# ---------------------------------------------------------------------------
# throughput mode (batched engine vs per-request loop)
# ---------------------------------------------------------------------------

def bench_batched(cfg, params, workload, batch: int, max_len: int,
                  chunk_size: int = 16):
    """Slot engine: timed after a warmup run compiles the executable set."""
    def submit_all(engine):
        for rid, (prompt, max_new) in enumerate(workload):
            engine.submit(Request(rid=rid, prompt=prompt,
                                  max_new_tokens=max_new))

    warm = Engine(cfg, params, batch_size=batch, max_len=max_len,
                  chunk_size=chunk_size)
    submit_all(warm)
    warm.run()

    engine = Engine(cfg, params, batch_size=batch, max_len=max_len,
                    chunk_size=chunk_size,
                    compile_cache=warm.cache_compiles)  # same (cfg, shapes)
    submit_all(engine)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.output) - 1 for r in done)  # decode tokens only
    return {
        "tokens": tokens,
        "tokens_per_s": tokens / dt,
        "steps": engine.steps,
        "occupancy": engine.slot_occupancy,
    }


def _seed_decode(cfg, params, prompt, max_new_tokens, *, max_len, cc):
    """The seed engine's inner loop: ONE bucketed batch-1 prefill + greedy
    decode.  Kept as the throughput baseline so BENCH trend numbers stay
    comparable across PRs — ``reference_decode`` is now the exact
    teacher-forced ORACLE (O(len) dispatches) and would overstate the
    batched engine's speedup if timed as the baseline."""
    buckets = TokenBuckets(max_tokens=max_len)
    bucket = buckets.bucket(len(prompt))
    padded = np.zeros((1, bucket), np.int32)
    padded[0, -len(prompt):] = prompt
    pf = cc.get("base_prefill", bucket, lambda: jax.jit(
        lambda p, b: api.prefill(cfg, p, b, max_len)))
    logits, cache = pf(params, {"tokens": jnp.asarray(padded)})
    out = [int(np.argmax(np.asarray(logits[0])))]
    dec = cc.get("base_decode", 1, lambda: jax.jit(
        lambda p, c, t, l: api.decode_step(cfg, p, c, t, l)))
    length = bucket
    while len(out) < max_new_tokens and length < max_len:
        length += 1
        logits, cache = dec(params, cache,
                            jnp.asarray([[out[-1]]], jnp.int32),
                            jnp.asarray([length], jnp.int32))
        out.append(int(np.argmax(np.asarray(logits[0]))))
    return out


def bench_per_request(cfg, params, workload, max_len: int):
    """Seed baseline: sequential batch-1 greedy loops (shared compile cache)."""
    cc = CompileCache()
    for prompt, max_new in workload:                  # warm/compile pass
        _seed_decode(cfg, params, prompt, max_new, max_len=max_len, cc=cc)
    t0 = time.perf_counter()
    tokens = 0
    for prompt, max_new in workload:
        out = _seed_decode(cfg, params, prompt, max_new, max_len=max_len,
                           cc=cc)
        tokens += len(out) - 1
    dt = time.perf_counter() - t0
    return {"tokens": tokens, "tokens_per_s": tokens / dt}


# ---------------------------------------------------------------------------
# mixed-load mode (chunked admission vs stall-prefill)
# ---------------------------------------------------------------------------

def _mixed_workload(cfg, *, residents: int, burst: int, max_len: int,
                    seed: int = 0):
    """Resident decoders (short prompt, long generation) + an admission
    burst of long prompts arriving mid-decode."""
    rng = np.random.default_rng(seed)
    res = [Request(rid=i,
                   prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                   max_new_tokens=48)
           for i in range(residents)]
    prompt_len = max(8, int(max_len * 0.6))
    bur = [Request(rid=100 + i,
                   prompt=rng.integers(0, cfg.vocab_size,
                                       prompt_len).astype(np.int32),
                   max_new_tokens=4)
           for i in range(burst)]
    return res, bur


def bench_mixed_load(cfg, params, *, policy: str, batch: int, max_len: int,
                     chunk_size: int, burst: int,
                     compile_cache: CompileCache | None = None):
    """One mixed-load trial; returns latency metrics + the compile cache."""
    engine = Engine(cfg, params, batch_size=batch, max_len=max_len,
                    chunk_size=chunk_size, prefill_policy=policy,
                    compile_cache=compile_cache)
    # residents on half the slots; the burst admits into the free half WHILE
    # they decode — that concurrency is exactly what the two policies differ on
    residents, burst_reqs = _mixed_workload(
        cfg, residents=max(1, batch // 2), burst=burst, max_len=max_len)
    for r in residents:
        engine.submit(r)
    engine.run(max_steps=4)          # residents admitted + decoding
    for r in burst_reqs:             # the burst arrives mid-decode
        engine.submit(r)
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0

    ttft = [r.first_token_at - r.submitted_at for r in burst_reqs]
    itl = [d for r in residents for d in np.diff(r.token_times).tolist()]
    tokens = sum(len(r.output) - 1 for r in residents + burst_reqs)
    return {
        "policy": policy,
        "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
        "ttft_p99_ms": float(np.percentile(ttft, 99) * 1e3),
        "itl_p50_ms": float(np.percentile(itl, 50) * 1e3),
        "itl_p99_ms": float(np.percentile(itl, 99) * 1e3),
        "decode_tokens_per_s": tokens / dt,
        "steps": engine.steps,
        "mixed_ticks": engine.mixed_ticks,
        "compile_misses": engine.cache_compiles.misses,
        "compile_budget": engine.compile_budget,
    }, engine.cache_compiles


def run_mixed(cfg, params, *, batch: int = 4, max_len: int = 128,
              chunk_size: int = 16, burst: int = 6) -> dict:
    """Warm both policies on a shared compile cache, then measure each."""
    _, cc = bench_mixed_load(cfg, params, policy="mixed", batch=batch,
                             max_len=max_len, chunk_size=chunk_size,
                             burst=burst)                       # warm/compile
    stall, cc = bench_mixed_load(cfg, params, policy="stall", batch=batch,
                                 max_len=max_len, chunk_size=chunk_size,
                                 burst=burst, compile_cache=cc)
    mixed, cc = bench_mixed_load(cfg, params, policy="mixed", batch=batch,
                                 max_len=max_len, chunk_size=chunk_size,
                                 burst=burst, compile_cache=cc)
    return {
        "config": {"arch": cfg.name, "batch": batch, "max_len": max_len,
                   "chunk_size": chunk_size, "burst": burst},
        "stall_prefill": stall,
        "mixed": mixed,
        "ttft_p99_speedup": stall["ttft_p99_ms"] / mixed["ttft_p99_ms"],
        "itl_p99_speedup": stall["itl_p99_ms"] / mixed["itl_p99_ms"],
    }


# ---------------------------------------------------------------------------
# paged-KV capacity mode (resident tokens at equal HBM budget)
# ---------------------------------------------------------------------------

def _capacity_trial(cfg, params, *, batch: int, max_len: int,
                    n_requests: int, chunk_size: int = 8, seed: int = 3):
    """One engine run over a short-request workload; returns the capacity
    metrics (peak resident tokens, admission stalls) plus throughput."""
    rng = np.random.default_rng(seed)
    engine = Engine(cfg, params, batch_size=batch, max_len=max_len,
                    chunk_size=chunk_size)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(8, 15))
                                        ).astype(np.int32),
                    max_new_tokens=8)
            for i in range(n_requests)]
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    cache_tokens = (engine.pool_blocks * engine.block_size if engine.paged
                    else batch * max_len)
    out = {
        "kv_layout": cfg.kv_layout,
        "batch_slots": batch,
        "hbm_cache_tokens": cache_tokens,
        "hbm_cache_bytes": kv_cache_bytes(
            cache_tokens, cfg.n_kv_heads, cfg.head_dim,
            cfg.kv_quant == "int8") * cfg.n_layers,
        "peak_resident_tokens": engine.peak_resident_tokens,
        "admission_stalls": engine.admission_stalls,
        "completed": len(done),
        "steps": engine.steps,
        "tokens_per_s": sum(len(r.output) for r in done) / dt,
    }
    if engine.paged:
        out["block_size"] = engine.block_size
        out["pool_blocks"] = engine.pool_blocks
    return out


def run_paged_capacity(cfg, params, *, max_len: int = 64,
                       slot_batch: int = 4, paged_batch: int = 12,
                       block_size: int = 16, n_requests: int = 18) -> dict:
    """Slot vs paged at EQUAL KV HBM budget.

    The slot engine reserves ``max_len`` rows per slot, so its resident
    batch is capped at ``slot_batch`` regardless of how short requests are.
    The paged engine gets the SAME pool of cache tokens
    (``slot_batch * max_len``) carved into blocks, plus more slots — short
    requests lease only the blocks they touch, so more of them fit
    resident; reservation pressure shows up as admission stalls instead of
    wasted rows."""
    import dataclasses
    pool_blocks = slot_batch * max_len // block_size   # equal token budget
    cfg_paged = dataclasses.replace(cfg, kv_layout="paged",
                                    kv_block_size=block_size,
                                    kv_pool_blocks=pool_blocks)
    slot = _capacity_trial(cfg, params, batch=slot_batch, max_len=max_len,
                           n_requests=n_requests)
    paged = _capacity_trial(cfg_paged, params, batch=paged_batch,
                            max_len=max_len, n_requests=n_requests)
    return {
        "config": {"arch": cfg.name, "max_len": max_len,
                   "block_size": block_size, "n_requests": n_requests},
        "slot": slot,
        "paged": paged,
        "resident_tokens_gain": (paged["peak_resident_tokens"] /
                                 max(slot["peak_resident_tokens"], 1)),
    }


# ---------------------------------------------------------------------------
# mesh mode (sharded paged serving: resident capacity across a device mesh)
# ---------------------------------------------------------------------------

_MESH_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import contextlib, json, sys, time
sys.path.insert(0, "src")
import jax
import numpy as np
from repro.configs import get_smoke_config
from repro.models import api
from repro.parallel.hints import use_mesh
from repro.serving.engine import Engine, Request

P_DEV, BS, MAX_LEN, N_REQ = 11, 8, 64, 24
n_dev = jax.device_count()

def mk_cfg(pool_blocks):
    return get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                            kv_layout="paged", kv_block_size=BS,
                            kv_pool_blocks=pool_blocks)

# single-device engine holds P_DEV + 1 pool rows (null included); the
# sharded engine holds the SAME rows PER SHARD: n_dev * (P_DEV + 1) rows
cfg_one = mk_cfg(P_DEV)
cfg_mesh = mk_cfg(n_dev * (P_DEV + 1) - 1)
params = api.init_params(cfg_one, jax.random.PRNGKey(0))

def workload():
    rng = np.random.default_rng(3)
    return [Request(rid=i,
                    prompt=rng.integers(0, 256, int(rng.integers(8, 15))
                                        ).astype(np.int32),
                    max_new_tokens=8)
            for i in range(N_REQ)]

def trial(cfg, batch, ctx):
    reqs = workload()
    with ctx:
        engine = Engine(cfg, params, batch_size=batch, max_len=MAX_LEN,
                        chunk_size=8, audit_every=4)
        for r in reqs:
            engine.submit(r)
        t0 = time.perf_counter()
        done = engine.run()
        dt = time.perf_counter() - t0
        engine.audit()
    return {
        "batch_slots": batch,
        "pool_blocks": engine.pool_blocks,
        "n_homes": engine.n_homes,
        "per_device_pool_rows": (engine.pool_blocks + 1) // engine.n_homes,
        "peak_resident_tokens": engine.peak_resident_tokens,
        "admission_stalls": engine.admission_stalls,
        "completed": len(done),
        "steps": engine.steps,
        "tokens_per_s": sum(len(r.output) for r in done) / dt,
        "outputs": {r.rid: [int(t) for t in r.output] for r in reqs},
    }

single = trial(cfg_one, 4, contextlib.nullcontext())
mesh = jax.make_mesh((1, n_dev), ("data", "model"))
sharded = trial(cfg_mesh, 16, use_mesh(mesh))
tokens_equal = single.pop("outputs") == sharded.pop("outputs")
print("RESULT " + json.dumps({
    "n_devices": n_dev,
    "single_device": single,
    "sharded": sharded,
    "resident_tokens_gain": (sharded["peak_resident_tokens"] /
                             max(single["peak_resident_tokens"], 1)),
    "tokens_equal": tokens_equal,
}))
"""


def run_mesh() -> dict:
    """Sharded paged serving vs a single device at EQUAL per-device KV
    budget (the PR 10 acceptance cut).

    Runs in a subprocess with 8 forced host devices: the single-device
    engine gets ``P_DEV + 1`` pool rows; the sharded engine gets the same
    rows on EACH of the 8 shards (block homes), so resident batch scales
    with total mesh memory.  Token streams must be identical — the mesh
    buys capacity, never different tokens."""
    import os
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_WORKER], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(
        f"mesh bench worker failed:\nstdout={proc.stdout[-2000:]}\n"
        f"stderr={proc.stderr[-3000:]}")


# ---------------------------------------------------------------------------
# prefix-sharing mode (shared system prompt, radix cache + CoW paged KV)
# ---------------------------------------------------------------------------

def _prefix_workload(cfg, *, n_requests: int, system_len: int, max_new: int,
                     user_lo: int = 4, user_hi: int = 13, seed: int = 5):
    """The multi-tenant shape prefix sharing targets: every request opens
    with the SAME system prompt and differs only in a short user turn."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab_size, system_len)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [system,
                         rng.integers(0, cfg.vocab_size,
                                      int(rng.integers(user_lo, user_hi)))]
                    ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n_requests)]


def _prefix_trial(cfg, params, *, prefix_cache: bool, batch: int,
                  max_len: int, workload, chunk_size: int = 8,
                  compile_cache: CompileCache | None = None):
    """One engine run over the shared-system-prompt workload.

    ``cached_ttft_p50_ms`` is the headline: TTFT over the requests admitted
    AFTER the first batch — the ones whose system prompt is already cached
    when sharing is on (the cache warms as the first wave's prefills
    finish), measured identically for the no-sharing baseline."""
    engine = Engine(cfg, params, batch_size=batch, max_len=max_len,
                    chunk_size=chunk_size, prefix_cache=prefix_cache,
                    compile_cache=compile_cache)
    reqs = [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens) for r in workload]
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    ttft = [r.first_token_at - r.submitted_at for r in reqs]
    late = [r.first_token_at - r.submitted_at for r in reqs
            if r.rid >= batch]
    out = {
        "prefix_cache": engine.prefix_sharing,
        "completed": len(done),
        "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
        "cached_ttft_p50_ms": float(np.percentile(late, 50) * 1e3),
        "tokens_per_s": sum(len(r.output) for r in done) / dt,
        "steps": engine.steps,
        "mixed_ticks": engine.mixed_ticks,
        "occupancy": engine.slot_occupancy,
        "admission_stalls": engine.admission_stalls,
        "peak_pool_blocks": engine.peak_pool_blocks,
        "pool_blocks": engine.pool_blocks,
        "outputs": {r.rid: [int(t) for t in r.output] for r in done},
    }
    if engine.prefix_sharing:
        out["prefix"] = engine.prefix_stats()
        st = engine.pool_stats()
        out["shared_blocks"] = st["shared_blocks"]
        out["cow_copies"] = st["cow_copies"]
        out["prefix_hit_tokens"] = st["prefix_hit_tokens"]
    return out, engine.cache_compiles


def run_prefix_sharing(cfg, params, *, batch: int = 4, max_len: int = 96,
                       block_size: int = 8, system_len: int = 48,
                       n_requests: int = 16, max_new: int = 8) -> dict:
    """Sharing ON vs OFF on the same workload at EQUAL KV HBM budget.

    The pool is sized so the no-sharing engine can hold only ~2 requests'
    worst case at once (reservation pressure): sharing admits the common
    system prompt by page-table copy, so the same pool holds the full batch
    concurrently — stalls collapse, slot occupancy rises, and cached-prefix
    TTFT drops to the cost of the user-turn suffix.  Outputs are checked
    token-identical between the two runs (sharing is exact)."""
    import dataclasses
    worst = -(-(system_len + 12 + max_new) // block_size)
    pool_blocks = 2 * worst + 6          # ~2 concurrent without sharing
    cfg_paged = dataclasses.replace(cfg, kv_layout="paged",
                                    kv_block_size=block_size,
                                    kv_pool_blocks=pool_blocks)
    workload = _prefix_workload(cfg_paged, n_requests=n_requests,
                                system_len=system_len, max_new=max_new)
    kw = dict(batch=batch, max_len=max_len, workload=workload)
    _, cc = _prefix_trial(cfg_paged, params, prefix_cache=True, **kw)  # warm
    off, cc = _prefix_trial(cfg_paged, params, prefix_cache=False,
                            compile_cache=cc, **kw)
    on, cc = _prefix_trial(cfg_paged, params, prefix_cache=True,
                           compile_cache=cc, **kw)
    outputs_match = off.pop("outputs") == on.pop("outputs")
    return {
        "config": {"arch": cfg.name, "batch": batch, "max_len": max_len,
                   "block_size": block_size, "system_len": system_len,
                   "n_requests": n_requests, "pool_blocks": pool_blocks},
        "no_sharing": off,
        "sharing": on,
        "outputs_match": outputs_match,
        "cached_ttft_p50_speedup": (off["cached_ttft_p50_ms"] /
                                    max(on["cached_ttft_p50_ms"], 1e-9)),
        "occupancy_gain": on["occupancy"] / max(off["occupancy"], 1e-9),
    }


# ---------------------------------------------------------------------------
# speculative-decoding mode (prompt-lookup drafts through the mixed dispatch)
# ---------------------------------------------------------------------------

def _spec_workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    """Repetition-heavy cut: prompts are a short n-gram pattern tiled a few
    times, and the generation budget is long.  Tiled prompts give the
    prompt-lookup drafter immediate matches, and a deterministic greedy
    model run long enough falls into token cycles the drafter then predicts
    from the row's own emitted history — the synthetic stand-in for the
    copied spans / boilerplate / format scaffolding that make real LLM
    output locally repetitive."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        pat = rng.integers(0, cfg.vocab_size, int(rng.integers(3, 6)))
        reqs.append((np.tile(pat, 4).astype(np.int32), max_new))
    return reqs


def run_spec(cfg, params, *, batch: int = 4, max_len: int = 128,
             max_new: int = 96, n_requests: int = 12,
             ks: tuple = (2, 4, 8), repeats: int = 7) -> dict:
    """Plain-decode baseline vs draft depths K — same workload, same greedy
    outputs (checked), fewer weight streams per emitted token.

    Each depth's run is only ~100 dispatches at smoke scale, so a single
    wall-clock sample is scheduler noise.  Every depth (baseline included)
    is re-run ``repeats`` times with the runs INTERLEAVED round-robin, and
    each speedup is the MEDIAN of per-cycle PAIRED ratios (depth-K's sample
    over the baseline sample from the SAME cycle): machine state is shared
    within a cycle, so load/frequency drift cancels out of each ratio, and
    the median rejects cycles that drifted mid-cycle.  ``tokens_per_s`` is
    best-of for each depth (tokens, dispatches and acceptance are
    deterministic across runs)."""
    workload = _spec_workload(cfg, n_requests, max_new)
    depths = (0,) + tuple(ks)
    # spec and non-spec engines bind DIFFERENT executables under the same
    # ("mixed", W) keys — one shared compile cache per variant, not per K
    caches = {False: CompileCache(), True: CompileCache()}

    def run_once(k):
        engine = Engine(cfg, params, batch_size=batch, max_len=max_len,
                        chunk_size=16, spec_k=k,
                        compile_cache=caches[bool(k)])
        for rid, (prompt, mn) in enumerate(workload):
            engine.submit(Request(rid=rid, prompt=prompt,
                                  max_new_tokens=mn))
        t0 = time.perf_counter()
        done = engine.run()
        return time.perf_counter() - t0, engine, done

    results = {}
    for k in depths:                     # warm pass compiles + records stats
        _, engine, done = run_once(k)
        results[k] = {
            "spec_k": k,
            "tokens": sum(len(r.output) - 1 for r in done),
            "dispatches": engine.dispatches,
            "outputs": {r.rid: [int(t) for t in r.output] for r in done},
        }
        if k:
            s = engine.spec_stats()
            results[k].update(
                {f: s[f] for f in ("draft_tokens", "accepted_tokens",
                                   "acceptance_rate",
                                   "accepted_per_dispatch", "rewinds")})
    samples = {k: [] for k in depths}
    for _ in range(repeats):             # interleaved timing cycles
        for k in depths:
            samples[k].append(run_once(k)[0])
    for k in depths:
        results[k]["tokens_per_s"] = results[k]["tokens"] / min(samples[k])

    base = results[0]
    base_outputs = base.pop("outputs")
    trials = []
    for k in ks:
        r = results[k]
        r["outputs_match_baseline"] = r.pop("outputs") == base_outputs
        ratios = sorted(samples[0][i] / samples[k][i]
                        for i in range(repeats))
        r["speedup_vs_plain"] = ratios[repeats // 2]
        trials.append(r)
    return {
        "config": {"arch": cfg.name, "batch": batch, "max_len": max_len,
                   "max_new": max_new, "n_requests": n_requests,
                   "repeats": repeats},
        "baseline": base,
        "spec": trials,
        "best_speedup": max(t["speedup_vs_plain"] for t in trials),
    }


# ---------------------------------------------------------------------------
# overload mode (past-capacity: stall-only vs preemption + deadlines)
# ---------------------------------------------------------------------------

def _overload_workload(cfg, *, hogs: int, interactive: int, hog_new: int,
                       int_new: int, deadline_s: float, seed: int = 9):
    """Past-capacity mix: ``hogs`` low-priority long generations that FIFO
    admission seats first and that hold their slots for ~``hog_new`` ticks,
    plus ``interactive`` high-priority short requests with a deadline that
    only fits if they do NOT wait behind the hogs."""
    rng = np.random.default_rng(seed)
    hog_reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            8).astype(np.int32),
                        max_new_tokens=hog_new, priority=0)
                for i in range(hogs)]
    int_reqs = [Request(rid=100 + i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            8).astype(np.int32),
                        max_new_tokens=int_new, priority=1,
                        deadline_s=deadline_s)
                for i in range(interactive)]
    return hog_reqs, int_reqs


def _overload_trial(cfg, params, *, resilient: bool, batch: int,
                    max_len: int, deadline_s: float, hog_new: int,
                    int_new: int, hogs: int, interactive: int,
                    compile_cache: CompileCache | None = None):
    """One past-capacity run.  ``resilient`` turns on bounded preemption +
    deadline enforcement; the baseline is the stall-only engine (requests
    keep their deadlines for POST-HOC goodput accounting, but nothing is
    evicted or expired).  Goodput counts only tokens of requests that
    finished ``done`` within their deadline."""
    engine = Engine(cfg, params, batch_size=batch, max_len=max_len,
                    chunk_size=16,
                    max_preemptions=1 if resilient else 0,
                    enforce_deadlines=resilient,
                    compile_cache=compile_cache)
    hog_reqs, int_reqs = _overload_workload(
        cfg, hogs=hogs, interactive=interactive, hog_new=hog_new,
        int_new=int_new, deadline_s=deadline_s)
    for r in hog_reqs + int_reqs:       # hogs first: FIFO seats them
        engine.submit(r)
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    reqs = hog_reqs + int_reqs

    def in_deadline(r):
        return (r.deadline_s is None or
                (r.finished_at or 1e30) - r.submitted_at <= r.deadline_s)

    good = sum(len(r.output) for r in reqs
               if r.status == "done" and in_deadline(r))
    misses = sum(1 for r in int_reqs
                 if r.status != "done" or not in_deadline(r))
    ttft = [r.first_token_at - r.submitted_at for r in int_reqs
            if r.first_token_at is not None]
    return {
        "resilient": resilient,
        "wall_s": dt,
        "goodput_tokens_per_s": good / dt,
        "goodput_tokens": good,
        "total_tokens": sum(len(r.output) for r in reqs),
        "deadline_miss_rate": misses / len(int_reqs),
        "interactive_ttft_p99_ms": (float(np.percentile(ttft, 99) * 1e3)
                                    if ttft else None),
        "preemptions": engine.preemptions,
        "deadline_kills": engine.deadline_misses,
        "admission_stalls": engine.admission_stalls,
        "steps": engine.steps,
    }, engine.cache_compiles


def run_overload(cfg, params, *, batch: int = 4, max_len: int = 128,
                 hogs: int = 4, interactive: int = 8, hog_new: int = 64,
                 int_new: int = 6, deadline_ticks: int = 40) -> dict:
    """Sustained past-capacity load: stall-only vs preemption + deadlines.

    The offered load is 3x slot capacity (12 concurrent requests on 4
    slots) and FIFO seats the hogs first, so the stall baseline makes every
    interactive request wait ~``hog_new`` ticks for a slot — far past its
    deadline.  The resilient engine priority-preempts hogs (losslessly,
    bounded at 1 each) so interactive requests run immediately and meet it.
    Deadlines are wall-clock, so the budget is calibrated in TICKS: a warm
    probe run measures the per-tick wall time and ``deadline_ticks`` (less
    than the hogs' slot-holding time, multiples of the interactive service
    time) converts to seconds."""
    # warm compiles the executable set; the probe then measures the true
    # per-tick wall time (compilation excluded — it would inflate the
    # deadline budget ~10x and nothing would ever miss)
    warm = dict(batch=batch, max_len=max_len, deadline_s=1e9,
                hog_new=hog_new, int_new=int_new, hogs=hogs,
                interactive=interactive)
    _, cc = _overload_trial(cfg, params, resilient=True, **warm)
    probe, cc = _overload_trial(cfg, params, resilient=True,
                                compile_cache=cc, **warm)
    tick_s = probe["wall_s"] / probe["steps"]
    deadline_s = deadline_ticks * tick_s
    kw = dict(batch=batch, max_len=max_len, deadline_s=deadline_s,
              hog_new=hog_new, int_new=int_new, hogs=hogs,
              interactive=interactive, compile_cache=cc)
    stall, cc = _overload_trial(cfg, params, resilient=False, **kw)
    kw["compile_cache"] = cc
    resilient, cc = _overload_trial(cfg, params, resilient=True, **kw)
    return {
        "config": {"arch": cfg.name, "batch": batch, "max_len": max_len,
                   "hogs": hogs, "interactive": interactive,
                   "hog_new": hog_new, "int_new": int_new,
                   "deadline_ticks": deadline_ticks,
                   "deadline_ms": deadline_s * 1e3,
                   "offered_load_x": (hogs + interactive) / batch},
        "stall_baseline": stall,
        "resilient": resilient,
        "goodput_gain": (resilient["goodput_tokens_per_s"] /
                         max(stall["goodput_tokens_per_s"], 1e-9)),
        "miss_rate_drop": (stall["deadline_miss_rate"] -
                           resilient["deadline_miss_rate"]),
    }


# ---------------------------------------------------------------------------
# restart mode (snapshot cost, recovery latency, warm vs cold TTFT)
# ---------------------------------------------------------------------------

def _probe_ttft(engine, rid: int, prompt, max_new: int = 4) -> tuple:
    """Submit ONE probe request into an idle engine and run it to completion;
    returns (ttft_seconds, output tokens)."""
    req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new)
    engine.submit(req)
    engine.run()
    return req.first_token_at - req.submitted_at, [int(t) for t in req.output]


def run_restart(cfg, params, *, batch: int = 4, max_len: int = 96,
                block_size: int = 8, system_len: int = 48,
                n_requests: int = 10, max_new: int = 8,
                save_repeats: int = 3) -> dict:
    """Durability cost/benefit: snapshot save time, ``Engine.restore``
    latency, and what the restored state buys — warm-restore TTFT (prefix
    cache + executables back) vs cold-start TTFT (same process, empty
    cache) on an identical probe prompt.

    All engines share ONE compile cache, so every TTFT delta isolates
    STATE (the radix prefix cache restored from the snapshot) rather than
    re-jit — the cost a cold process actually pays twice.  Probe prompts
    share the workload's system prompt with a fresh user turn, so each
    probe hits exactly the system-prefix chain (never a previous probe's).
    The restored and cold probes use the SAME prompt and must emit the
    same greedy tokens (``outputs_match``)."""
    import dataclasses
    import os
    import shutil
    import tempfile

    worst = -(-(system_len + 12 + max_new) // block_size)
    pool_blocks = 2 * worst + 6
    cfg_paged = dataclasses.replace(cfg, kv_layout="paged",
                                    kv_block_size=block_size,
                                    kv_pool_blocks=pool_blocks)
    workload = _prefix_workload(cfg_paged, n_requests=n_requests,
                                system_len=system_len, max_new=max_new)
    system = workload[0].prompt[:system_len]
    rng = np.random.default_rng(11)
    probe_x = np.concatenate(
        [system, rng.integers(0, cfg.vocab_size, 8)]).astype(np.int32)
    probe_y = np.concatenate(
        [system, rng.integers(0, cfg.vocab_size, 8)]).astype(np.int32)
    kw = dict(batch_size=batch, max_len=max_len, chunk_size=8,
              prefix_cache=True)

    # warm pass: compile the executable set every later engine reuses
    warm = Engine(cfg_paged, params, **kw)
    for r in workload:
        warm.submit(Request(rid=r.rid, prompt=r.prompt,
                            max_new_tokens=r.max_new_tokens))
    warm.run()
    _probe_ttft(warm, 9000, probe_x)
    cc = warm.cache_compiles

    # live engine: serve the workload, measure the warm cached-prefix TTFT,
    # then snapshot (save_repeats times for a median save cost)
    workdir = tempfile.mkdtemp(prefix="bench_restart_")
    engine = Engine(cfg_paged, params, compile_cache=cc,
                    snapshot_dir=workdir, snapshot_every=0, **kw)
    for r in workload:
        engine.submit(Request(rid=r.rid, prompt=r.prompt,
                              max_new_tokens=r.max_new_tokens))
    engine.run()
    prekill_ttft, _ = _probe_ttft(engine, 9001, probe_x)
    saves = []
    for _ in range(save_repeats):
        t0 = time.perf_counter()
        engine.snapshot()
        saves.append(time.perf_counter() - t0)
    from repro.serving import snapshot as snaplib
    _, snapdir = snaplib.latest_snapshot(workdir)
    snap_bytes = sum(os.path.getsize(os.path.join(dp, f))
                     for dp, _, fs in os.walk(snapdir) for f in fs)

    # the process "dies" here: the live engine is abandoned unflushed and a
    # fresh one recovers everything from disk
    t0 = time.perf_counter()
    restored = Engine.restore(workdir, params, compile_cache=cc)
    restore_s = time.perf_counter() - t0
    restored_ttft, out_restored = _probe_ttft(restored, 9002, probe_y)

    # cold start: same executables, but no durable state — the probe pays
    # the full system-prompt prefill again
    cold = Engine(cfg_paged, params, compile_cache=cc, **kw)
    cold_ttft, out_cold = _probe_ttft(cold, 9003, probe_y)

    shutil.rmtree(workdir, ignore_errors=True)
    return {
        "config": {"arch": cfg.name, "batch": batch, "max_len": max_len,
                   "block_size": block_size, "system_len": system_len,
                   "n_requests": n_requests, "pool_blocks": pool_blocks},
        "snapshot_save_ms": float(np.median(saves) * 1e3),
        "snapshot_bytes": snap_bytes,
        "restore_ms": restore_s * 1e3,
        "prekill_cached_ttft_ms": prekill_ttft * 1e3,
        "restored_ttft_ms": restored_ttft * 1e3,
        "cold_ttft_ms": cold_ttft * 1e3,
        "warm_restore_ttft_speedup": cold_ttft / max(restored_ttft, 1e-9),
        "restored_vs_prekill": restored_ttft / max(prekill_ttft, 1e-9),
        "outputs_match": out_restored == out_cold,
        "restored_prefix_hit_tokens": restored.prefix_hit_tokens,
    }


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def rows() -> list[tuple[str, float, str]]:
    """benchmarks.run driver entry: us/token + mixed-load latency cut."""
    cfg = get_smoke_config("qwen-7b", d_model=128, d_ff=256, vocab_size=512)
    params = quantize_model(api.init_params(cfg, jax.random.PRNGKey(0)),
                            "dense")
    workload = _workload(cfg, 6, 8)
    base = bench_per_request(cfg, params, workload, max_len=64)
    batched = bench_batched(cfg, params, workload, batch=4, max_len=64)
    # same engine with an int8 KV cache: decode runs the fused-dequant
    # blocked/pallas path end to end (decode_bench has the kernel-level cut)
    cfg_q = get_smoke_config("qwen-7b", d_model=128, d_ff=256, vocab_size=512,
                             kv_quant="int8")
    batched_q = bench_batched(cfg_q, params, workload, batch=4, max_len=64)
    mixed = run_mixed(cfg, params)
    out = [
        ("serving/per_request_tok", 1e6 / base["tokens_per_s"],
         f"tok_s={base['tokens_per_s']:.1f}"),
        ("serving/batched_b4_tok", 1e6 / batched["tokens_per_s"],
         f"tok_s={batched['tokens_per_s']:.1f} "
         f"occup={batched['occupancy']:.2f} "
         f"speedup={batched['tokens_per_s'] / base['tokens_per_s']:.2f}x"),
        ("serving/batched_b4_int8kv_tok", 1e6 / batched_q["tokens_per_s"],
         f"tok_s={batched_q['tokens_per_s']:.1f} "
         f"occup={batched_q['occupancy']:.2f}"),
        ("serving/mixed_ttft_p99_us", mixed["mixed"]["ttft_p99_ms"] * 1e3,
         f"vs_stall={mixed['ttft_p99_speedup']:.2f}x"),
        ("serving/mixed_itl_p99_us", mixed["mixed"]["itl_p99_ms"] * 1e3,
         f"vs_stall={mixed['itl_p99_speedup']:.2f}x"),
    ]
    spec = run_spec(cfg, params, n_requests=4, max_new=32, ks=(4,))
    k4 = spec["spec"][0]
    out.append(
        ("serving/spec_k4_tok", 1e6 / k4["tokens_per_s"],
         f"tok_s={k4['tokens_per_s']:.1f} "
         f"accept={k4['acceptance_rate']:.2f} "
         f"speedup={k4['speedup_vs_plain']:.2f}x "
         f"match={k4['outputs_match_baseline']}"))
    pfx = run_prefix_sharing(cfg, params, n_requests=10)
    out.append(
        ("serving/prefix_cached_ttft_p50_us",
         pfx["sharing"]["cached_ttft_p50_ms"] * 1e3,
         f"vs_cold={pfx['cached_ttft_p50_speedup']:.2f}x "
         f"hit_tokens={pfx['sharing']['prefix_hit_tokens']} "
         f"cow={pfx['sharing']['cow_copies']} "
         f"match={pfx['outputs_match']}"))
    ovl = run_overload(cfg, params)
    out.append(
        ("serving/overload_goodput_tok",
         1e6 / max(ovl["resilient"]["goodput_tokens_per_s"], 1e-9),
         f"goodput_gain={ovl['goodput_gain']:.2f}x "
         f"miss={ovl['resilient']['deadline_miss_rate']:.2f}"
         f"<-{ovl['stall_baseline']['deadline_miss_rate']:.2f} "
         f"preempt={ovl['resilient']['preemptions']}"))
    rst = run_restart(cfg, params, n_requests=8)
    out.append(
        ("serving/restore_us", rst["restore_ms"] * 1e3,
         f"save={rst['snapshot_save_ms']:.1f}ms "
         f"warm_ttft={rst['restored_ttft_ms']:.1f}ms "
         f"vs_cold={rst['warm_restore_ttft_speedup']:.2f}x "
         f"match={rst['outputs_match']}"))
    return out


def run_smoke(path: str = "BENCH_serving.json") -> dict:
    """CI trend record: mixed-load latency, chunked vs stall-prefill."""
    cfg = get_smoke_config("qwen-7b", d_model=128, d_ff=256, vocab_size=512)
    params = quantize_model(api.init_params(cfg, jax.random.PRNGKey(0)),
                            "dense")
    record = run_mixed(cfg, params)
    workload = _workload(cfg, 6, 8)
    base = bench_per_request(cfg, params, workload, max_len=64)
    batched = bench_batched(cfg, params, workload, batch=4, max_len=64)
    record["decode_tokens_per_s"] = {
        "per_request": base["tokens_per_s"],
        "batched_b4": batched["tokens_per_s"],
    }
    # paged-KV capacity cut: strictly more admissible resident tokens than
    # the slot layout at the same KV HBM budget (the acceptance record)
    record["paged_capacity"] = run_paged_capacity(cfg, params)
    # speculative-decoding cut: accepted tokens/dispatch and decode tok/s at
    # K in {2, 4, 8} on the repetition-heavy workload, plain decode baseline
    record["speculative"] = run_spec(cfg, params)
    # prefix-sharing cut: shared-system-prompt workload, sharing ON vs OFF
    # at equal KV HBM budget (cached TTFT + concurrency, outputs checked)
    record["prefix_sharing"] = run_prefix_sharing(cfg, params)
    # overload cut: past-capacity workload, stall-only baseline vs bounded
    # preemption + deadline enforcement (goodput must strictly dominate)
    record["overload"] = run_overload(cfg, params)
    # restart cut: snapshot save cost, Engine.restore latency, and the
    # warm-restore vs cold-start TTFT gap the durable prefix cache buys
    record["restart"] = run_restart(cfg, params)
    # mesh cut (subprocess, 8 forced host devices): sharded paged serving
    # must fit >= 1.5x the resident tokens of one device at equal
    # per-device KV budget, with identical token streams
    record["mesh"] = run_mesh()
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    print(json.dumps(record, indent=2, sort_keys=True))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="mixed",
                    choices=["mixed", "throughput", "spec", "prefix",
                             "overload", "restart", "mesh"])
    ap.add_argument("--arch", default="qwen-7b")
    ap.add_argument("--batches", default="1,2,4,8")
    ap.add_argument("--queue-depths", default="8,16")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--burst", type=int, default=6)
    ap.add_argument("--quantize", default="dense")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="int8 = fused-dequant decode path end to end")
    ap.add_argument("--smoke", action="store_true",
                    help="mixed-load latency smoke -> BENCH_serving.json")
    ap.add_argument("--paged-capacity", action="store_true",
                    help="slot vs paged resident-token capacity at equal "
                         "KV HBM budget")
    args = ap.parse_args()

    if args.smoke:
        run_smoke()
        return

    cfg = get_smoke_config(args.arch, d_model=128, d_ff=256, vocab_size=512,
                           kv_quant=args.kv_quant)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    if args.quantize != "none":
        params = quantize_model(params, args.quantize)

    if args.paged_capacity:
        rec = run_paged_capacity(cfg, params, max_len=args.max_len)
        print(json.dumps(rec, indent=2, sort_keys=True))
        gain = rec["resident_tokens_gain"]
        print(f"paged resident-token capacity: {gain:.2f}x the slot layout "
              f"at equal HBM (stalls: paged={rec['paged']['admission_stalls']}"
              f" slot={rec['slot']['admission_stalls']})")
        return

    if args.mode == "mesh":
        rec = run_mesh()
        print(f"{rec['n_devices']} devices, equal per-device KV budget "
              f"({rec['single_device']['per_device_pool_rows']} pool rows "
              f"each)")
        print(f"{'engine':>14} {'slots':>6} {'pool':>6} {'homes':>6} "
              f"{'resident':>9} {'stalls':>7} {'steps':>6} {'tok/s':>8}")
        for key, name in (("single_device", "single"),
                          ("sharded", "sharded")):
            r = rec[key]
            print(f"{name:>14} {r['batch_slots']:>6} {r['pool_blocks']:>6} "
                  f"{r['n_homes']:>6} {r['peak_resident_tokens']:>9} "
                  f"{r['admission_stalls']:>7} {r['steps']:>6} "
                  f"{r['tokens_per_s']:>8.1f}")
        print(f"sharded paged serving holds "
              f"{rec['resident_tokens_gain']:.2f}x the resident tokens of "
              f"one device (tokens_equal={rec['tokens_equal']})")
        return

    if args.mode == "prefix":
        rec = run_prefix_sharing(cfg, params, max_len=args.max_len)
        c = rec["config"]
        print(f"arch={cfg.name} system_prompt={c['system_len']} tokens, "
              f"{c['n_requests']} requests, pool={c['pool_blocks']} blocks "
              f"x {c['block_size']} (equal HBM both runs)")
        print(f"{'sharing':>8} {'cached_ttft_p50':>15} {'stalls':>7} "
              f"{'occup':>6} {'peak_blk':>8} {'steps':>6}")
        for key in ("no_sharing", "sharing"):
            r = rec[key]
            print(f"{str(r['prefix_cache']):>8} "
                  f"{r['cached_ttft_p50_ms']:>14.1f}m "
                  f"{r['admission_stalls']:>7} {r['occupancy']:>6.2f} "
                  f"{r['peak_pool_blocks']:>8} {r['steps']:>6}")
        on = rec["sharing"]
        print(f"cached-prefix TTFT p50 {rec['cached_ttft_p50_speedup']:.2f}x "
              f"faster, occupancy {rec['occupancy_gain']:.2f}x at equal pool "
              f"(outputs_match={rec['outputs_match']}); "
              f"{on['prefix']['hits']} hits, "
              f"{on['prefix_hit_tokens']} prompt tokens reused, "
              f"{on['cow_copies']} CoW copies, "
              f"{on['shared_blocks']} blocks shared at end")
        return

    if args.mode == "overload":
        rec = run_overload(cfg, params, max_len=args.max_len)
        c = rec["config"]
        print(f"arch={cfg.name} offered load {c['offered_load_x']:.1f}x "
              f"slot capacity ({c['hogs']} hogs x {c['hog_new']} tokens + "
              f"{c['interactive']} interactive x {c['int_new']}, deadline "
              f"{c['deadline_ms']:.0f} ms = {c['deadline_ticks']} ticks)")
        print(f"{'engine':>10} {'goodput/s':>10} {'miss':>6} {'ttft_p99':>9} "
              f"{'preempt':>8} {'kills':>6} {'stalls':>7} {'steps':>6}")
        for key, name in (("stall_baseline", "stall"),
                          ("resilient", "resilient")):
            r = rec[key]
            t = (f"{r['interactive_ttft_p99_ms']:>8.1f}m"
                 if r["interactive_ttft_p99_ms"] is not None else f"{'-':>9}")
            print(f"{name:>10} {r['goodput_tokens_per_s']:>10.1f} "
                  f"{r['deadline_miss_rate']:>6.2f} {t} "
                  f"{r['preemptions']:>8} {r['deadline_kills']:>6} "
                  f"{r['admission_stalls']:>7} {r['steps']:>6}")
        print(f"preemption+deadlines: {rec['goodput_gain']:.2f}x goodput, "
              f"miss rate -{rec['miss_rate_drop']:.2f} vs stall-only")
        return

    if args.mode == "restart":
        rec = run_restart(cfg, params, max_len=args.max_len)
        c = rec["config"]
        print(f"arch={cfg.name} {c['n_requests']} requests, system prompt "
              f"{c['system_len']} tokens, pool={c['pool_blocks']} blocks "
              f"(snapshot={rec['snapshot_bytes'] / 1024:.0f} KiB)")
        print(f"snapshot save      {rec['snapshot_save_ms']:>8.1f} ms "
              f"(median of 3, atomic)")
        print(f"Engine.restore     {rec['restore_ms']:>8.1f} ms "
              f"(device state + host replay + warm executables)")
        print(f"TTFT  pre-kill     {rec['prekill_cached_ttft_ms']:>8.1f} ms "
              f"(cached prefix, live engine)")
        print(f"TTFT  warm restore {rec['restored_ttft_ms']:>8.1f} ms "
              f"({rec['restored_vs_prekill']:.2f}x pre-kill; prefix cache "
              f"survived the crash)")
        print(f"TTFT  cold start   {rec['cold_ttft_ms']:>8.1f} ms "
              f"(no durable state)")
        print(f"warm restore beats cold start "
              f"{rec['warm_restore_ttft_speedup']:.2f}x on TTFT "
              f"(outputs_match={rec['outputs_match']})")
        return

    if args.mode == "spec":
        rec = run_spec(cfg, params, max_len=args.max_len)
        print(f"arch={cfg.name} max_len={args.max_len} "
              f"workload={rec['config']['n_requests']} reqs x "
              f"{rec['config']['max_new']} new tokens (repetition-heavy)")
        print(f"{'spec_k':>6} {'tok/s':>8} {'disp':>6} {'accept':>7} "
              f"{'acc/disp':>8} {'rewinds':>7} {'speedup':>8} {'match':>6}")
        b = rec["baseline"]
        print(f"{0:>6} {b['tokens_per_s']:>8.1f} {b['dispatches']:>6} "
              f"{'-':>7} {'-':>8} {'-':>7} {'1.00x':>8} {'-':>6}")
        for t in rec["spec"]:
            print(f"{t['spec_k']:>6} {t['tokens_per_s']:>8.1f} "
                  f"{t['dispatches']:>6} {t['acceptance_rate']:>7.2f} "
                  f"{t['accepted_per_dispatch']:>8.2f} {t['rewinds']:>7} "
                  f"{t['speedup_vs_plain']:>7.2f}x "
                  f"{str(t['outputs_match_baseline']):>6}")
        print(f"best decode throughput: {rec['best_speedup']:.2f}x plain "
              f"decode (same greedy outputs)")
        return

    if args.mode == "mixed":
        rec = run_mixed(cfg, params, max_len=args.max_len,
                        chunk_size=args.chunk_size, burst=args.burst)
        print(f"arch={cfg.name} max_len={args.max_len} "
              f"chunk={args.chunk_size} burst={args.burst}")
        print(f"{'policy':>8} {'ttft_p50':>9} {'ttft_p99':>9} "
              f"{'itl_p50':>9} {'itl_p99':>9} {'tok/s':>8}")
        for key in ("stall_prefill", "mixed"):
            r = rec[key]
            print(f"{r['policy']:>8} {r['ttft_p50_ms']:>8.1f}m "
                  f"{r['ttft_p99_ms']:>8.1f}m {r['itl_p50_ms']:>8.1f}m "
                  f"{r['itl_p99_ms']:>8.1f}m {r['decode_tokens_per_s']:>8.1f}")
        print(f"chunked admission: ttft_p99 {rec['ttft_p99_speedup']:.2f}x, "
              f"itl_p99 {rec['itl_p99_speedup']:.2f}x vs stall-prefill")
        return

    depths = [int(d) for d in args.queue_depths.split(",")]
    batches = [int(b) for b in args.batches.split(",")]
    print(f"arch={cfg.name} max_new={args.max_new_tokens} max_len={args.max_len}")
    print(f"{'queue':>6} {'mode':>14} {'batch':>6} {'tok/s':>9} "
          f"{'steps':>6} {'occup':>6}")
    for depth in depths:
        workload = _workload(cfg, depth, args.max_new_tokens)
        base = bench_per_request(cfg, params, workload, args.max_len)
        print(f"{depth:>6} {'per-request':>14} {1:>6} "
              f"{base['tokens_per_s']:>9.1f} {base['tokens']:>6} {'-':>6}")
        for batch in batches:
            r = bench_batched(cfg, params, workload, batch, args.max_len,
                              chunk_size=args.chunk_size)
            speedup = r["tokens_per_s"] / base["tokens_per_s"]
            print(f"{depth:>6} {'batched':>14} {batch:>6} "
                  f"{r['tokens_per_s']:>9.1f} {r['steps']:>6} "
                  f"{r['occupancy']:>6.2f}  ({speedup:.2f}x vs per-request)")


if __name__ == "__main__":
    main()
