"""Serving throughput benchmark: batched continuous decode vs the seed's
per-request loop.

Measures decode tokens/s as a function of slot-batch size and queue depth.
The baseline is the seed engine's inner loop (one batch-1 jitted
``decode_step`` per live request per step, ``reference_decode``); the
contender is the slot-based ``Engine`` (ONE jitted decode over all B slots
per step).  Both share the bucketed prefill contract, so the delta isolates
the scheduler + dispatch win — the JAX restatement of EdgeLLM Fig. 9's
"keep the accelerator saturated" pipeline.

Run:  PYTHONPATH=src python benchmarks/serving_bench.py [--batches 1,2,4]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache, quantize_model
from repro.models import api
from repro.serving.engine import Engine, Request, reference_decode


def _workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [
        (rng.integers(0, cfg.vocab_size, int(rng.integers(4, 28))).astype(np.int32),
         max_new)
        for _ in range(n_requests)
    ]


def bench_batched(cfg, params, workload, batch: int, max_len: int):
    """Slot engine: timed after a warmup run compiles the executable set."""
    def submit_all(engine):
        for rid, (prompt, max_new) in enumerate(workload):
            engine.submit(Request(rid=rid, prompt=prompt,
                                  max_new_tokens=max_new))

    warm = Engine(cfg, params, batch_size=batch, max_len=max_len)
    submit_all(warm)
    warm.run()

    engine = Engine(cfg, params, batch_size=batch, max_len=max_len,
                    compile_cache=warm.cache_compiles)  # same (cfg, max_len)
    submit_all(engine)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.output) - 1 for r in done)  # decode tokens only
    return {
        "tokens": tokens,
        "tokens_per_s": tokens / dt,
        "steps": engine.steps,
        "occupancy": engine.slot_occupancy,
    }


def bench_per_request(cfg, params, workload, max_len: int):
    """Seed baseline: sequential batch-1 greedy loops (shared compile cache)."""
    cc = CompileCache()
    for prompt, max_new in workload:                  # warm/compile pass
        reference_decode(cfg, params, prompt, max_new, max_len=max_len,
                         compile_cache=cc)
    t0 = time.perf_counter()
    tokens = 0
    for prompt, max_new in workload:
        out = reference_decode(cfg, params, prompt, max_new, max_len=max_len,
                               compile_cache=cc)
        tokens += len(out) - 1
    dt = time.perf_counter() - t0
    return {"tokens": tokens, "tokens_per_s": tokens / dt}


def rows() -> list[tuple[str, float, str]]:
    """benchmarks.run driver entry: us/token at queue=6 for both modes."""
    cfg = get_smoke_config("qwen-7b", d_model=128, d_ff=256, vocab_size=512)
    params = quantize_model(api.init_params(cfg, jax.random.PRNGKey(0)),
                            "dense")
    workload = _workload(cfg, 6, 8)
    base = bench_per_request(cfg, params, workload, max_len=64)
    batched = bench_batched(cfg, params, workload, batch=4, max_len=64)
    # same engine with an int8 KV cache: decode runs the fused-dequant
    # blocked/pallas path end to end (decode_bench has the kernel-level cut)
    cfg_q = get_smoke_config("qwen-7b", d_model=128, d_ff=256, vocab_size=512,
                             kv_quant="int8")
    batched_q = bench_batched(cfg_q, params, workload, batch=4, max_len=64)
    return [
        ("serving/per_request_tok", 1e6 / base["tokens_per_s"],
         f"tok_s={base['tokens_per_s']:.1f}"),
        ("serving/batched_b4_tok", 1e6 / batched["tokens_per_s"],
         f"tok_s={batched['tokens_per_s']:.1f} "
         f"occup={batched['occupancy']:.2f} "
         f"speedup={batched['tokens_per_s'] / base['tokens_per_s']:.2f}x"),
        ("serving/batched_b4_int8kv_tok", 1e6 / batched_q["tokens_per_s"],
         f"tok_s={batched_q['tokens_per_s']:.1f} "
         f"occup={batched_q['occupancy']:.2f}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen-7b")
    ap.add_argument("--batches", default="1,2,4,8")
    ap.add_argument("--queue-depths", default="8,16")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--quantize", default="dense")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="int8 = fused-dequant decode path end to end")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch, d_model=128, d_ff=256, vocab_size=512,
                           kv_quant=args.kv_quant)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    if args.quantize != "none":
        params = quantize_model(params, args.quantize)

    depths = [int(d) for d in args.queue_depths.split(",")]
    batches = [int(b) for b in args.batches.split(",")]
    print(f"arch={cfg.name} max_new={args.max_new_tokens} max_len={args.max_len}")
    print(f"{'queue':>6} {'mode':>14} {'batch':>6} {'tok/s':>9} "
          f"{'steps':>6} {'occup':>6}")
    for depth in depths:
        workload = _workload(cfg, depth, args.max_new_tokens)
        base = bench_per_request(cfg, params, workload, args.max_len)
        print(f"{depth:>6} {'per-request':>14} {1:>6} "
              f"{base['tokens_per_s']:>9.1f} {base['tokens']:>6} {'-':>6}")
        for batch in batches:
            r = bench_batched(cfg, params, workload, batch, args.max_len)
            speedup = r["tokens_per_s"] / base["tokens_per_s"]
            print(f"{depth:>6} {'batched':>14} {batch:>6} "
                  f"{r['tokens_per_s']:>9.1f} {r['steps']:>6} "
                  f"{r['occupancy']:>6.2f}  ({speedup:.2f}x vs per-request)")


if __name__ == "__main__":
    main()
