"""Table I reproduction: mixed-precision computing-unit error rates.

The paper tests 100,000 random inputs through three datapaths and reports
the rate of "erroneous" outputs (error beyond a half-ULP-of-FP16 criterion):

    this work  (full-mantissa + scale-after-accumulate): 0.047% / 0.0044%
    baseline1  (pairwise adder tree, FP16 intermediates): 2.86% / 14.47%
    baseline2  (pairwise adder tree, FP20 S1-E6-M13):     2.64% / 0.02%

We re-run that experiment numerically: a 128-length FP16(×INT4) dot product
evaluated with (a) our kernel numerics (integer-exact product, f32
accumulate, scale at the end — the MXU path), (b) an FP16 pairwise adder
tree, (c) an FP20-like tree (f32 accumulate rounded to 13-bit mantissa per
add).  Reference = float64.  Error rate = fraction of outputs whose
relative error exceeds an FP16 ULP (2^-11).
"""

from __future__ import annotations

import numpy as np
import ml_dtypes

T_IN = 128
N_TRIALS = 100_000
_TOL = 2.0 ** -11        # one FP16 mantissa ULP


def _round_mantissa(x: np.ndarray, bits: int) -> np.ndarray:
    """Round f32 to `bits` explicit mantissa bits (FP20 = 13)."""
    m, e = np.frexp(x)
    scale = 2.0 ** bits
    return np.ldexp(np.round(m * scale) / scale, e)


def _pairwise_tree(x: np.ndarray, round_fn) -> np.ndarray:
    """Pairwise adder tree along axis 1 with per-add rounding."""
    while x.shape[1] > 1:
        if x.shape[1] % 2:
            x = np.concatenate([x, np.zeros_like(x[:, :1])], axis=1)
        x = round_fn(x[:, 0::2] + x[:, 1::2])
    return x[:, 0]


def run(n_trials: int = N_TRIALS, seed: int = 0) -> dict[str, float]:
    rng = np.random.default_rng(seed)
    # FP16*INT4 mode: activations fp16, weights int4 with fp16 group scale
    act = rng.normal(0, 1, (n_trials, T_IN)).astype(ml_dtypes.bfloat16).astype(np.float64)
    wq = rng.integers(-8, 8, (n_trials, T_IN)).astype(np.float64)
    scale = np.abs(rng.normal(0, 0.05, (n_trials, 1))).astype(np.float16).astype(np.float64)

    prods_int = act * wq                          # integer-exact in bf16/f32
    exact_i4 = (prods_int.sum(axis=1)) * scale[:, 0]

    # (a) ours: f32 accumulate of exact products, scale at the end
    ours_i4 = (prods_int.astype(np.float32).sum(axis=1, dtype=np.float32)
               * scale[:, 0].astype(np.float32))
    # (b) baseline1: scale first (fp16 products), fp16 pairwise tree
    prods16 = (prods_int * scale).astype(np.float16).astype(np.float64)
    b1_i4 = _pairwise_tree(prods16.copy(),
                           lambda v: v.astype(np.float16).astype(np.float64))
    # (c) baseline2: fp20-ish tree
    b2_i4 = _pairwise_tree(prods16.copy(), lambda v: _round_mantissa(v, 13))

    # FP16*FP16 mode (MHA): both operands fp16
    a2 = rng.normal(0, 1, (n_trials, T_IN)).astype(np.float16).astype(np.float64)
    b2v = rng.normal(0, 1, (n_trials, T_IN)).astype(np.float16).astype(np.float64)
    prods2 = a2 * b2v
    exact_f16 = prods2.sum(axis=1)
    ours_f16 = prods2.astype(np.float32).sum(axis=1, dtype=np.float32)
    p16 = prods2.astype(np.float16).astype(np.float64)
    b1_f16 = _pairwise_tree(p16.copy(),
                            lambda v: v.astype(np.float16).astype(np.float64))
    b2_f16 = _pairwise_tree(p16.copy(), lambda v: _round_mantissa(v, 13))

    def err_rate(got, exact):
        rel = np.abs(got - exact) / np.maximum(np.abs(exact), 1e-6)
        return float((rel > _TOL).mean() * 100)

    return {
        "ours_fp16xint4_pct": err_rate(ours_i4, exact_i4),
        "ours_fp16xfp16_pct": err_rate(ours_f16, exact_f16),
        "baseline1_fp16xint4_pct": err_rate(b1_i4, exact_i4),
        "baseline1_fp16xfp16_pct": err_rate(b1_f16, exact_f16),
        "baseline2_fp16xint4_pct": err_rate(b2_i4, exact_i4),
        "baseline2_fp16xfp16_pct": err_rate(b2_f16, exact_f16),
    }


def rows() -> list[tuple[str, float, str]]:
    r = run()
    out = []
    for k, v in r.items():
        out.append((f"table1/{k}", 0.0, f"{v:.4f}%"))
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(k, f"{v:.4f}%")
