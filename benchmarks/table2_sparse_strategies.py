"""Table II reproduction: GLM-6B per-layer-kind weight bytes under the
paper's sparse strategies, and the resulting decode speedup.

Paper (per block): dense 100.33 MB -> s1 79.22 MB -> s2 61.50 MB ->
s3 53.15 MB, speedups 1 / 1.27 / 1.63 / 1.89x.

Our numbers come from the packing cost model applied to the paper's
layer-kind map (Q/K/V dense; O 50%; h->4h per strategy; 4h->h per
strategy), with one-hot vs addr-in-block chosen per the paper's hybrid
rule.  Decode speed is weight-bytes-bound (the paper's own §V-B model), so
speedup = dense_bytes / strategy_bytes.
"""

from __future__ import annotations

from repro.configs import get_config
from repro.core.compiler import SPARSE_STRATEGIES
from repro.core.sparsity import packing_cost


def _layer_matrices(cfg) -> dict[str, tuple[int, int]]:
    d, hd, hq, hkv, f = (cfg.d_model, cfg.head_dim, cfg.n_heads,
                         cfg.n_kv_heads, cfg.d_ff)
    return {
        "Q": ("qkv", d, hq * hd),
        "K": ("qkv", d, hkv * hd),
        "V": ("qkv", d, hkv * hd),
        "O": ("o", hq * hd, d),
        "h_to_4h": ("h_to_4h", d, 2 * f),   # gate+up (GLM uses paired GLU)
        "4h_to_h": ("4h_to_h", f, d),
    }


def _bytes(in_f: int, out_f: int, density: float) -> float:
    # per-out-channel package of `in_f` channels; paper's hybrid encoding
    cost = packing_cost(density, "auto", channels=max(2048, in_f))
    bits_per_channel = cost.total_bits / max(2048, in_f)
    return in_f * out_f * bits_per_channel / 8


def run(arch: str = "chatglm-6b") -> list[dict]:
    cfg = get_config(arch)
    mats = _layer_matrices(cfg)
    out = []
    dense_total = None
    for strategy in ("dense", "strategy1", "strategy2", "strategy3"):
        dmap = SPARSE_STRATEGIES[strategy]
        per_kind = {}
        total = 0.0
        for name, (kind, in_f, out_f) in mats.items():
            b = _bytes(in_f, out_f, dmap.get(kind, 1.0))
            per_kind[name] = b / 1e6
            total += b
        if dense_total is None:
            dense_total = total
        out.append({
            "strategy": strategy,
            **{f"{k}_MB": round(v, 2) for k, v in per_kind.items()},
            "block_total_MB": round(total / 1e6, 2),
            "speedup": round(dense_total / total, 2),
        })
    return out


def rows() -> list[tuple[str, float, str]]:
    return [(f"table2/{r['strategy']}", 0.0,
             f"block={r['block_total_MB']}MB speedup={r['speedup']}x")
            for r in run()]


if __name__ == "__main__":
    for r in run():
        print(r)
