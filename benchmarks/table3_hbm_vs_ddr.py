"""Table III reproduction: per-operator step latency, HBM vs DDR memory
system, decode token=128 and prefill token=128 (dense GLM).

Uses the op-graph latency model (core/opgraph.py) with the paper's VCU128
constants: HBM 460 GB/s, DDR 60 GB/s, compute 8192 MACs @ 280 MHz
(decode parallelism 2048 x 2 clock = 1.147 TFLOP/s eqv).  Reproduces the
paper's qualitative structure: VMM steps dominate and blow up ~4x on DDR in
decode; prefill is compute-bound so DDR hurts far less; plus the
paper's summary rows (single-block delay, total LLM delay, token/s).
"""

from __future__ import annotations

from repro.configs import get_config
from repro.core import opgraph

HBM_BW = 460e9
DDR_BW = 60e9
FPGA_FLOPS = 2.294e12      # 4096 int4 MACs @ 280 MHz x 2 ops/MAC


def run(arch: str = "chatglm-6b") -> dict:
    cfg = get_config(arch)
    out = {"steps": [], "summary": {}}
    for mode, tokens in (("decode", 1), ("prefill", 128)):
        ctx = 128
        graph = opgraph.block_graph(cfg, tokens=tokens, context=ctx)
        rows = []
        for op in graph:
            t_hbm = op.ideal_time_s(hbm_bw=HBM_BW, ddr_bw=DDR_BW,
                                    compute_flops=FPGA_FLOPS)
            t_ddr = op.ideal_time_s(hbm_bw=DDR_BW, ddr_bw=DDR_BW,
                                    compute_flops=FPGA_FLOPS)
            rows.append({"step": op.name, "mode": mode,
                         "hbm_us": t_hbm * 1e6, "ddr_us": t_ddr * 1e6})
        out["steps"].extend(rows)
        block_hbm = sum(r["hbm_us"] for r in rows)
        block_ddr = sum(r["ddr_us"] for r in rows)
        epi = opgraph.epilogue_graph(cfg)
        epi_hbm = sum(op.ideal_time_s(hbm_bw=HBM_BW, ddr_bw=DDR_BW,
                                      compute_flops=FPGA_FLOPS) for op in epi)
        epi_ddr = sum(op.ideal_time_s(hbm_bw=DDR_BW, ddr_bw=DDR_BW,
                                      compute_flops=FPGA_FLOPS) for op in epi)
        total_hbm = block_hbm * cfg.n_layers + epi_hbm * 1e6
        total_ddr = block_ddr * cfg.n_layers + epi_ddr * 1e6
        out["summary"][mode] = {
            "block_hbm_us": round(block_hbm, 1),
            "block_ddr_us": round(block_ddr, 1),
            "total_hbm_ms": round(total_hbm / 1e3, 2),
            "total_ddr_ms": round(total_ddr / 1e3, 2),
            "tokens_per_s_hbm": round(tokens / (total_hbm / 1e6), 2),
            "tokens_per_s_ddr": round(tokens / (total_ddr / 1e6), 2),
            "ddr_slowdown": round(total_ddr / total_hbm, 2),
        }
    return out


def rows() -> list[tuple[str, float, str]]:
    r = run()
    out = []
    for mode, s in r["summary"].items():
        out.append((f"table3/{mode}", s["block_hbm_us"],
                    f"hbm={s['tokens_per_s_hbm']}tok/s "
                    f"ddr={s['tokens_per_s_ddr']}tok/s "
                    f"slowdown={s['ddr_slowdown']}x"))
    return out


if __name__ == "__main__":
    r = run()
    for row in r["steps"]:
        print(f"{row['mode']:8s} {row['step']:24s} "
              f"hbm={row['hbm_us']:9.2f}us ddr={row['ddr_us']:9.2f}us")
    print(r["summary"])
