"""Table V reproduction: platform efficiency comparison.

Paper row (EdgeLLM @ VCU128): ~75% bandwidth utilization, 85.8 tok/s on the
6B LLM @ 56.8 W -> 1.51 token/J.  We reproduce EdgeLLM's own numbers from
the op-graph model, then extend the table with the TPU-v5e single-chip
projection of the same W4A16 + sparse technique (this repo's actual
target), derived from the decode roofline memory term.
"""

from __future__ import annotations

from repro.configs import get_config
from repro.core import opgraph
from repro.core.sparsity import packing_cost

VCU128 = dict(hbm_bw=460e9, ddr_bw=60e9, compute=2.294e12, power_w=56.86)
V5E = dict(hbm_bw=819e9, compute=197e12, power_w=170.0)  # chip TDP est.


def _edgellm_tokens_per_s(cfg, wt_bits: float, hw=VCU128, ctx=128) -> float:
    g = opgraph.model_graph(cfg, tokens=1, context=ctx, wt_bits=wt_bits)
    t = opgraph.total_time_s(g, hbm_bw=hw["hbm_bw"], ddr_bw=hw["ddr_bw"],
                             compute_flops=hw["compute"])
    return 1.0 / t


def _v5e_decode_tokens_per_s(cfg, wt_bits: float, ctx=128) -> float:
    """Weight-streaming bound on one v5e chip (decode batch 1)."""
    n = cfg.param_count()
    weight_bytes = n * wt_bits / 8
    kv_bytes = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * ctx * 2
    return V5E["hbm_bw"] / (weight_bytes + kv_bytes)


def run() -> list[dict]:
    cfg = get_config("chatglm-6b")
    qwen = get_config("qwen-7b")
    sparse_bits = packing_cost(0.25, "auto").effective_bitwidth()  # s2-ish mix
    dense_bits = packing_cost(1.0).effective_bitwidth()

    rows_ = [
        {"platform": "A100 GPU (paper)", "bw_util": "~30%",
         "tokens_per_s": 45.0, "power_w": 220.0},
        {"platform": "FlightLLM U280 (paper)", "bw_util": "65.9%",
         "tokens_per_s": 55.0, "power_w": 45.0},
        {"platform": "EdgeLLM VCU128 (paper)", "bw_util": "~75%",
         "tokens_per_s": 85.8, "power_w": 56.86},
    ]
    # our reproduction of the paper's own platform, sparse strategy-3-ish
    ours = _edgellm_tokens_per_s(cfg, wt_bits=2.2)
    rows_.append({"platform": "EdgeLLM VCU128 (our model)",
                  "bw_util": "100% (ideal)", "tokens_per_s": round(ours, 1),
                  "power_w": 56.86})
    rows_.append({"platform": "Qwen-7B VCU128 (our model)",
                  "bw_util": "100% (ideal)",
                  "tokens_per_s": round(
                      _edgellm_tokens_per_s(qwen, wt_bits=2.2), 1),
                  "power_w": 56.86})
    # TPU v5e projections of the same technique
    for name, bits in (("bf16", 16.0), ("W4A16 dense", dense_bits),
                       ("W4A16+sparse-s2", 2.7)):
        tps = _v5e_decode_tokens_per_s(cfg, bits)
        rows_.append({"platform": f"TPU v5e 1 chip, {name} (this repo)",
                      "bw_util": "100% (roofline)",
                      "tokens_per_s": round(tps, 1), "power_w": V5E["power_w"]})
    for r in rows_:
        r["tokens_per_joule"] = round(r["tokens_per_s"] / r["power_w"], 3)
    return rows_


def rows() -> list[tuple[str, float, str]]:
    return [(f"table5/{r['platform'][:40]}", 0.0,
             f"{r['tokens_per_s']}tok/s {r['tokens_per_joule']}tok/J")
            for r in run()]


if __name__ == "__main__":
    for r in run():
        print(r)
