"""Quickstart: the EdgeLLM technique end to end on one small model.

  1. build a model (reduced ChatGLM-family config),
  2. quantize it with the paper's compiler (W4A16 + log-scale sparsity),
  3. compare outputs dense vs quantized vs sparse,
  4. decode a few tokens through the serving path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.compiler import quantize_model, quantized_bytes
from repro.models import api


def main() -> None:
    cfg = get_smoke_config("chatglm-6b", d_model=512, d_ff=1024, vocab_size=512)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(cfg, rng)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)

    logits, _ = api.forward(cfg, params, {"tokens": tokens})
    print(f"dense forward: logits {logits.shape}, "
          f"params {quantized_bytes(params)/1e6:.1f} MB")

    for strategy in ("dense", "strategy1", "strategy3"):
        qp = quantize_model(params, strategy)
        qlogits, _ = api.forward(cfg, qp, {"tokens": tokens})
        corr = np.corrcoef(np.asarray(logits, np.float32).ravel(),
                           np.asarray(qlogits, np.float32).ravel())[0, 1]
        print(f"{strategy:10s}: {quantized_bytes(qp)/1e6:6.2f} MB "
              f"logit corr vs dense = {corr:.4f}")

    # greedy decode through prefill + decode_step
    qp = quantize_model(params, "dense")
    prompt = tokens[:1, :8]
    logits0, cache = api.prefill(cfg, qp, {"tokens": prompt}, max_len=64)
    out = [int(jnp.argmax(logits0[0]))]
    length = prompt.shape[1]
    for _ in range(8):
        length += 1
        logits_t, cache = api.decode_step(
            cfg, qp, cache, jnp.asarray([[out[-1]]], jnp.int32), jnp.int32(length))
        out.append(int(jnp.argmax(logits_t[0])))
    print("decoded token ids:", out)


if __name__ == "__main__":
    main()
