"""Serving example: continuous batching through the quantized engine
(the paper's client/server deployment, §IV-B).

All requests share one slot-based KV cache; each step is a single jitted
decode over every slot with per-row lengths, and finished slots are
refilled from the queue mid-flight.

Run:  PYTHONPATH=src python examples/serve.py
"""

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.core.compiler import quantize_model
from repro.models import api
from repro.serving.engine import Engine, Request


def main() -> None:
    cfg = get_smoke_config("qwen-7b", d_model=256, d_ff=512, vocab_size=1024)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_model(params, "strategy2")   # W4A16 + log-scale sparse

    engine = Engine(cfg, qparams, batch_size=4, max_len=128)
    rng = np.random.default_rng(0)
    for rid in range(8):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 24))
        engine.submit(Request(rid=rid, prompt=prompt.astype(np.int32),
                              max_new_tokens=16))

    done = engine.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.output[:8]}...")
    print("summary:", Engine.summarize(done))
    print(f"scheduler: {engine.steps} batched ticks "
          f"({engine.dispatches} dispatches, {engine.mixed_ticks} mixed), "
          f"slot occupancy {engine.slot_occupancy:.2f}")
    print(f"compile cache: {len(engine.cache_compiles)} executables, "
          f"{engine.cache_compiles.hits} hits / "
          f"{engine.cache_compiles.misses} misses (dynamic compilation)")


if __name__ == "__main__":
    main()
