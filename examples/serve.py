"""Serving example: continuous batching through the quantized engine
(the paper's client/server deployment, §IV-B).

All requests share one slot-based KV cache; each step is a single jitted
decode over every slot with per-row lengths, and finished slots are
refilled from the queue mid-flight.  Pass ``--spec`` to layer speculative
decoding on top: prompt-lookup drafts verified K+1 tokens at a time
through the same mixed dispatch (greedy outputs are identical token for
token — only the dispatch count changes).  Pass ``--prefix-cache`` to run
the paged layout with cross-request prefix sharing: every request carries
the same synthetic system prompt, so after the first author finishes its
KV blocks admit later requests by page-table copy (plus at most one
copy-on-write block) instead of re-prefilling.  Pass ``--chaos`` to inject
deterministic faults (reservation denials, forced preemptions, NaN rows)
and watch the lifecycle absorb them: faulted rows finish
``status="error"``, preempted requests requeue losslessly (bounded by
``--max-preemptions``), ``--deadline-s`` expires laggards, and everything
else still matches the batch-1 oracle bitwise.  Pass ``--snapshot-dir``
to make the run crash-safe: atomic engine snapshots plus a write-ahead
request journal, so a killed process restarts with ``--restore`` and
finishes every request with the exact tokens it would have emitted
uninterrupted.

Run:  PYTHONPATH=src python examples/serve.py [--spec] [--prefix-cache]
      PYTHONPATH=src python examples/serve.py --chaos --max-preemptions 2
      PYTHONPATH=src python examples/serve.py --snapshot-dir /tmp/snap
      PYTHONPATH=src python examples/serve.py --snapshot-dir /tmp/snap --restore
"""

import argparse

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.core.compiler import quantize_model
from repro.models import api
from repro.serving.engine import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding (prompt-lookup drafts)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per verify row")
    ap.add_argument("--drafter", default="plookup")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="paged KV + cross-request prefix sharing")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (seconds after submit); "
                         "expired requests finish status=deadline_missed")
    ap.add_argument("--max-preemptions", type=int, default=0,
                    help="lossless evict-and-requeue bound per request "
                         "(0 = stall-only admission)")
    ap.add_argument("--chaos", action="store_true",
                    help="seeded fault injection (repro.serving.chaos)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="atomic engine snapshots + write-ahead request "
                         "journal under this dir (crash-safe serving)")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="snapshot cadence in ticks (with --snapshot-dir)")
    ap.add_argument("--restore", action="store_true",
                    help="recover from --snapshot-dir instead of starting "
                         "fresh; the journal replays anything the last "
                         "snapshot missed and in-flight requests resume")
    args = ap.parse_args()
    if args.restore and not args.snapshot_dir:
        ap.error("--restore requires --snapshot-dir")

    kv = (dict(kv_layout="paged", kv_block_size=16)
          if args.prefix_cache else {})
    cfg = get_smoke_config("qwen-7b", d_model=256, d_ff=512, vocab_size=1024,
                           **kv)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_model(params, "strategy2")   # W4A16 + log-scale sparse

    chaos = None
    if args.chaos:
        from repro.serving.chaos import ChaosConfig, ChaosMonkey
        chaos = ChaosMonkey(ChaosConfig(seed=0, deny_rate=0.05,
                                        preempt_rate=0.1, nan_rate=0.02))
    if args.restore:
        engine = Engine.restore(args.snapshot_dir, qparams, chaos=chaos)
        print(f"restored from {args.snapshot_dir}:",
              engine.durability_stats())
    else:
        engine = Engine(cfg, qparams, batch_size=4, max_len=128,
                        spec_k=args.spec_k if args.spec else 0,
                        drafter=args.drafter,
                        prefix_cache=args.prefix_cache,
                        max_preemptions=args.max_preemptions, chaos=chaos,
                        snapshot_dir=args.snapshot_dir,
                        snapshot_every=args.snapshot_every)
        rng = np.random.default_rng(0)
        system = (rng.integers(0, cfg.vocab_size, 32)
                  if args.prefix_cache else rng.integers(0, cfg.vocab_size, 0))
        for rid in range(8):
            user = rng.integers(0, cfg.vocab_size, rng.integers(4, 24))
            engine.submit(Request(rid=rid,
                                  prompt=np.concatenate(
                                      [system, user]).astype(np.int32),
                                  max_new_tokens=16,
                                  deadline_s=args.deadline_s))

    done = engine.run()
    for r in sorted(done, key=lambda r: r.rid):
        tag = "" if r.status == "done" else f" [{r.status}]"
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> "
              f"{r.output[:8]}...{tag}")
    print("summary:", Engine.summarize(done))
    if chaos is not None or args.max_preemptions or args.deadline_s:
        print("resilience:", engine.resilience_stats())
    if args.snapshot_dir:
        print("durability:", engine.durability_stats())
    print(f"scheduler: {engine.steps} batched ticks "
          f"({engine.dispatches} dispatches, {engine.mixed_ticks} mixed), "
          f"slot occupancy {engine.slot_occupancy:.2f}")
    if engine.spec_k:
        s = engine.spec_stats()
        print(f"speculation: K={s['spec_k']}, "
              f"{s['accepted_per_dispatch']:.2f} accepted tokens/dispatch, "
              f"acceptance {s['acceptance_rate']:.2f} "
              f"({s['accepted_tokens']}/{s['draft_tokens']} drafts, "
              f"{s['rewinds']} rewinds)")
    if engine.prefix_sharing:
        p = engine.prefix_stats()
        print(f"prefix cache: {p['hits']} hits "
              f"({p['hit_tokens']} prompt tokens reused), "
              f"{p['shared_blocks']} shared blocks, "
              f"{p['cow_copies']} CoW copies")
    print(f"compile cache: {len(engine.cache_compiles)} executables, "
          f"{engine.cache_compiles.hits} hits / "
          f"{engine.cache_compiles.misses} misses (dynamic compilation)")


if __name__ == "__main__":
    main()
