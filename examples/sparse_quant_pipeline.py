"""The paper's offline compiler pipeline, end to end on one weight matrix:

  magnitude stats -> log-scale structured sparsity choice -> block INT4
  quantization -> packing cost accounting -> kernel execution check.

Run:  PYTHONPATH=src python examples/sparse_quant_pipeline.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.quant import quantize, dequantize
from repro.core.sparsity import (LOG_SCALE_DENSITIES, block_sparsify_quantize,
                                 enhancement_ratio, packing_cost,
                                 sparse_dequantize)
from repro.kernels import ops


def main() -> None:
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.02, (4096, 512)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1.0, (4, 4096)).astype(np.float32))
    ref = np.asarray(x @ w)

    print(f"weight {w.shape}: dense fp16 = {w.size*2/1e6:.2f} MB")
    qt = quantize(w)
    err = np.abs(np.asarray(dequantize(qt, jnp.float32)) - np.asarray(w)).max()
    print(f"W4A16: {qt.nbytes_model/1e6:.2f} MB  max dequant err {err:.2e}")

    for density in LOG_SCALE_DENSITIES:
        cost = packing_cost(density)
        if density == 1.0:
            out = ops.w4a16_matmul(x, qt, impl="xla")
        else:
            st = block_sparsify_quantize(w, density)
            out = ops.sparse_w4a16_matmul(x, st, impl="xla")
        nrmse = (np.sqrt(np.mean((np.asarray(out, np.float32) - ref) ** 2))
                 / ref.std())
        print(f"density {density:5.3f}: eff {cost.effective_bitwidth():.3f} "
              f"bits ({cost.encoding:13s}) enhancement "
              f"{enhancement_ratio(density):.2f}x  matmul NRMSE {nrmse:.3f}")


if __name__ == "__main__":
    main()
