"""End-to-end training driver: ~100M-param qwen3-family model, a few hundred
steps on the synthetic pipeline, with checkpointing + preemption handling +
straggler watchdog — the full production loop at laptop scale.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--resume]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.models import api
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train import checkpoint as ckpt
from repro.train.fault import PreemptionGuard, StragglerWatchdog
from repro.train.trainer import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    # ~100M params: qwen3 family, shrunk
    cfg = get_config(
        "qwen3-8b", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32768, dtype=jnp.float32,
        remat="none")
    n_params = cfg.param_count()
    print(f"model: {cfg.name} shrunk to {n_params/1e6:.1f}M params")

    opt = AdamW(lr=cosine_schedule(3e-4, 20, args.steps))
    step_fn = jax.jit(make_train_step(cfg, opt, accum_steps=2))

    data = SyntheticTokens(DataConfig(cfg.vocab_size, args.seq, args.batch))
    start = ckpt.latest_step(args.ckpt_dir) or 0
    if start:
        like_p, like_o = init_train_state(cfg, opt, jax.random.PRNGKey(0))
        state, _ = ckpt.restore(args.ckpt_dir, start,
                                {"params": like_p, "opt": like_o})
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start}")
    else:
        params, opt_state = init_train_state(cfg, opt, jax.random.PRNGKey(0))

    wd = StragglerWatchdog()
    losses = []
    prefetch = Prefetcher(lambda s: jax.tree.map(jnp.asarray, data.batch(s)),
                          start_step=start)
    with PreemptionGuard() as guard:
        t0 = time.time()
        for step, batch in prefetch:
            if step >= args.steps:
                break
            ts = time.time()
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jax.random.PRNGKey(step))
            loss = float(metrics["loss"])
            wd.observe(time.time() - ts)
            losses.append(loss)
            if step % 20 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0):.0f}s)")
            if guard.preempted or (step + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, step + 1,
                          {"params": params, "opt": opt_state})
                if guard.preempted:
                    print("preempted: checkpointed and exiting")
                    break
    prefetch.close()

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'DECREASED ✓' if last < first - 0.1 else 'no clear decrease'})")
    print(f"straggler incidents: {wd.incidents}")


if __name__ == "__main__":
    main()
