"""Architecture config registry.

Each ``<arch>.py`` module defines:

    config()        -> full-size ModelConfig (assignment-exact)
    smoke_config()  -> reduced same-family config for CPU tests
    SKIP            -> dict[shape_name, reason] of inapplicable cells

Use ``get_config(name)`` / ``get_smoke_config(name)`` / ``list_archs()``.
"""

from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, ShapeCell

ARCHS = [
    "qwen1.5-4b",
    "gemma-2b",
    "starcoder2-7b",
    "qwen3-8b",
    "xlstm-1.3b",
    "granite-moe-3b-a800m",
    "mixtral-8x22b",
    "qwen2-vl-7b",
    "whisper-small",
    "zamba2-7b",
]

# canonical ids from the assignment map to module names
ALIASES = {
    "qwen1.5-4b": "qwen1_5_4b",
    "gemma-2b": "gemma_2b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen3-8b": "qwen3_8b",
    "xlstm-1.3b": "xlstm_1_3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-small": "whisper_small",
    "zamba2-7b": "zamba2_7b",
    # paper's own models
    "chatglm-6b": "chatglm_6b",
    "qwen-7b": "qwen_7b",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, **overrides):
    import dataclasses
    cfg = _module(name).config()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke_config(name: str, **overrides):
    import dataclasses
    cfg = _module(name).smoke_config()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def skip_reason(name: str, shape: str) -> str | None:
    return getattr(_module(name), "SKIP", {}).get(shape)


def list_archs() -> list[str]:
    return list(ARCHS)


def list_cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells including skipped ones."""
    return [(a, s) for a in ARCHS for s in SHAPES]
