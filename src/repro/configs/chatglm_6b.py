"""ChatGLM2-6B — the paper's own primary model (EdgeLLM Table II / Fig 11).
28L d4096 32H (MQA kv=2 "multi-query group 2") d_ff=13696 vocab=65024."""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {"long_500k": "pure full attention — quadratic; sub-quadratic required"}


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab_size=65024, head_dim=128,
        activation="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm-6b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=256, head_dim=32,
        activation="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_theta=10000.0, dtype=jnp.float32, remat="none",
    )
