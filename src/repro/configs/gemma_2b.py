"""gemma-2b [dense] — 18L d2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256.  [arXiv:2403.08295; hf]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {"long_500k": "pure full attention — quadratic; sub-quadratic required"}


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", family="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_ff=16384, vocab_size=256000, head_dim=256,
        activation="geglu", norm="rmsnorm",
        rope_theta=10000.0, tie_embeddings=True, embed_scale=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
        d_ff=256, vocab_size=256, head_dim=64,
        activation="geglu", norm="rmsnorm",
        rope_theta=10000.0, tie_embeddings=True, embed_scale=True,
        dtype=jnp.float32, remat="none",
    )
