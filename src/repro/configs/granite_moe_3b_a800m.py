"""granite-moe-3b-a800m [moe] — 32L d1536 24H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE 40 experts top-8.  [hf:ibm-granite; hf]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {"long_500k": "pure full attention — quadratic; sub-quadratic required"}


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab_size=49155, head_dim=64,
        activation="swiglu", norm="rmsnorm",
        rope_theta=10000.0, tie_embeddings=True,
        n_experts=40, top_k=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=256, head_dim=32,
        activation="swiglu", norm="rmsnorm",
        rope_theta=10000.0, tie_embeddings=True,
        n_experts=4, top_k=2, dtype=jnp.float32, remat="none",
    )
