"""mixtral-8x22b [moe] — 56L d6144 48H (GQA kv=8) d_ff=16384/expert,
vocab=32768, 8 experts top-2, SWA.  [arXiv:2401.04088; hf]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {}  # SWA caps the KV window: long_500k runs


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32768, head_dim=128,
        activation="swiglu", norm="rmsnorm",
        rope_theta=1e6, window=4096,
        n_experts=8, top_k=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=256, head_dim=32,
        activation="swiglu", norm="rmsnorm",
        rope_theta=1e6, window=64,
        n_experts=4, top_k=2, dtype=jnp.float32, remat="none",
    )
