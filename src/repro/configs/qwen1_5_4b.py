"""qwen1.5-4b [dense] — 40L d2560 20H (kv=20) d_ff=6912 vocab=151936, QKV bias.
[hf:Qwen/Qwen1.5-0.5B family; hf]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {"long_500k": "pure full attention — quadratic; sub-quadratic required"}


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
        d_ff=6912, vocab_size=151936, head_dim=128,
        activation="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=256, head_dim=32,
        activation="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_theta=1e6, dtype=jnp.float32, remat="none",
    )
