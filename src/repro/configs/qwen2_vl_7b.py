"""qwen2-vl-7b [vlm] — 28L d3584 28H (GQA kv=4) d_ff=18944 vocab=152064,
M-RoPE, dynamic-resolution patch frontend STUBBED (input_specs provides
patch embeddings).  [arXiv:2409.12191; hf]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {"long_500k": "pure full attention — quadratic; sub-quadratic required"}


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064, head_dim=128,
        activation="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_type="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=256, head_dim=32,
        activation="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_type="mrope", mrope_sections=(4, 6, 6), rope_theta=1e6,
        dtype=jnp.float32, remat="none",
    )
