"""qwen3-8b [dense] — 36L d4096 32H (GQA kv=8) d_ff=12288 vocab=151936,
qk_norm.  [hf:Qwen/Qwen3-8B; hf]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {"long_500k": "pure full attention — quadratic; sub-quadratic required"}


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12288, vocab_size=151936, head_dim=128,
        activation="swiglu", norm="rmsnorm", qk_norm=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=256, head_dim=32,
        activation="swiglu", norm="rmsnorm", qk_norm=True,
        rope_theta=1e6, dtype=jnp.float32, remat="none",
    )
