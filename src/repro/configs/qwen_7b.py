"""Qwen-7B — the paper's second model (EdgeLLM §V-A).
32L d4096 32H (kv=32; paper notes 4 shared weight-heads) d_ff=11008
vocab=151936."""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {"long_500k": "pure full attention — quadratic; sub-quadratic required"}


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab_size=151936, head_dim=128,
        activation="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen-7b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=256, head_dim=32,
        activation="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_theta=10000.0, dtype=jnp.float32, remat="none",
    )
