"""starcoder2-7b [dense] — 32L d4608 36H (GQA kv=4) d_ff=18432 vocab=49152,
GQA + RoPE, LayerNorm + plain GELU MLP, biases.  [arXiv:2402.19173; hf]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {"long_500k": "pure full attention — quadratic; sub-quadratic required"}


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
        d_ff=18432, vocab_size=49152, head_dim=128,
        activation="gelu", norm="layernorm", qkv_bias=True,
        rope_theta=1e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke", family="dense",
        n_layers=2, d_model=144, n_heads=4, n_kv_heads=2,
        d_ff=288, vocab_size=256, head_dim=36,
        activation="gelu", norm="layernorm", qkv_bias=True,
        rope_theta=1e5, dtype=jnp.float32, remat="none",
    )
