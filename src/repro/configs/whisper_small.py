"""whisper-small [audio] — 12L enc + 12L dec, d768 12H d_ff=3072
vocab=51865, conv frontend STUBBED (input_specs provides frame embeddings).
[arXiv:2212.04356; unverified]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {"long_500k": "full-attention enc-dec — quadratic; sub-quadratic required"}


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865, head_dim=64,
        activation="gelu", norm="layernorm", rope_type="none",
        n_encoder_layers=12, encoder_frames=1500,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke", family="audio",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=256, head_dim=32,
        activation="gelu", norm="layernorm", rope_type="none",
        n_encoder_layers=2, encoder_frames=32,
        dtype=jnp.float32, remat="none",
    )
