"""xlstm-1.3b [ssm] — 48L d2048 4H, sLSTM + mLSTM blocks (7:1),
vocab=50304.  [arXiv:2405.04517; unverified]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {}  # recurrent state: long_500k runs


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304, head_dim=512,
        norm="rmsnorm", rope_type="none", slstm_every=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b-smoke", family="ssm",
        n_layers=4, d_model=128, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab_size=256, head_dim=64,
        norm="rmsnorm", rope_type="none", slstm_every=2,
        dtype=jnp.float32, remat="none",
    )
