"""zamba2-7b [hybrid] — 81L d3584 32H (kv=32) d_ff=14336 ssm_state=64
vocab=32000, Mamba2 backbone + 2 alternating shared attention blocks every
6 layers.  [arXiv:2411.15242; unverified]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

SKIP = {}  # Mamba2 state is O(1); shared-attn KV shards over data: long_500k runs


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000, head_dim=112,
        activation="swiglu", norm="rmsnorm",
        rope_theta=10000.0,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        shared_attn_every=6, n_shared_blocks=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=5, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=256, head_dim=32,
        activation="swiglu", norm="rmsnorm",
        rope_theta=10000.0,
        ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_conv=4,
        shared_attn_every=2, n_shared_blocks=2,
        dtype=jnp.float32, remat="none",
    )
