"""Atomic directory writes: the temp-then-``os.replace`` pattern, shared.

Both the training checkpointer (``train/checkpoint.py``) and the serving
snapshot store (``serving/snapshot.py``) need the same crash-consistency
guarantee: a directory either appears fully written or not at all, and a
process killed mid-write leaves only a ``<dir>.tmp`` turd that the next
writer clears.  One implementation, used by both.
"""

from __future__ import annotations

import contextlib
import os
import shutil
from typing import Iterator


@contextlib.contextmanager
def atomic_dir(final: str) -> Iterator[str]:
    """Yield a scratch directory; on clean exit, ``os.replace`` it to ``final``.

    The scratch dir is ``<final>.tmp`` — a stale one from a previous killed
    writer is removed first.  On exception the scratch dir is removed and the
    exception propagates; ``final`` is never observed half-written.  If
    ``final`` already exists it is replaced atomically-enough for our single
    writer: the old dir is removed just before the rename (readers pick
    snapshots by scanning for *complete* dirs, so the narrow window where
    ``final`` is absent is already handled by fallback-to-previous).
    """
    final = os.fspath(final)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        yield tmp
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_dir(os.path.dirname(final) or ".")


def _fsync_dir(path: str) -> None:
    """Best-effort fsync of a directory entry (durability of the rename)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)
