"""End-to-end model compiler (EdgeLLM §IV) — the JAX restatement.

Two halves:

1. **quantize_model** — the offline half of the paper's compiler: walk the
   parameter pytree and replace every static weight matrix with its W4A16
   (``QuantizedTensor``) or log-scale-sparse (``SparseQuantizedTensor``)
   packed form, per a *sparse strategy* (the paper's Table II per-layer-kind
   density map).  Dynamically-generated operands (KV caches, activations,
   norms, router, conv, embeddings-as-lookup) stay 16-bit, exactly the
   paper's rule.

2. **CompileCache / buckets** — the online half: the paper compiles
   instruction streams per dynamic token length with a MAX-token static
   address space.  Under JAX, a compiled executable per (shape-bucket) is
   the same contract; ``TokenBuckets`` picks the bucket, and
   ``CompileCache`` memoizes jit executables per (fn, bucket) so serving
   never re-traces mid-flight.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.quant import GROUP_SIZE, QuantizedTensor, quantize
from repro.core.sparsity import (
    BLOCKS_PER_GROUP,
    SparseQuantizedTensor,
    block_sparsify_quantize,
)

# ---------------------------------------------------------------------------
# sparse strategies (paper Table II)
# ---------------------------------------------------------------------------

# layer-kind -> density (1.0 = dense-quantized; None = keep 16-bit)
SPARSE_STRATEGIES: dict[str, dict[str, float]] = {
    # paper Table II, GLM-6B
    "dense": {"qkv": 1.0, "o": 1.0, "h_to_4h": 1.0, "4h_to_h": 1.0,
              "head": 1.0, "other": 1.0},
    "strategy1": {"qkv": 1.0, "o": 0.5, "h_to_4h": 0.5, "4h_to_h": 0.5,
                  "head": 1.0, "other": 1.0},
    "strategy2": {"qkv": 1.0, "o": 0.5, "h_to_4h": 0.25, "4h_to_h": 0.5,
                  "head": 1.0, "other": 1.0},
    "strategy3": {"qkv": 1.0, "o": 0.5, "h_to_4h": 0.25, "4h_to_h": 0.25,
                  "head": 1.0, "other": 1.0},
}

_KIND_BY_NAME = {
    "wq": "qkv", "wk": "qkv", "wv": "qkv", "wo": "o",
    "gate": "h_to_4h", "up": "h_to_4h", "down": "4h_to_h",
    "lm_head": "head",
    "in_proj": "other", "out_proj": "other",
    "up_x": "h_to_4h", "up_z": "h_to_4h",
    "w_gates": "other",
    # r_gates (sLSTM recurrent, block-diagonal, streamed per timestep) is
    # deliberately NOT quantized: it is tiny and sits inside the recurrence
}

_NEVER_QUANTIZE = {
    "embed", "router", "conv_w", "conv_b", "gamma", "beta", "norm",
    "out_norm", "A_log", "D", "dt_bias", "q_norm", "k_norm",
    "w_i", "w_f", "b_i", "b_f", "b_gates", "bq", "bk", "bv",
    "up_bias", "down_bias", "scale",
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _quantize_2d(w, density: float, shard_groups: int | None = None,
                 tile_uniform: bool = False):
    """shard_groups: make (in_features // group_size) divisible by this —
    required when the contraction axis is TP-sharded at serve time (MoE
    experts under shard_map); smaller groups cost a few extra scale bits.

    tile_uniform: rank sparse kept-blocks across ALL output channels (one
    kept set per contraction block) — the layout the fused FFN kernel's
    down-projection gather consumes (see ``kernels/ffn_fused.py``)."""
    in_f, out_f = w.shape
    group = GROUP_SIZE
    if shard_groups:
        for g in (128, 64, 32):
            if in_f % g == 0 and (in_f // g) % shard_groups == 0:
                group = g
                break
    if in_f % group or (density < 1.0 and out_f % 128):
        return w  # not tileable; keep 16-bit
    if density >= 1.0:
        return quantize(w, group_size=group)
    n_blocks = in_f // 128
    if in_f % 128 == 0:
        for m in (BLOCKS_PER_GROUP, 4, 2):
            if n_blocks % m == 0 and round(density * m) >= 1:
                return block_sparsify_quantize(w, density, blocks_per_group=m,
                                               tile_uniform=tile_uniform)
    return quantize(w, group_size=group)


def quantize_model(params: Any, strategy: str | dict = "dense") -> Any:
    """Pytree transform: static weight matrices -> packed INT4 (+sparse).

    Stacked leading dims (layer scan, experts, segments) are vmapped over,
    so a (L, E, d, f) MoE weight becomes a QuantizedTensor whose arrays
    carry (L, E, ...) leading axes — scan/slice compatible.
    """
    dmap = SPARSE_STRATEGIES[strategy] if isinstance(strategy, str) else strategy

    def f(path, leaf):
        names = [str(e.key) for e in path
                 if isinstance(e, jax.tree_util.DictKey)]
        name = _leaf_name(path)
        if name in _NEVER_QUANTIZE or not hasattr(leaf, "dtype"):
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating) or leaf.ndim < 2:
            return leaf
        kind = _KIND_BY_NAME.get(name)
        if kind is None:
            return leaf
        density = dmap.get(kind, 1.0)
        if density is None:
            return leaf

        # MoE expert contractions are TP-sharded at serve time: keep their
        # quant-group count divisible by the model-axis size (16)
        shard_groups = 16 if "moe" in names else None
        # the FFN down projection contracts over d_ff — the axis the fused
        # FFN kernel walks; a tile-uniform kept set lets it skip dropped
        # hidden tiles (and their gate/up weight streams) outright
        fn = functools.partial(_quantize_2d, density=density,
                               shard_groups=shard_groups,
                               tile_uniform=(kind == "4h_to_h"))
        for _ in range(leaf.ndim - 2):
            fn = jax.vmap(fn)
        return fn(leaf)

    return jax.tree_util.tree_map_with_path(f, params)


def quantized_bytes(params: Any) -> int:
    """Total HBM bytes of the packed model (the paper's Table II wt. sums)."""
    total = 0

    def visit(leaf):
        nonlocal total
        if isinstance(leaf, (QuantizedTensor, SparseQuantizedTensor)):
            total += leaf.nbytes_model
        elif hasattr(leaf, "dtype"):
            total += leaf.size * leaf.dtype.itemsize

    jax.tree.map(visit, params,
                 is_leaf=lambda x: isinstance(
                     x, (QuantizedTensor, SparseQuantizedTensor)))
    return total


# ---------------------------------------------------------------------------
# dynamic-token compile cache (paper §IV-B)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TokenBuckets:
    """Power-of-two token-length buckets with a MAX token bound.

    The paper's compiler embeds the token count as a DAG variable evaluated
    at runtime; XLA needs static shapes, so the equivalent contract is
    bucketed padding: 17 operators × B buckets executables instead of 17 × T.
    """

    max_tokens: int
    min_bucket: int = 16

    def bucket(self, n: int) -> int:
        if n > self.max_tokens:
            raise ValueError(f"{n} tokens exceeds MAX {self.max_tokens}")
        b = self.min_bucket
        while b < n:
            b *= 2
        return min(b, self.max_tokens)

    def all_buckets(self) -> list[int]:
        out, b = [], self.min_bucket
        while b < self.max_tokens:
            out.append(b)
            b *= 2
        out.append(self.max_tokens)
        return out


class CompileCache:
    """Memoized jit executables per (name, key) — dynamic compilation.

    Serving uses three key families (the paper's pre-compiled executable
    set from Fig. 9, restated for XLA's static shapes):

    * ``("mixed", W)`` — the mixed prefill/decode tick at chunk-width
      bucket W (``TokenBuckets`` over the engine's chunk size): prompts
      admit through the SAME dispatch that advances decode rows, so there
      is no per-prompt-length prefill family at all;
    * ``("decode", B)`` — the pure-decode tick: one executable per resident
      slot-batch size, shared by every request at every step;
    * ``("insert", B)`` — the slot scatter behind ``insert_request`` /
      ``evict_slot`` (the slot index is a traced operand, so one executable
      covers all B slots); audio engines add one ``("admit", F)`` encoder
      executable per frame count.

    Total serving executables are therefore bounded by
    ``n_chunk_buckets + 2`` per engine regardless of traffic — the JAX
    restatement of the paper's "17 operators x B buckets"
    instruction-stream budget.
    """

    def __init__(self):
        self._cache: dict[tuple, Any] = {}
        self.hits = 0
        self.misses = 0
        self.misses_by_name: dict[str, int] = {}

    def get(self, name: str, bucket: int, build: Callable[[], Any]):
        key = (name, bucket)
        if key not in self._cache:
            self._cache[key] = build()
            self.misses += 1
            self.misses_by_name[name] = self.misses_by_name.get(name, 0) + 1
        else:
            self.hits += 1
        return self._cache[key]

    def keys(self) -> list[tuple]:
        return list(self._cache)

    def __len__(self):
        return len(self._cache)
