"""Unified data format (EdgeLLM §IV-A).

The paper keeps *every* operator's activations in one canonical tensor shape
so that no reshape/transpose is ever needed between operators and every AXI
burst is a contiguous ``T_out × 16 bit`` packet:

    text:   [CH / T_out, token, T_out]
    image:  [CH / T_out, H, W, T_out]
    (+ leading head / batch dims as needed)

On TPU the analogous invariant is: the minor-most axis is the 128-lane axis,
activations are ``[..., token, d_model]`` with ``d_model % 128 == 0``, and
every kernel BlockSpec tiles ``(tokens_block, 128·k)``.  ``T_out = 128`` (the
paper uses the AXI width / 16; we use the VPU lane width).

This module provides the canonical-layout type, the pack/unpack bijections to
the paper's explicit ``[CH/T, token, T]`` form, and the layout check the
op-graph compiler runs between fused steps (the "no data rearrangement"
guarantee, enforced rather than assumed).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

T_OUT = 128  # lane width; the paper's T_out (AXI 2048-bit / FP16)

__all__ = ["T_OUT", "Layout", "to_unified", "from_unified", "check_canonical", "pad_to_lanes"]


@dataclasses.dataclass(frozen=True)
class Layout:
    """Declared layout of an operator's input/output."""

    channels: int                 # CH (model dim)
    t_out: int = T_OUT

    def __post_init__(self):
        if self.channels % self.t_out:
            raise ValueError(
                f"channels {self.channels} not a multiple of T_out {self.t_out}; "
                f"pad with pad_to_lanes() first")

    @property
    def ch_tiles(self) -> int:
        return self.channels // self.t_out


def pad_to_lanes(channels: int, t_out: int = T_OUT) -> int:
    """Smallest multiple of t_out >= channels."""
    return (channels + t_out - 1) // t_out * t_out


def to_unified(x: jax.Array, t_out: int = T_OUT) -> jax.Array:
    """[..., token, CH] -> [..., CH/T, token, T]  (paper Fig. 7 packing)."""
    *lead, tok, ch = x.shape
    if ch % t_out:
        raise ValueError(f"channel dim {ch} not a multiple of {t_out}")
    x = x.reshape(*lead, tok, ch // t_out, t_out)
    perm = list(range(len(lead))) + [len(lead) + 1, len(lead), len(lead) + 2]
    return jnp.transpose(x, perm)


def from_unified(x: jax.Array) -> jax.Array:
    """[..., CH/T, token, T] -> [..., token, CH]."""
    *lead, cht, tok, t = x.shape
    perm = list(range(len(lead))) + [len(lead) + 1, len(lead), len(lead) + 2]
    x = jnp.transpose(x, perm)
    return x.reshape(*lead, tok, cht * t)


def check_canonical(x: jax.Array | jax.ShapeDtypeStruct, t_out: int = T_OUT) -> None:
    """Raise if an activation violates the canonical layout contract.

    Canonical = minor-most axis is the channel axis and is 128-aligned.  The
    op-graph compiler calls this at every fused-step boundary, which is how
    the "no rearrangement between operators" property is *checked* rather
    than hoped for.
    """
    if x.ndim < 2:
        raise ValueError(f"activation must be >=2D, got shape {x.shape}")
    if x.shape[-1] % t_out:
        raise ValueError(
            f"minor-most axis {x.shape[-1]} not {t_out}-aligned (shape {x.shape}); "
            "an operator emitted a non-canonical layout")
