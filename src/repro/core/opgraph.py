"""Operator-graph IR: the paper's fused block schedule (EdgeLLM Fig. 6).

The paper's compiler fuses one ChatGLM block into 17 hardware steps, each an
operator with a fixed engine binding (HBM-fed MatMUL / MHA vs DDR-fed
"other" ops) and the unified ``[CH/T_out, token, T_out]`` layout at every
edge.  This module reproduces that artifact as a first-class IR:

* ``OpNode`` — operator with kind, engine binding, byte/FLOP cost model;
* ``block_graph(cfg)`` — the fused step list for one decoder block of any
  configured architecture (the GLM-6B instance reproduces the paper's 17
  steps + the 2 epilogue steps of Table III exactly — pinned in tests);
* layout checking at every edge (``core.layout.check_canonical``) — the
  "no data rearrangement between operators" property is enforced;
* per-step latency model under a given memory system (HBM vs DDR
  bandwidth), which is what benchmarks/table3 uses to reproduce the paper's
  HBM-vs-DDR comparison.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core.layout import T_OUT

HBM = "hbm"    # weight/KV streaming engines (MatMUL, MHA)
DDR = "ddr"    # activation-only operators (norms, softmax, rotary, ...)


@dataclasses.dataclass(frozen=True)
class OpNode:
    name: str
    kind: str                     # vmm | mha | norm | softmax | rope | act |
                                  # cache_write | transpose | elementwise
    engine: str                   # HBM | DDR
    weight_bytes: int = 0         # streamed per call (packed int4 + scales)
    act_in_bytes: int = 0
    act_out_bytes: int = 0
    flops: int = 0

    def ideal_time_s(self, *, hbm_bw: float, ddr_bw: float,
                     compute_flops: float) -> float:
        """Paper §V-B latency model: max(stream time, compute time); weights
        stream from HBM, activations from DDR."""
        t_w = self.weight_bytes / hbm_bw if self.weight_bytes else 0.0
        t_a = (self.act_in_bytes + self.act_out_bytes) / ddr_bw
        t_c = self.flops / compute_flops if self.flops else 0.0
        return max(t_w + t_a, t_c)


def _vmm(name, tokens, d_in, d_out, dtype_bytes=2, wt_bits=4.125,
         engine=HBM) -> OpNode:
    """VMM-BN step: block-quantized weight stream + activation in/out."""
    return OpNode(
        name=name, kind="vmm", engine=engine,
        weight_bytes=int(d_in * d_out * wt_bits / 8),
        act_in_bytes=tokens * d_in * dtype_bytes,
        act_out_bytes=tokens * d_out * dtype_bytes,
        flops=2 * tokens * d_in * d_out,
    )


def _simple(name, kind, tokens, d, dtype_bytes=2, flops_per_elem=4) -> OpNode:
    return OpNode(
        name=name, kind=kind, engine=DDR,
        act_in_bytes=tokens * d * dtype_bytes,
        act_out_bytes=tokens * d * dtype_bytes,
        flops=flops_per_elem * tokens * d,
    )


def block_graph(cfg, *, tokens: int = 1, context: int = 128,
                wt_bits: float = 4.125) -> list[OpNode]:
    """The fused per-block schedule (paper Fig. 6 / Table III steps 1-17).

    ``tokens`` = new tokens this pass (1 for decode), ``context`` = KV length.
    """
    d = cfg.d_model
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    dtype_bytes = 2
    f = cfg.d_ff

    kv_bytes = context * hkv * hd * dtype_bytes
    steps = [
        _simple("step1:LayerNorm", "norm", tokens, d),
        _vmm("step2:VMM-BN(Q)", tokens, d, hq * hd, wt_bits=wt_bits),
        _simple("step3:PosEmb(Q)", "rope", tokens, hq * hd),
        _vmm("step4:VMM-BN(K)", tokens, d, hkv * hd, wt_bits=wt_bits),
        _simple("step5:PosEmb(K)", "rope", tokens, hkv * hd),
        OpNode("step6:KcacheHBM", "cache_write", HBM,
               act_in_bytes=tokens * hkv * hd * dtype_bytes),
        OpNode("step7:VMM(Q*K^T)", "mha", HBM,
               weight_bytes=kv_bytes,  # K stream plays the weight role
               act_in_bytes=tokens * hq * hd * dtype_bytes,
               act_out_bytes=tokens * hq * context * dtype_bytes,
               flops=2 * tokens * hq * hd * context),
        _simple("step8:Softmax", "softmax", tokens, hq * context,
                flops_per_elem=6),
        _vmm("step9:VMM-BN(V)", tokens, d, hkv * hd, wt_bits=wt_bits),
        OpNode("step10:VcacheHBM", "cache_write", HBM,
               act_in_bytes=tokens * hkv * hd * dtype_bytes),
        OpNode("step11:VMM(SFT*V)", "mha", HBM,
               weight_bytes=kv_bytes,
               act_in_bytes=tokens * hq * context * dtype_bytes,
               act_out_bytes=tokens * hq * hd * dtype_bytes,
               flops=2 * tokens * hq * hd * context),
        _vmm("step12:VMM-BN-RES(O)", tokens, hq * hd, d, wt_bits=wt_bits),
        _simple("step13:LayerNorm", "norm", tokens, d),
        _vmm("step14:VMM-BN(h->4h)", tokens, d,
             2 * f if cfg.activation in ("swiglu", "geglu") else f,
             wt_bits=wt_bits),
        _simple("step15:Act(Swiglu)", "act", tokens, f),
        _vmm("step16:VMM-BN-Res(4h->h)", tokens, f, d, wt_bits=wt_bits),
        # step17 in the paper is the residual-fused output VMM of the block
        _simple("step17:Residual", "elementwise", tokens, d, flops_per_elem=1),
    ]
    return steps


def epilogue_graph(cfg, tokens: int = 1, wt_bits: float = 4.125) -> list[OpNode]:
    """Steps 18-19 (Table III): final norm + LM head on the LAST token only
    (the paper's last-token optimization, §IV-B)."""
    return [
        _simple("step18:Outlayer_LN", "norm", 1, cfg.d_model),
        _vmm("step19:VMMBN_Arg", 1, cfg.d_model, cfg.vocab_size,
             wt_bits=wt_bits),
    ]


def model_graph(cfg, *, tokens: int = 1, context: int = 128,
                wt_bits: float = 4.125) -> list[OpNode]:
    g: list[OpNode] = []
    for layer in range(cfg.n_layers):
        g.extend(block_graph(cfg, tokens=tokens, context=context,
                             wt_bits=wt_bits))
    g.extend(epilogue_graph(cfg, tokens=tokens, wt_bits=wt_bits))
    return g


def total_time_s(graph: Iterable[OpNode], *, hbm_bw: float = 460e9,
                 ddr_bw: float = 60e9, compute_flops: float = 1.147e12
                 ) -> float:
    """Temporal execution (paper: "one operator starts only after the
    previous one has finished"); defaults = VCU128 (460 GB/s HBM, 8192 MACs
    @ 280 MHz x2 = 1.147 TFLOP/s)."""
    return sum(op.ideal_time_s(hbm_bw=hbm_bw, ddr_bw=ddr_bw,
                               compute_flops=compute_flops) for op in graph)


def check_layouts(cfg) -> None:
    """Every operator edge must carry the canonical layout (d % 128 == 0
    after padding) — the paper's universal-format contract."""
    from repro.core.layout import pad_to_lanes
    dims = [cfg.d_model, cfg.n_heads * cfg.head_dim,
            cfg.n_kv_heads * cfg.head_dim]
    if cfg.d_ff:
        dims.append(cfg.d_ff)
    for dim in dims:
        padded = pad_to_lanes(dim)
        if padded != dim:
            raise ValueError(
                f"{cfg.name}: edge dim {dim} not {T_OUT}-aligned; pad to "
                f"{padded} in the op-graph (paper Fig. 7 padding rule)")
