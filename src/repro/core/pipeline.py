"""Instruction-pipeline latency hiding (EdgeLLM Fig. 9).

The paper's accelerator pre-loads the next serialized instruction block
while the current one executes, so host-side instruction updates cost ~zero
after the first inference.  The JAX analogue has two layers:

* **device side** — JAX async dispatch already queues the next jitted step
  while the previous executes; ``PipelinedRunner`` exploits it by preparing
  and dispatching step k+1 *before* blocking on step k's results, and
  measures the achieved overlap (tests assert host-work is actually hidden);
* **host side** — ``InstructionStream`` mirrors the paper's double-buffered
  register file: a bounded deque of pre-built step closures (the
  "serialized operator instructions"), refilled by a background thread from
  the compiler, drained by the runner.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Iterable

import jax


class InstructionStream:
    """Double-buffered queue of prepared step closures."""

    def __init__(self, build: Callable[[int], Callable[[], Any]],
                 depth: int = 2):
        self._build = build
        self._buf: collections.deque = collections.deque()
        self._depth = depth
        self._next = 0
        self._lock = threading.Lock()
        self.prepared = 0
        self.fill()

    def fill(self) -> None:
        with self._lock:
            while len(self._buf) < self._depth:
                self._buf.append(self._build(self._next))
                self._next += 1
                self.prepared += 1

    def pop(self) -> Callable[[], Any]:
        with self._lock:
            instr = self._buf.popleft()
        self.fill()
        return instr


class PipelinedRunner:
    """Dispatch-ahead step runner with overlap accounting.

    ``host_work(step)`` models the per-step host preparation the paper hides
    (dynamic instruction updates); ``device_step`` is the jitted function.
    With ``pipelined=True`` the host work for step k+1 runs while the device
    executes step k (async dispatch); with False everything serializes —
    the delta is the measured Fig. 9 win.
    """

    def __init__(self, device_step: Callable, host_work: Callable[[int], Any],
                 *, pipelined: bool = True):
        self.device_step = device_step
        self.host_work = host_work
        self.pipelined = pipelined
        self.host_time = 0.0
        self.wall_time = 0.0

    def run(self, state: Any, steps: int) -> Any:
        t_start = time.monotonic()
        if not self.pipelined:
            for k in range(steps):
                t0 = time.monotonic()
                args = self.host_work(k)
                self.host_time += time.monotonic() - t0
                state = self.device_step(state, args)
                state = jax.block_until_ready(state)   # serialize
        else:
            # dispatch step k, prepare k+1 while the device is busy, only
            # then block on k's completion
            t0 = time.monotonic()
            args = self.host_work(0)
            self.host_time += time.monotonic() - t0
            for k in range(steps):
                state = self.device_step(state, args)  # async dispatch
                if k + 1 < steps:
                    t0 = time.monotonic()
                    args = self.host_work(k + 1)       # hidden behind device
                    self.host_time += time.monotonic() - t0
            state = jax.block_until_ready(state)
        self.wall_time = time.monotonic() - t_start
        return state
