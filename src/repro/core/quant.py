"""Block-level INT4 weight quantization (EdgeLLM §III-B / §III-C).

The paper quantizes every static weight matrix to symmetric INT4 where 128
adjacent input-channel parameters share one FP16 scale ("block-level
quantization", group_size=128).  Activations stay in 16-bit float; the
accelerator multiplies FP16 activations against INT4 weights and rescales by
the block scale (the "Scale value" multiplier in Fig. 4 Stage-3).

This module is the pure-JAX substrate used by both the XLA execution path and
the Pallas kernels:

* ``quantize`` / ``dequantize``      – round-trip with per-group scales
* ``QuantizedTensor``                – pytree carrying packed nibbles + scales
* nibble packing uses the *sublane-pair* scheme: within each 128-row group the
  uint8 at row r holds the nibbles of rows ``r`` (low) and ``r + 64`` (high).
  Unpacking in a kernel is therefore one mask, one shift and one sublane
  concatenate - no interleaving reshuffle (TPU adaptation note in DESIGN.md).

Weight convention throughout the repo: ``w`` has shape ``(in_features,
out_features)`` and quantization groups run along the **contraction** axis
(``in_features``), exactly like the paper's CH_in groups.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

GROUP_SIZE = 128          # paper: 128 adjacent params share one scale
_HALF = GROUP_SIZE // 2   # 64: nibble-pair offset inside a group

__all__ = [
    "GROUP_SIZE",
    "QuantizedTensor",
    "quantize",
    "dequantize",
    "pack_int4",
    "unpack_int4",
    "quantization_error",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Block-quantized INT4 weight.

    Attributes:
      packed:  uint8 ``(in_features // 2, out_features)`` - two int4 nibbles
               per byte, sublane-pair packing within each 128-row group.
      scales:  ``(in_features // group_size, out_features)`` scale per group
               per output channel (paper stores FP16; we default bf16 and
               upcast to f32 at use).
      shape:   original ``(in_features, out_features)``.
      group_size: contraction-axis group length (128).
    """

    packed: jax.Array
    scales: jax.Array
    shape: tuple[int, int]
    group_size: int = GROUP_SIZE

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.packed, self.scales), (self.shape, self.group_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scales = children
        shape, group_size = aux
        return cls(packed=packed, scales=scales, shape=shape, group_size=group_size)

    # -- conveniences -------------------------------------------------------
    @property
    def in_features(self) -> int:
        return self.shape[0]

    @property
    def out_features(self) -> int:
        return self.shape[1]

    @property
    def nbytes_model(self) -> int:
        """HBM bytes this tensor streams per full read (packed + scales)."""
        scale_bytes = int(np.prod(self.scales.shape)) * self.scales.dtype.itemsize
        return int(np.prod(self.packed.shape)) + scale_bytes

    def dequantize(self, dtype=jnp.bfloat16) -> jax.Array:
        return dequantize(self, dtype=dtype)


def pack_int4(q: jax.Array, group_size: int = GROUP_SIZE) -> jax.Array:
    """Pack int4 values (int8 storage, range [-8, 7]) into uint8 nibbles.

    ``q`` is ``(in, out)``; rows r and r+64 of each 128-row group share a byte
    (low nibble = r, high nibble = r+64) so a kernel can unpack with a single
    sublane concat.
    """
    in_f, out_f = q.shape
    if in_f % group_size:
        raise ValueError(f"in_features {in_f} not a multiple of {group_size}")
    half = group_size // 2
    g = q.reshape(in_f // group_size, group_size, out_f)
    lo = g[:, :half, :]          # rows [0, 64)
    hi = g[:, half:, :]          # rows [64, 128)
    lo_u = jnp.asarray(lo, jnp.uint8) & 0xF
    hi_u = jnp.asarray(hi, jnp.uint8) & 0xF
    packed = lo_u | (hi_u << 4)
    return packed.reshape(in_f // 2, out_f)


def unpack_int4(packed: jax.Array, group_size: int = GROUP_SIZE) -> jax.Array:
    """Inverse of :func:`pack_int4`; returns int8 values in [-8, 7]."""
    in_half, out_f = packed.shape
    half = group_size // 2
    g = packed.reshape(in_half // half, half, out_f)
    lo = (g & 0xF).astype(jnp.int8)
    hi = (g >> 4).astype(jnp.int8)
    # sign-extend 4-bit two's complement
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    full = jnp.concatenate([lo, hi], axis=1)  # (groups, 128, out)
    return full.reshape(in_half * 2, out_f)


def quantize(
    w: jax.Array,
    group_size: int = GROUP_SIZE,
    scale_dtype=jnp.bfloat16,
) -> QuantizedTensor:
    """Symmetric block-level INT4 quantization along the contraction axis."""
    in_f, out_f = w.shape
    if in_f % group_size:
        raise ValueError(f"in_features {in_f} not a multiple of {group_size}")
    wf = jnp.asarray(w, jnp.float32)
    g = wf.reshape(in_f // group_size, group_size, out_f)
    absmax = jnp.max(jnp.abs(g), axis=1)                       # (groups, out)
    scale = jnp.maximum(absmax / 7.0, 1e-10)
    q = jnp.clip(jnp.round(g / scale[:, None, :]), -8, 7).astype(jnp.int8)
    packed = pack_int4(q.reshape(in_f, out_f), group_size)
    return QuantizedTensor(
        packed=packed,
        scales=scale.astype(scale_dtype),
        shape=(in_f, out_f),
        group_size=group_size,
    )


def dequantize(qt: QuantizedTensor, dtype=jnp.bfloat16) -> jax.Array:
    q = unpack_int4(qt.packed, qt.group_size).astype(jnp.float32)
    in_f, out_f = qt.shape
    g = q.reshape(in_f // qt.group_size, qt.group_size, out_f)
    w = g * qt.scales.astype(jnp.float32)[:, None, :]
    return w.reshape(in_f, out_f).astype(dtype)


def quantization_error(w: jax.Array, qt: QuantizedTensor) -> dict[str, Any]:
    """Relative error metrics of the round-trip (paper Table-I methodology)."""
    wf = jnp.asarray(w, jnp.float32)
    wq = dequantize(qt, jnp.float32)
    err = jnp.abs(wf - wq)
    denom = jnp.maximum(jnp.abs(wf), 1e-8)
    return {
        "max_abs": float(jnp.max(err)),
        "mean_rel": float(jnp.mean(err / denom)),
        "rms": float(jnp.sqrt(jnp.mean(err**2))),
    }
