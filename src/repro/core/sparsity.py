"""Log-scale structured weight sparsity (EdgeLLM §III-C, Fig. 5, Table II).

The paper's scheme, faithfully:

* weights are already block-quantized INT4 (128-channel groups, one FP16
  scale per group — see :mod:`repro.core.quant`);
* sparsity is *density-bound-block* (DBB) structured: within every group of
  ``M = 8`` adjacent weights along the input-channel axis, at most ``k``
  are non-zero, with **log-scale densities** k/M ∈ {1, 1/2, 1/4, 1/8}
  (sparsity 0 / 50 / 75 / 87.5 %);
* non-zero positions are encoded either *one-hot* (M mask bits per group —
  cheap at low sparsity) or *address-in-block* (one index per non-zero —
  cheap at high sparsity); the hybrid choice minimizes HBM traffic;
* because k and M are powers of two the FPGA's time-unrolled PEs stay 100 %
  utilized at every sparsity level, and — unlike GPU 2:4 — the *memory*
  traffic shrinks with sparsity.  Effective bit-widths: 4.125 / 3.125 /
  1.875 / 1.125 bits → performance enhancement 1 / 1.32 / 2.2 / 3.67×.

TPU adaptation (DESIGN.md §2): element-wise gathers are hostile to the MXU,
so the *execution* granularity is raised from single weights to 128-channel
blocks shared across a 128-wide output tile — "our sparse blocks are larger"
taken to MXU scale, keeping each surviving grid step a fully dense 128×128
matmul (the same 100 %-utilization argument as the paper's power-of-two
schedule).  The element-wise N:M masks remain available here for the
algorithm-fidelity path (accuracy benchmarks, Table II reproduction), and the
packing cost model reproduces the paper's Fig. 5 byte counts exactly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import GROUP_SIZE, QuantizedTensor, pack_int4, quantize

BLOCKS_PER_GROUP = 8      # paper: "every group of eight adjacent data blocks"
LOG_SCALE_DENSITIES = (1.0, 0.5, 0.25, 0.125)

__all__ = [
    "BLOCKS_PER_GROUP",
    "LOG_SCALE_DENSITIES",
    "PackingCost",
    "SparseQuantizedTensor",
    "packing_cost",
    "effective_bitwidth",
    "enhancement_ratio",
    "nm_magnitude_mask",
    "apply_nm_sparsity",
    "block_importance",
    "block_sparsify_quantize",
    "sparse_dequantize",
]


# ---------------------------------------------------------------------------
# Packing cost model (Fig. 5 reproduction)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackingCost:
    """Bit cost of one 2048-CH_in weight package (per output channel)."""

    density: float
    encoding: str               # "dense" | "one-hot" | "addr-in-block"
    scale_bits: int
    mask_bits: int
    wt_bits: int

    @property
    def total_bits(self) -> int:
        return self.scale_bits + self.mask_bits + self.wt_bits

    def effective_bitwidth(self, channels: int = 2048) -> float:
        return self.total_bits / channels


def packing_cost(
    density: float,
    encoding: str = "auto",
    channels: int = 2048,
    m: int = BLOCKS_PER_GROUP,
    wt_bits_per_weight: int = 4,
    group_size: int = GROUP_SIZE,
    scale_bits_per_group: int = 16,
    addr_bits: int | None = None,
) -> PackingCost:
    """Bit cost of a weight package under the paper's packing (Fig. 5).

    ``encoding="auto"`` picks the cheaper of one-hot / address-in-block —
    the paper's hybrid scheme.  ``addr_bits`` defaults to the paper's own
    (slightly irregular) choices: nibble-aligned 4-bit indices, except the
    75 % case where the paper uses the minimal 3-bit index (ceil(log2 8)).
    """
    if channels % group_size:
        raise ValueError("channels must be a multiple of the quant group")
    scale_bits = (channels // group_size) * scale_bits_per_group
    n_nonzero = int(round(channels * density))
    if density >= 1.0:
        return PackingCost(density, "dense", scale_bits, 0, channels * wt_bits_per_weight)

    wt_bits = n_nonzero * wt_bits_per_weight
    one_hot_mask = channels  # 1 bit per position
    if addr_bits is None:
        min_bits = max(1, math.ceil(math.log2(m)))
        # Paper quirk: 4-bit (nibble-aligned) addresses at 50 % and 87.5 %,
        # minimal 3-bit addresses at 75 % (Fig. 5 table).  Reproduced so the
        # published effective bit-widths fall out exactly.
        addr_bits = min_bits if math.isclose(density, 0.25) else max(4, min_bits)
    addr_mask = n_nonzero * addr_bits

    if encoding == "one-hot":
        mask_bits = one_hot_mask
    elif encoding == "addr-in-block":
        mask_bits = addr_mask
    elif encoding == "auto":
        if addr_mask < one_hot_mask:
            encoding, mask_bits = "addr-in-block", addr_mask
        else:
            encoding, mask_bits = "one-hot", one_hot_mask
    else:
        raise ValueError(f"unknown encoding {encoding!r}")
    return PackingCost(density, encoding, scale_bits, mask_bits, wt_bits)


def effective_bitwidth(density: float, encoding: str = "auto") -> float:
    return packing_cost(density, encoding).effective_bitwidth()


def enhancement_ratio(density: float, encoding: str = "auto") -> float:
    """Memory-traffic speedup over the dense INT4 package (Fig. 5 bottom row)."""
    dense = packing_cost(1.0).total_bits
    return dense / packing_cost(density, encoding).total_bits


# ---------------------------------------------------------------------------
# Paper-faithful element-wise N:M masks (algorithm-fidelity path)
# ---------------------------------------------------------------------------

def nm_magnitude_mask(w: jax.Array, density: float, m: int = BLOCKS_PER_GROUP) -> jax.Array:
    """Boolean keep-mask: top-k-of-m by magnitude along the input axis.

    ``w`` is ``(in, out)``; every run of ``m`` adjacent input channels keeps
    the ``k = density * m`` largest-magnitude weights (per output channel),
    the paper's k-of-8 DBB rule.
    """
    in_f, out_f = w.shape
    k = int(round(density * m))
    if not (1 <= k <= m):
        raise ValueError(f"density {density} gives k={k} outside [1, {m}]")
    if in_f % m:
        raise ValueError(f"in_features {in_f} not a multiple of m={m}")
    if k == m:
        return jnp.ones_like(w, dtype=bool)
    g = jnp.abs(jnp.asarray(w, jnp.float32)).reshape(in_f // m, m, out_f)
    # rank within each group: keep the k largest
    order = jnp.argsort(jnp.argsort(-g, axis=1), axis=1)  # rank, 0 = largest
    mask = order < k
    return mask.reshape(in_f, out_f)


def apply_nm_sparsity(w: jax.Array, density: float, m: int = BLOCKS_PER_GROUP) -> jax.Array:
    return jnp.where(nm_magnitude_mask(w, density, m), w, 0)


# ---------------------------------------------------------------------------
# TPU-granular block sparsity + kernel-facing container
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseQuantizedTensor:
    """Block-sparse block-quantized weight, laid out for the Pallas kernel.

    The contraction axis is cut into 128-channel blocks; every group of 8
    adjacent blocks keeps ``k`` (density k/8), and the kept set is shared
    across a 128-wide output tile.  Layout (S = n_groups * k kept blocks):

      packed:    uint8   (out_tiles, S, 64, 128)   nibble-packed kept blocks
      scales:    (out_tiles, S, 128)               per kept block, per out ch
      block_idx: int32   (out_tiles, S)            absolute kept block index,
                                                   ascending - this IS the
                                                   paper's address-in-block
                                                   encoding at block scale

    ``tile_uniform`` (static metadata) marks a tensor whose kept set is the
    SAME for every out tile (every ``block_idx`` row identical) — required
    by the fused FFN kernel's down-projection gather, which visits kept
    f-blocks once for ALL output channels.  Such a tensor only really needs
    one index row (the nbytes model keeps the shared layout for simplicity).
    """

    packed: jax.Array
    scales: jax.Array
    block_idx: jax.Array
    shape: tuple[int, int]
    density: float
    group_size: int = GROUP_SIZE
    tile_uniform: bool = False

    def tree_flatten(self):
        return (self.packed, self.scales, self.block_idx), (
            self.shape, self.density, self.group_size, self.tile_uniform)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scales, block_idx = children
        shape, density, group_size, tile_uniform = aux
        return cls(packed, scales, block_idx, shape, density, group_size,
                   tile_uniform)

    @property
    def in_features(self) -> int:
        return self.shape[0]

    @property
    def out_features(self) -> int:
        return self.shape[1]

    @property
    def kept_blocks(self) -> int:
        return self.packed.shape[1]

    @property
    def nbytes_model(self) -> int:
        """HBM bytes per full stream: packed + scales + indices (the paper's
        scale/mask/wt triple at block granularity)."""
        return (
            int(np.prod(self.packed.shape))
            + int(np.prod(self.scales.shape)) * self.scales.dtype.itemsize
            + int(np.prod(self.block_idx.shape)) * self.block_idx.dtype.itemsize
        )


def block_importance(w: jax.Array, block: int = GROUP_SIZE, out_tile: int = GROUP_SIZE) -> jax.Array:
    """L1 importance of each (128-in-block, 128-out-tile) weight block."""
    in_f, out_f = w.shape
    g = jnp.abs(jnp.asarray(w, jnp.float32)).reshape(
        in_f // block, block, out_f // out_tile, out_tile)
    return g.sum(axis=(1, 3))  # (in_blocks, out_tiles)


def block_sparsify_quantize(
    w: jax.Array,
    density: float,
    blocks_per_group: int = BLOCKS_PER_GROUP,
    scale_dtype=jnp.bfloat16,
    tile_uniform: bool = False,
) -> SparseQuantizedTensor:
    """Magnitude-prune to log-scale block sparsity, then block-quantize.

    Keeps the top ``k = density * 8`` blocks (by L1 mass) out of every 8
    adjacent 128-channel blocks, per 128-wide output tile, then quantizes the
    survivors with per-block scales.

    ``tile_uniform=True`` ranks block importance summed across ALL out tiles
    so every tile keeps the same blocks — slightly coarser selection, but the
    kept set becomes a property of the contraction axis alone, which is what
    lets the fused FFN kernel skip whole hidden tiles the down projection
    dropped (and their gate/up weight streams with them).
    """
    in_f, out_f = w.shape
    block = GROUP_SIZE
    k = int(round(density * blocks_per_group))
    if not (1 <= k <= blocks_per_group):
        raise ValueError(f"density {density} -> k={k} invalid")
    n_blocks = in_f // block
    if in_f % block or out_f % block:
        raise ValueError("in/out features must be multiples of 128")
    if n_blocks % blocks_per_group:
        raise ValueError(
            f"{n_blocks} blocks not a multiple of group {blocks_per_group}")
    n_groups = n_blocks // blocks_per_group
    out_tiles = out_f // block

    imp = block_importance(w)                       # (n_blocks, out_tiles)
    if tile_uniform:
        imp = jnp.broadcast_to(imp.sum(axis=1, keepdims=True), imp.shape)
    imp_g = imp.reshape(n_groups, blocks_per_group, out_tiles)
    # top-k blocks per group, ascending absolute index per out tile
    order = jnp.argsort(-imp_g, axis=1)[:, :k, :]   # (n_groups, k, out_tiles)
    local = jnp.sort(order, axis=1)
    base = (jnp.arange(n_groups) * blocks_per_group)[:, None, None]
    abs_idx = (local + base).reshape(n_groups * k, out_tiles)
    block_idx = abs_idx.T.astype(jnp.int32)          # (out_tiles, S)

    # quantize the full matrix once, then gather kept blocks per out tile
    qt = quantize(w, group_size=block, scale_dtype=scale_dtype)
    wq_packed = qt.packed.reshape(n_blocks, block // 2, out_tiles, block)
    scales = qt.scales.reshape(n_blocks, out_tiles, block)

    def take(tile: jax.Array, idx: jax.Array):
        # tile-wise gather of kept blocks
        return tile[idx]

    # vmap over out tiles
    packed_t = jnp.transpose(wq_packed, (2, 0, 1, 3))   # (out_tiles, n_blocks, 64, 128)
    scales_t = jnp.transpose(scales, (1, 0, 2))          # (out_tiles, n_blocks, 128)
    packed_kept = jax.vmap(take)(packed_t, block_idx)    # (out_tiles, S, 64, 128)
    scales_kept = jax.vmap(take)(scales_t, block_idx)    # (out_tiles, S, 128)

    return SparseQuantizedTensor(
        packed=packed_kept,
        scales=scales_kept,
        block_idx=block_idx,
        shape=(in_f, out_f),
        density=float(density),
        tile_uniform=tile_uniform,
    )


def sparse_dequantize(st: SparseQuantizedTensor, dtype=jnp.bfloat16) -> jax.Array:
    """Scatter the kept blocks back into a dense (in, out) weight matrix."""
    in_f, out_f = st.shape
    block = GROUP_SIZE
    n_blocks = in_f // block
    out_tiles = out_f // block
    half = block // 2

    # unpack nibbles: packed (out_tiles, S, 64, 128) -> values (out_tiles, S, 128, 128)
    lo = (st.packed & 0xF).astype(jnp.int8)
    hi = (st.packed >> 4).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    vals = jnp.concatenate([lo, hi], axis=2).astype(jnp.float32)  # (T, S, 128, 128)
    vals = vals * st.scales.astype(jnp.float32)[:, :, None, :]

    dense = jnp.zeros((out_tiles, n_blocks, block, block), jnp.float32)
    tile_ids = jnp.arange(out_tiles)[:, None]
    dense = dense.at[tile_ids, st.block_idx].set(vals)
    # (out_tiles, n_blocks, 128in, 128out) -> (in, out)
    dense = jnp.transpose(dense, (1, 2, 0, 3)).reshape(in_f, out_f)
    return dense.astype(dtype)
