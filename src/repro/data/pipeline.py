"""Deterministic synthetic token pipeline (sharding-aware, prefetching).

No external datasets ship with the container, so the pipeline synthesizes
token streams from a seeded generator — but with the *production* plumbing a
real loader needs:

* deterministic resume: batches are a pure function of (seed, step), so a
  restored checkpoint replays the exact stream (fault-tolerance invariant,
  tested);
* shard-awareness: each data-parallel host materializes only its slice
  (``host_slice``) — the global batch never exists on one host;
* double-buffered prefetch: the next batch is generated while the device
  step runs (the host-side analogue of the paper's Fig. 9 latency hiding);
* a mixture of Zipf-distributed "natural" tokens and repeated n-gram
  motifs, so language-model loss actually decreases during the examples'
  training runs (pure-uniform tokens give a flat loss — useless for
  validating the optimizer path).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.5


class SyntheticTokens:
    """Stateless batch generator: batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed motif table — shared structure the model can learn
        rng = np.random.default_rng(cfg.seed)
        self._motifs = rng.integers(
            0, cfg.vocab_size, (256, cfg.motif_len), dtype=np.int32)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        b, s = cfg.global_batch, cfg.seq_len
        # Zipf body
        z = rng.zipf(cfg.zipf_a, size=(b, s + 1)).astype(np.int64)
        tokens = (z % cfg.vocab_size).astype(np.int32)
        # splice motifs (learnable repeated structure)
        n_splices = int(cfg.motif_prob * b * (s // cfg.motif_len) / 2)
        if n_splices:
            rows = rng.integers(0, b, n_splices)
            cols = rng.integers(0, s + 1 - cfg.motif_len, n_splices)
            ids = rng.integers(0, len(self._motifs), n_splices)
            for r, c, i in zip(rows, cols, ids):
                tokens[r, c:c + cfg.motif_len] = self._motifs[i]
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def host_slice(self, step: int, host_id: int, n_hosts: int) -> dict:
        full = self.batch(step)
        b = self.cfg.global_batch
        lo = host_id * b // n_hosts
        hi = (host_id + 1) * b // n_hosts
        return {k: v[lo:hi] for k, v in full.items()}


class Prefetcher:
    """Background-thread double buffering around any step->batch function."""

    def __init__(self, fetch, start_step: int = 0, depth: int = 2):
        self._fetch = fetch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._fetch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, Any]]:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
