"""Pallas TPU kernel: length-aware batched flash-decoding with fused int8-KV
dequant (EdgeLLM §IV-B static MAX-token addressing + Fig. 4 mixed-precision
datapath, applied to the decode hot path).

One-token decode against a preallocated ``(B, hkv, MAX, d)`` cache is the
memory-bound half of serving: every step streams the KV cache once and does
O(1) FLOPs per byte.  The paper wins its HBM-bandwidth-utilization metric by
(a) never touching addresses past the valid context and (b) keeping the
quantized operand packed all the way into the PE array, rescaling partial
sums afterwards.  This kernel is the TPU restatement of both:

* **Grid** ``(B, hkv, MAX/bk)`` with the KV-block axis innermost
  ("arbitrary").  ``lengths: (B,)`` and ``q_lens: (B,)`` ride in as
  scalar-prefetch operands (SMEM), so both the kernel body and the BlockSpec
  index maps can read them.

* **Mixed q-block.**  The query block packs ``q_lens[b]`` live queries per
  row (1 for a decoding row, C for a row mid-prefill), so one fixed
  executable advances a mixed prefill/decode batch — the paper's "one data
  shape for every operator" contract (§IV universal data parallelism)
  applied to the serving tick.  Query j of row b sits at absolute position
  ``lengths[b] - q_lens[b] + j``; intra-chunk causality is a per-position
  mask, and dead queries (j >= q_lens[b]) end with ``l == 0`` -> zeros.

* **Per-row block skipping.**  Blocks at or past row ``b``'s valid context
  are (1) skipped in compute via ``pl.when`` and (2) *elided in the DMA*:
  the K/V index maps clamp the block index into the row's live range, and
  Mosaic's pipeline skips the copy when consecutive grid steps map the same
  block.  Compute AND bytes scale with ``ceil(length_b / bk)`` instead of
  ``MAX/bk`` — the paper's "only the valid tokens travel" contract.

* **GQA via query-group packing.**  The ``rep = hq/hkv`` query heads that
  share one KV head are packed (together with the chunk axis) into a single
  ``(rep*C, d)`` q block, so each KV byte is read once per *group*, never
  ``jnp.repeat``-ed into an ``hq``-sized cache copy.

* **Fused int8→fp dequant.**  With an int8 cache the kernel reads 1
  byte/value from HBM, does the integer-exact dot in bf16 (int8 values are
  exactly representable), and multiplies the per-token scale into the
  **partial sum** — the paper's Fig. 4 Stage-3 scale-after-accumulate, same
  contract as ``w4a16_matmul_pallas``.  The full-precision cache copy the
  old path materialized every step never exists.

* **Rolling-SWA addressing.**  A rolling buffer (``cache_len <= window``)
  stores the last ``cache_len`` tokens at slot ``pos mod cache_len``; RoPE
  is applied before caching and softmax is permutation-invariant, so the
  kernel just treats every slot below ``min(length, MAX)`` as valid (the
  caller clamps ``lengths``).  A non-rolling window additionally raises the
  *first* live block to the first block the earliest query's window reaches.

* **(m, l, acc) in VMEM scratch.**  Softmax running stats and the output
  accumulator stay resident across the KV-block axis — the G-VSA
  "partial sums never leave the array" discipline.

Roofline (per decode step, per layer): bytes ≈
``sum_b ceil(len_b/bk) * bk * d * hkv * kv_bytes * 2`` (+ ``4`` scale
bytes/token for int8) vs the dense ref's ``B * MAX * d * hkv * elt * 2`` —
at length 128 in a 2048-slot fp16 cache that is 16× fewer bytes, and int8
halves the per-byte cost again while the seed's dequantize-everything path
*tripled* it (int8 read + fp write + fp read).  A C-token chunk amortizes
the same KV stream over C queries — chunked prefill is the compute-bound
counterpart riding the identical pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams, default_interpret

__all__ = [
    "decode_flash_attention_pallas",
    "mixed_flash_attention_pallas",
    "kv_block_size",
    "DEFAULT_BLOCK_KV",
]

_NEG_INF = -1e30
_STATS = 128  # lane-replicated softmax statistics width
DEFAULT_BLOCK_KV = 128  # KV tile; ops.decode_attention gates tileability on it


def kv_block_size(max_len: int, block_kv: int) -> int:
    """Largest divisor of ``max_len`` that is <= ``block_kv``."""
    bk = min(block_kv, max_len)
    while max_len % bk:
        bk -= 1
    return bk


def _kernel(len_ref, qlen_ref, *refs, scale, window, bk, max_len, rep, chunk,
            quant, paged=False):
    if paged:
        # the page table is consumed by the BlockSpec index maps only — the
        # body sees logical positions; physical placement is pure DMA routing
        _pt_ref, *refs = refs
    q_ref, k_ref, v_ref, *rest = refs
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]           # total valid context incl. this step's chunk
    qlen = qlen_ref[b]            # live queries this step (1 = plain decode)
    valid_len = jnp.clip(length, 1, max_len)
    k_start = ik * bk
    live = k_start < valid_len
    if window is not None:
        # earliest query position is length - qlen; its window floor is
        # (length - qlen) - window + 1
        live = jnp.logical_and(
            live, k_start + bk > length - qlen - window + 1)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0]                                    # (rep*chunk, d)
        k = k_ref[0, 0]                                    # (bk, d)
        s = jax.lax.dot_general(
            q, k.astype(q.dtype),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (rep*chunk, bk)
        if quant:
            # scale-after-dot: the int8 dot is integer-exact in bf16; the
            # per-token fp scale multiplies the finished partial sum
            s = s * ks_ref[0, 0][None, :]
        s = s * scale

        rows = rep * chunk
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (rows, bk), 1)
        j = jax.lax.broadcasted_iota(jnp.int32, (rows, bk), 0) % chunk
        q_pos = length - qlen + j                           # per-query position
        valid = jnp.logical_and(pos < jnp.minimum(length, max_len),
                                pos <= q_pos)               # intra-chunk causal
        valid = jnp.logical_and(valid, j < qlen)            # dead query rows
        if window is not None:
            valid = jnp.logical_and(valid, pos > q_pos - window)
        s = jnp.where(valid, s, _NEG_INF)

        m_prev = m_ref[:, :1]                              # (rows, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)                       # dead rows: l == 0
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)

        if quant:
            # fold the per-token v scale into the probabilities (linear in v)
            p = p * vs_ref[0, 0][None, :]
        pv = jax.lax.dot_general(
            p.astype(q.dtype), v_ref[0, 0].astype(q.dtype),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (rows, d)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == pl.num_programs(2) - 1)
    def _done():
        l = l_ref[:, :1]
        l_safe = jnp.where(l == 0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "scale", "block_kv", "interpret"))
def mixed_flash_attention_pallas(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    q_lens: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool | None = None,
    page_table: jax.Array | None = None,
) -> jax.Array:
    """Mixed prefill/decode batched attention (chunk q-block).

    ``q`` (B, hq, C, d); caches (B, hkv, MAX, d) in fp or int8 (with
    ``k_scale``/``v_scale`` (B, hkv, MAX, 1) f32); ``lengths`` (B,) =
    per-row valid context *including* this step's chunk; ``q_lens`` (B,) =
    live queries per row (1 = decoding row, up to C = mid-prefill row; the
    padding queries return zeros).  Rolling-SWA callers pass ``lengths``
    pre-clamped to the buffer size and ``window=None``.  Returns
    (B, hq, C, d) in q.dtype.

    Paged layout: ``page_table`` (B, n_pages) int32 rides in as a THIRD
    scalar-prefetch operand; the caches are shared pools
    ``(P, hkv, bs, d)`` (scales ``(P, hkv, bs)``-shaped), the KV tile is
    the page size, and the K/V BlockSpec index maps translate the logical
    block id to ``page_table[b, ik]`` — the length-clamp DMA elision
    composes unchanged (clamped steps revisit the last live page's physical
    block, so Mosaic skips the copy).
    """
    if interpret is None:
        interpret = default_interpret()
    b, hq, chunk, d = q.shape
    hkv = k_cache.shape[1]
    paged = page_table is not None
    if paged:
        bk = k_cache.shape[2]                 # the page size IS the KV tile
        n_blocks = page_table.shape[1]
        max_len = n_blocks * bk
    else:
        max_len = k_cache.shape[2]
        bk = kv_block_size(max_len, block_kv)
        n_blocks = max_len // bk
    if hq % hkv:
        raise ValueError(f"hq={hq} not a multiple of hkv={hkv}")
    rep = hq // hkv
    rows = rep * chunk
    quant = k_scale is not None
    scale_v = scale if scale is not None else float(1.0 / (d ** 0.5))

    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    q_lens = jnp.broadcast_to(
        jnp.asarray(q_lens, jnp.int32).reshape(-1), (b,))
    # (B, hq, C, d) -> (B, hkv, rep*C, d): row r*C + j is (group head r, query j)
    q4 = q.reshape(b, hkv, rep, chunk, d).reshape(b, hkv, rows, d)

    def _live_block(ib, ik, len_ref, qlen_ref):
        # clamp into the row's live block range: steps outside it revisit an
        # already-resident block, so Mosaic issues no DMA for them
        vl = jnp.clip(len_ref[ib], 1, max_len)
        last = (vl - 1) // bk
        if window is None:
            first = 0
        else:
            first = jnp.minimum(jnp.maximum(
                (len_ref[ib] - qlen_ref[ib] - window + 1) // bk, 0), last)
        return jnp.clip(ik, first, last)

    def kv_map(ib, h, ik, len_ref, qlen_ref, *pt_ref):
        lg = _live_block(ib, ik, len_ref, qlen_ref)
        if paged:     # logical -> physical page translation
            return (pt_ref[0][ib, lg], h, 0, 0)
        return (ib, h, lg, 0)

    def kv_scale_map(ib, h, ik, len_ref, qlen_ref, *pt_ref):
        return kv_map(ib, h, ik, len_ref, qlen_ref, *pt_ref)[:3]

    def q_map(ib, h, ik, len_ref, qlen_ref, *pt_ref):
        return (ib, h, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, rows, d), q_map),
        pl.BlockSpec((1, 1, bk, d), kv_map),
        pl.BlockSpec((1, 1, bk, d), kv_map),
    ]
    operands = [q4, k_cache, v_cache]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, bk), kv_scale_map),
            pl.BlockSpec((1, 1, bk), kv_scale_map),
        ]
        scale_shape = ((k_cache.shape[0], hkv, bk) if paged
                       else (b, hkv, max_len))
        operands += [
            k_scale.astype(jnp.float32).reshape(scale_shape),
            v_scale.astype(jnp.float32).reshape(scale_shape),
        ]

    kernel = functools.partial(
        _kernel, scale=scale_v, window=window, bk=bk, max_len=max_len,
        rep=rep, chunk=chunk, quant=quant, paged=paged)

    prefetch = [lengths, q_lens]
    if paged:
        prefetch.append(jnp.asarray(page_table, jnp.int32))

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, hkv, n_blocks),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, rows, d), q_map),
            scratch_shapes=[
                pltpu.VMEM((rows, _STATS), jnp.float32),
                pltpu.VMEM((rows, _STATS), jnp.float32),
                pltpu.VMEM((rows, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*prefetch, *operands)
    return out.reshape(b, hkv, rep, chunk, d).reshape(b, hq, chunk, d)


def decode_flash_attention_pallas(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool | None = None,
    page_table: jax.Array | None = None,
) -> jax.Array:
    """One-token batched decode attention: the chunk=1 specialization.

    ``q`` (B, hq, 1, d); caches (B, hkv, MAX, d) in fp or int8 (with
    ``k_scale``/``v_scale`` (B, hkv, MAX, 1) f32) — or shared pools with a
    ``page_table``; ``lengths`` scalar or (B,) = per-row valid context
    *including* the new token.  Rolling-SWA callers pass ``lengths``
    pre-clamped to the buffer size and ``window=None``.  Returns
    (B, hq, 1, d) in q.dtype.
    """
    b, hq, sq, d = q.shape
    if sq != 1:
        raise ValueError(f"decode kernel is single-token (sq={sq}); use "
                         "mixed_flash_attention_pallas for chunked queries")
    return mixed_flash_attention_pallas(
        q, k_cache, v_cache, lengths, jnp.ones((b,), jnp.int32),
        window=window, scale=scale, k_scale=k_scale, v_scale=v_scale,
        block_kv=block_kv, interpret=interpret, page_table=page_table)
