"""Pallas TPU kernel: fused W4A16 FFN — ONE dispatch per MLP (EdgeLLM §III-B/C).

The paper's headline datapath is the FP16×INT4 FFN: the mixed-precision PE
array (Fig. 4) multiplies FP16 activations against streamed INT4 weights,
keeps full-mantissa partial sums in the array, and applies the per-group
"Scale value" multiply AFTER accumulation (Stage-3); log-scale structured
sparsity (§III-C) then shrinks the weight stream itself.  Our serving FFN
used to run as three independent ``pallas_call``s per MLP (gate, up, down)
that each re-streamed the activations and bounced two full ``(tokens, d_ff)``
intermediates plus the silu-multiply through HBM.  This kernel is the fusion:

* the ``(bt, d)`` activation block is **resident in VMEM** for the whole
  MLP — streamed from HBM once per token block, not once per projection;
* per f-tile (128 hidden channels — the MXU width AND the down projection's
  quant-group length), gate and up partial sums accumulate in two VMEM
  scratch accumulators across the contraction grid, with each 128-group's
  scale applied to its partial sum (Fig. 4 scale-after-accumulate);
* at the last group step the activation (silu/gelu) and elementwise product
  run **in-kernel** on the f32 accumulators, and the resulting ``(bt, 128)``
  hidden tile is immediately contracted against the down projection's
  matching 128-wide weight group — whose quant group axis IS this f-tile, so
  one scale covers the whole contraction — into a resident ``(bt, d)``
  output accumulator;
* the ``(tokens, d_ff)`` hidden state therefore **never touches HBM**: a
  whole MLP is one dispatch moving ``W + x + out`` bytes instead of
  ``W + 2x + 6·tokens·d_ff·2 + out`` (3 kernels + 2 XLA elementwise ops).

The sparse twin composes ``sparse_w4a16.py``'s kept-block gather with the
fusion: gate/up kept-block indices are scalar-prefetched into SMEM and drive
the activation gather (a VMEM slice of the resident block — the DMA-side
gather of the standalone kernel, moved on-chip by the fusion), and the down
projection's kept f-blocks (``tile_uniform`` sparsity, one kept set for all
output channels) drive the OUTER grid axis — hidden tiles the down
projection dropped are never computed and their gate/up weight blocks are
never streamed, so compute and weight bytes shrink together exactly like the
paper's time-unrolled sparse schedule.

Usage: call :func:`repro.kernels.ops.ffn_w4a16` (``impl="pallas"`` → these
kernels, ``impl="xla"`` → the blocked twin with the same numerics contract,
``impl="ref"`` → the unfused oracle).  ``models/layers.mlp_apply`` and the
MoE expert loops dispatch through it; direct callers exist only in tests and
benchmarks.

VMEM budget per step (dense-quant, defaults bt=128, d=4096): x block
``bt·d·2`` = 1 MB + out accumulator ``bt·d·4`` = 2 MB + out block 1 MB +
gate/up accumulators ``2·bt·128·4`` = 128 KB + weight blocks (gate/up
``64·128`` packed + down ``64·d``) ≈ 0.3 MB — ≈ 4.5 MB, well under 16 MB
v5e VMEM with room for Mosaic's double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import GROUP_SIZE, QuantizedTensor
from repro.core.sparsity import SparseQuantizedTensor
from repro.kernels.pallas_compat import (
    CompilerParams, default_interpret, token_block)

__all__ = [
    "DEFAULT_BLOCK_TOKENS",
    "ffn_fused_w4a16_pallas",
    "ffn_fused_dense_pallas",
    "ffn_fused_sparse_pallas",
    "ffn_w4a16_xla",
    "fused_variant",
]

_HALF = GROUP_SIZE // 2
DEFAULT_BLOCK_TOKENS = 128

GATED_ACTIVATIONS = ("swiglu", "geglu")
ACTIVATIONS = GATED_ACTIVATIONS + ("gelu",)


def _unpack_rows(packed_u8: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """(..., 64, n) packed nibbles -> (..., 128, n) int4 values as ``dtype``.

    Sublane-pair packing (core.quant): one mask, one shift, one sublane
    concat — integer-exact in bf16 and f32 alike.  The single unpack used
    by every path in this module (in-kernel blocks and the XLA twin)."""
    lo = (packed_u8 & 0xF).astype(jnp.int8)
    hi = (packed_u8 >> 4).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    return jnp.concatenate([lo, hi], axis=-2).astype(dtype)


def _apply_act(name: str, gate_f32, u_f32):
    """Activation + gating on the f32 accumulators (in-kernel, VPU)."""
    if name == "swiglu":
        return jax.nn.silu(gate_f32) * u_f32
    if name == "geglu":
        return jax.nn.gelu(gate_f32, approximate=True) * u_f32
    if name == "gelu":
        return jax.nn.gelu(u_f32, approximate=True)
    raise ValueError(f"unknown activation {name!r}")


def _dot_f32(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a, b, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# dense-layout kernels (fp16 weights / dense-quantized W4A16)
# ---------------------------------------------------------------------------

def _make_kernel(activation: str, gated: bool, bias: bool, quant: bool):
    """Kernel body for the dense-layout fused FFN.

    Grid (token_blocks, f_tiles, d_groups); operand order (quant):
      x, [gate packed+scales], up packed+scales, down packed+scales,
      [up_bias, down_bias], out, scratch: [gate_acc], up_acc, out_acc.
    fp variant drops the packed/scales pairs for plain (128, ·) blocks.
    """

    def kernel(*refs):
        it = iter(refs)
        x_ref = next(it)
        if gated:
            g_refs = (next(it), next(it)) if quant else (next(it),)
        u_refs = (next(it), next(it)) if quant else (next(it),)
        d_refs = (next(it), next(it)) if quant else (next(it),)
        if bias:
            ub_ref, db_ref = next(it), next(it)
        o_ref = next(it)
        gacc = next(it) if gated else None
        uacc = next(it)
        oacc = next(it)

        j, g = pl.program_id(1), pl.program_id(2)
        nj, ng = pl.num_programs(1), pl.num_programs(2)

        @pl.when(g == 0)
        def _reset_tile():
            uacc[...] = jnp.zeros_like(uacc)
            if gated:
                gacc[...] = jnp.zeros_like(gacc)

        @pl.when((g == 0) & (j == 0))
        def _reset_out():
            oacc[...] = jnp.zeros_like(oacc)

        xg = x_ref[:, pl.ds(pl.multiple_of(g * GROUP_SIZE, GROUP_SIZE),
                            GROUP_SIZE)]

        def proj(refs_):
            if quant:
                pk, sc = refs_
                w = _unpack_rows(pk[...])                       # (128, 128)
                return _dot_f32(xg, w) * sc[...].astype(jnp.float32)
            (w_ref,) = refs_
            return _dot_f32(xg, w_ref[...].astype(x_ref.dtype))

        uacc[...] += proj(u_refs)
        if gated:
            gacc[...] += proj(g_refs)

        @pl.when(g == ng - 1)
        def _tile_done():
            u = uacc[...]
            if bias:
                u = u + ub_ref[...].astype(jnp.float32)
            h = _apply_act(activation, gacc[...] if gated else None, u)
            h16 = h.astype(x_ref.dtype)
            if quant:
                pk, sc = d_refs
                wd = _unpack_rows(pk[...])                      # (128, out_f)
                part = _dot_f32(h16, wd) * sc[...].astype(jnp.float32)
            else:
                (wd_ref,) = d_refs
                part = _dot_f32(h16, wd_ref[...].astype(x_ref.dtype))
            oacc[...] += part

        @pl.when((g == ng - 1) & (j == nj - 1))
        def _write():
            out = oacc[...]
            if bias:
                out = out + db_ref[...].astype(jnp.float32)
            o_ref[...] = out.astype(o_ref.dtype)

    return kernel


def _flatten_pad(x: jax.Array, in_f: int, block_tokens: int | None):
    x2 = x.reshape(-1, in_f)
    n_tok = x2.shape[0]
    bt = token_block(n_tok, block_tokens or DEFAULT_BLOCK_TOKENS)
    pad = (-n_tok) % bt
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, n_tok, bt


def _bias_rows(up_bias, down_bias, f: int, out_f: int, dtype):
    ub = jnp.zeros((f,), dtype) if up_bias is None else up_bias
    db = jnp.zeros((out_f,), dtype) if down_bias is None else down_bias
    return ub.reshape(1, f), db.reshape(1, out_f)


def _check_gated_bias(gated: bool, up_bias, down_bias):
    if gated and (up_bias is not None or down_bias is not None):
        raise ValueError("gated activations take no FFN biases")


@functools.partial(
    jax.jit, static_argnames=("activation", "block_tokens", "interpret"))
def ffn_fused_w4a16_pallas(
    x: jax.Array,
    gate: QuantizedTensor | None,
    up: QuantizedTensor,
    down: QuantizedTensor,
    *,
    activation: str = "swiglu",
    up_bias: jax.Array | None = None,
    down_bias: jax.Array | None = None,
    block_tokens: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused quantized FFN: ``down( act(x@gate) * (x@up) )`` in one dispatch.

    All three weights are dense W4A16 ``QuantizedTensor``s with 128-channel
    groups; ``activation`` picks swiglu/geglu (gated, ``gate`` required) or
    gelu (ungated, ``gate`` ignored, optional biases)."""
    if interpret is None:
        interpret = default_interpret()
    gated = activation in GATED_ACTIVATIONS
    _check_gated_bias(gated, up_bias, down_bias)
    in_f, f = up.shape
    out_f = down.shape[1]
    for name, qt in (("up", up), ("down", down)) + (
            (("gate", gate),) if gated else ()):
        if qt.group_size != GROUP_SIZE:
            raise ValueError(f"{name}: fused kernel needs 128-channel groups")
    if down.shape[0] != f:
        raise ValueError(f"down in_features {down.shape[0]} != d_ff {f}")
    if x.shape[-1] != in_f:
        raise ValueError(f"contraction mismatch {x.shape[-1]} vs {in_f}")
    if in_f % GROUP_SIZE or f % GROUP_SIZE or out_f % GROUP_SIZE:
        raise ValueError("d_model/d_ff/out must be multiples of 128")

    *lead, tokens, _ = x.shape
    x2, n_tok, bt = _flatten_pad(x, in_f, block_tokens)
    nj, ng = f // GROUP_SIZE, in_f // GROUP_SIZE
    grid = (x2.shape[0] // bt, nj, ng)
    bias = not gated

    in_specs = [pl.BlockSpec((bt, in_f), lambda t, j, g: (t, 0))]
    args = [x2]
    if gated:
        in_specs += [
            pl.BlockSpec((_HALF, GROUP_SIZE), lambda t, j, g: (g, j)),
            pl.BlockSpec((1, GROUP_SIZE), lambda t, j, g: (g, j)),
        ]
        args += [gate.packed, gate.scales]
    in_specs += [
        pl.BlockSpec((_HALF, GROUP_SIZE), lambda t, j, g: (g, j)),
        pl.BlockSpec((1, GROUP_SIZE), lambda t, j, g: (g, j)),
        pl.BlockSpec((_HALF, out_f), lambda t, j, g: (j, 0)),
        pl.BlockSpec((1, out_f), lambda t, j, g: (j, 0)),
    ]
    args += [up.packed, up.scales, down.packed, down.scales]
    if bias:
        ub, db = _bias_rows(up_bias, down_bias, f, out_f, x.dtype)
        in_specs += [
            pl.BlockSpec((1, GROUP_SIZE), lambda t, j, g: (0, j)),
            pl.BlockSpec((1, out_f), lambda t, j, g: (0, 0)),
        ]
        args += [ub, db]

    scratch = ([pltpu.VMEM((bt, GROUP_SIZE), jnp.float32)] if gated else []) + [
        pltpu.VMEM((bt, GROUP_SIZE), jnp.float32),
        pltpu.VMEM((bt, out_f), jnp.float32),
    ]
    out = pl.pallas_call(
        _make_kernel(activation, gated, bias, quant=True),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, out_f), lambda t, j, g: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], out_f), x.dtype),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*args)
    if n_tok != x2.shape[0]:
        out = out[:n_tok]
    return out.reshape(*lead, tokens, out_f)


@functools.partial(
    jax.jit, static_argnames=("activation", "block_tokens", "interpret"))
def ffn_fused_dense_pallas(
    x: jax.Array,
    gate: jax.Array | None,
    up: jax.Array,
    down: jax.Array,
    *,
    activation: str = "swiglu",
    up_bias: jax.Array | None = None,
    down_bias: jax.Array | None = None,
    block_tokens: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused 16-bit-weight FFN (same fusion, no dequant stage)."""
    if interpret is None:
        interpret = default_interpret()
    gated = activation in GATED_ACTIVATIONS
    _check_gated_bias(gated, up_bias, down_bias)
    in_f, f = up.shape
    out_f = down.shape[1]
    if x.shape[-1] != in_f or down.shape[0] != f:
        raise ValueError("FFN weight shape mismatch")
    if in_f % GROUP_SIZE or f % GROUP_SIZE or out_f % GROUP_SIZE:
        raise ValueError("d_model/d_ff/out must be multiples of 128")

    *lead, tokens, _ = x.shape
    x2, n_tok, bt = _flatten_pad(x, in_f, block_tokens)
    nj, ng = f // GROUP_SIZE, in_f // GROUP_SIZE
    grid = (x2.shape[0] // bt, nj, ng)
    bias = not gated

    in_specs = [pl.BlockSpec((bt, in_f), lambda t, j, g: (t, 0))]
    args = [x2]
    if gated:
        in_specs += [pl.BlockSpec((GROUP_SIZE, GROUP_SIZE),
                                  lambda t, j, g: (g, j))]
        args += [gate]
    in_specs += [
        pl.BlockSpec((GROUP_SIZE, GROUP_SIZE), lambda t, j, g: (g, j)),
        pl.BlockSpec((GROUP_SIZE, out_f), lambda t, j, g: (j, 0)),
    ]
    args += [up, down]
    if bias:
        ub, db = _bias_rows(up_bias, down_bias, f, out_f, x.dtype)
        in_specs += [
            pl.BlockSpec((1, GROUP_SIZE), lambda t, j, g: (0, j)),
            pl.BlockSpec((1, out_f), lambda t, j, g: (0, 0)),
        ]
        args += [ub, db]

    scratch = ([pltpu.VMEM((bt, GROUP_SIZE), jnp.float32)] if gated else []) + [
        pltpu.VMEM((bt, GROUP_SIZE), jnp.float32),
        pltpu.VMEM((bt, out_f), jnp.float32),
    ]
    out = pl.pallas_call(
        _make_kernel(activation, gated, bias, quant=False),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, out_f), lambda t, j, g: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], out_f), x.dtype),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*args)
    if n_tok != x2.shape[0]:
        out = out[:n_tok]
    return out.reshape(*lead, tokens, out_f)


# ---------------------------------------------------------------------------
# sparse twin (scalar-prefetched kept-block indices)
# ---------------------------------------------------------------------------

def _make_sparse_kernel(activation: str, gated: bool, bias: bool,
                        down_sparse: bool):
    """Kernel body for the sparse fused FFN.

    Grid (token_blocks, down_f_steps, kept_contraction_blocks).  Prefetch
    refs: ftile (f-tile per outer step — down's kept blocks, or arange when
    down is dense-quantized), then gate/up kept-block index tables whose
    rows are f-tiles; they drive both the activation slice of the resident
    x block and the weight BlockSpec index maps (DMA-side weight gather)."""

    def kernel(*refs):
        it = iter(refs)
        ft_ref = next(it)
        gi_ref = next(it) if gated else None
        ui_ref = next(it)
        x_ref = next(it)
        if gated:
            gpk_ref, gsc_ref = next(it), next(it)
        upk_ref, usc_ref = next(it), next(it)
        dpk_ref, dsc_ref = next(it), next(it)
        if bias:
            ub_ref, db_ref = next(it), next(it)
        o_ref = next(it)
        gacc = next(it) if gated else None
        uacc = next(it)
        oacc = next(it)

        s, sg = pl.program_id(1), pl.program_id(2)
        ns, nsg = pl.num_programs(1), pl.num_programs(2)
        jf = ft_ref[s]

        @pl.when(sg == 0)
        def _reset_tile():
            uacc[...] = jnp.zeros_like(uacc)
            if gated:
                gacc[...] = jnp.zeros_like(gacc)

        @pl.when((sg == 0) & (s == 0))
        def _reset_out():
            oacc[...] = jnp.zeros_like(oacc)

        # activation gather: the kept d-block index picks the slice of the
        # RESIDENT x block (sparse_w4a16's DMA-side gather, moved on-chip)
        xu = x_ref[:, pl.ds(ui_ref[jf, sg] * GROUP_SIZE, GROUP_SIZE)]
        wu = _unpack_rows(upk_ref[0, 0])                       # (128, 128)
        uacc[...] += _dot_f32(xu, wu) * usc_ref[0].astype(jnp.float32)
        if gated:
            xg = x_ref[:, pl.ds(gi_ref[jf, sg] * GROUP_SIZE, GROUP_SIZE)]
            wg = _unpack_rows(gpk_ref[0, 0])
            gacc[...] += _dot_f32(xg, wg) * gsc_ref[0].astype(jnp.float32)

        @pl.when(sg == nsg - 1)
        def _tile_done():
            u = uacc[...]
            if bias:
                u = u + ub_ref[...].astype(jnp.float32)
            h = _apply_act(activation, gacc[...] if gated else None, u)
            h16 = h.astype(x_ref.dtype)
            if down_sparse:
                wd = _unpack_rows(dpk_ref[:, 0])               # (Td, 128, 128)
                part = jax.lax.dot_general(
                    h16, wd, dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)        # (bt, Td, 128)
                part = part * dsc_ref[:, 0].astype(jnp.float32)[None]
                oacc[...] += part.reshape(part.shape[0], -1)
            else:
                wd = _unpack_rows(dpk_ref[...])                # (128, out_f)
                part = _dot_f32(h16, wd) * dsc_ref[...].astype(jnp.float32)
                oacc[...] += part

        @pl.when((sg == nsg - 1) & (s == ns - 1))
        def _write():
            out = oacc[...]
            if bias:
                out = out + db_ref[...].astype(jnp.float32)
            o_ref[...] = out.astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("activation", "block_tokens", "interpret"))
def ffn_fused_sparse_pallas(
    x: jax.Array,
    gate: SparseQuantizedTensor | None,
    up: SparseQuantizedTensor,
    down: QuantizedTensor | SparseQuantizedTensor,
    *,
    activation: str = "swiglu",
    up_bias: jax.Array | None = None,
    down_bias: jax.Array | None = None,
    block_tokens: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused log-scale-sparse FFN.

    ``gate``/``up`` are block-sparse (per-f-tile kept d-blocks, scalar
    prefetched); ``down`` is either dense-quantized (all f-tiles visited) or
    ``tile_uniform`` block-sparse, in which case the outer grid walks ONLY
    its kept f-blocks — dropped hidden tiles are never computed and their
    gate/up weight blocks never leave HBM."""
    if interpret is None:
        interpret = default_interpret()
    gated = activation in GATED_ACTIVATIONS
    _check_gated_bias(gated, up_bias, down_bias)
    in_f, f = up.shape
    down_sparse = isinstance(down, SparseQuantizedTensor)
    out_f = down.shape[1]
    if down.shape[0] != f or x.shape[-1] != in_f:
        raise ValueError("FFN weight shape mismatch")
    if up.group_size != GROUP_SIZE or down.group_size != GROUP_SIZE:
        raise ValueError("fused kernel needs 128-channel groups")
    if gated and (gate.shape != up.shape
                  or gate.kept_blocks != up.kept_blocks):
        raise ValueError("gate/up must share shape and kept-block count")
    if down_sparse and not down.tile_uniform:
        raise ValueError("sparse down must be tile_uniform for the fused "
                         "kernel (one kept set for all output channels)")

    *lead, tokens, _ = x.shape
    x2, n_tok, bt = _flatten_pad(x, in_f, block_tokens)
    nt = x2.shape[0] // bt
    n_ftiles = f // GROUP_SIZE
    sc = up.kept_blocks
    if down_sparse:
        ftile = down.block_idx[0]                              # (S_dn,)
        n_fsteps = down.kept_blocks
    else:
        ftile = jnp.arange(n_ftiles, dtype=jnp.int32)
        n_fsteps = n_ftiles
    grid = (nt, n_fsteps, sc)
    bias = not gated

    # prefetch + tensor operands; index maps receive the prefetch refs last
    prefetch = [ftile]
    if gated:
        prefetch.append(gate.block_idx)
    prefetch.append(up.block_idx)
    n_pre = len(prefetch)

    def _ft(s, refs):
        return refs[0][s]

    in_specs = [pl.BlockSpec((bt, in_f), lambda t, s, sg, *r: (t, 0))]
    args = [x2]
    if gated:
        in_specs += [
            pl.BlockSpec((1, 1, _HALF, GROUP_SIZE),
                         lambda t, s, sg, *r: (_ft(s, r), sg, 0, 0)),
            pl.BlockSpec((1, 1, GROUP_SIZE),
                         lambda t, s, sg, *r: (_ft(s, r), sg, 0)),
        ]
        args += [gate.packed, gate.scales]
    in_specs += [
        pl.BlockSpec((1, 1, _HALF, GROUP_SIZE),
                     lambda t, s, sg, *r: (_ft(s, r), sg, 0, 0)),
        pl.BlockSpec((1, 1, GROUP_SIZE),
                     lambda t, s, sg, *r: (_ft(s, r), sg, 0)),
    ]
    args += [up.packed, up.scales]
    if down_sparse:
        td = out_f // GROUP_SIZE
        in_specs += [
            pl.BlockSpec((td, 1, _HALF, GROUP_SIZE),
                         lambda t, s, sg, *r: (0, s, 0, 0)),
            pl.BlockSpec((td, 1, GROUP_SIZE),
                         lambda t, s, sg, *r: (0, s, 0)),
        ]
    else:
        in_specs += [
            pl.BlockSpec((_HALF, out_f),
                         lambda t, s, sg, *r: (_ft(s, r), 0)),
            pl.BlockSpec((1, out_f),
                         lambda t, s, sg, *r: (_ft(s, r), 0)),
        ]
    args += [down.packed, down.scales]
    if bias:
        ub, db = _bias_rows(up_bias, down_bias, f, out_f, x.dtype)
        in_specs += [
            pl.BlockSpec((1, GROUP_SIZE),
                         lambda t, s, sg, *r: (0, _ft(s, r))),
            pl.BlockSpec((1, out_f), lambda t, s, sg, *r: (0, 0)),
        ]
        args += [ub, db]

    scratch = ([pltpu.VMEM((bt, GROUP_SIZE), jnp.float32)] if gated else []) + [
        pltpu.VMEM((bt, GROUP_SIZE), jnp.float32),
        pltpu.VMEM((bt, out_f), jnp.float32),
    ]
    out = pl.pallas_call(
        _make_sparse_kernel(activation, gated, bias, down_sparse),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_pre,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bt, out_f), lambda t, s, sg, *r: (t, 0)),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], out_f), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*(prefetch + args))
    if n_tok != x2.shape[0]:
        out = out[:n_tok]
    return out.reshape(*lead, tokens, out_f)


# ---------------------------------------------------------------------------
# blocked-XLA twin (CPU CI parity / dry-run path)
# ---------------------------------------------------------------------------

def _unpack_f32(packed: jax.Array, group_size: int) -> jax.Array:
    """(in/2, out) packed nibbles -> (groups, gs, out) f32 integer values.

    Unlike the ref oracle, no intermediate bf16 weight matrix is
    materialized — the nibbles go straight to the f32 einsum operand (int4
    is exact in both, so numerics are identical; one fewer full-matrix
    round trip through memory, the twin's decode-shape win)."""
    half = group_size // 2
    out_f = packed.shape[-1]
    return _unpack_rows(packed.reshape(-1, half, out_f), jnp.float32)


def w4a16_matmul_f32(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Group-exact ``x @ dequant(qt)`` returning f32 (scale-after-dot)."""
    in_f = qt.shape[0]
    gs = qt.group_size
    xg = x.reshape(*x.shape[:-1], in_f // gs, gs).astype(jnp.float32)
    qg = _unpack_f32(qt.packed, gs)
    partial = jnp.einsum("...kg,kgo->...ko", xg, qg,
                         preferred_element_type=jnp.float32)
    return (partial * qt.scales.astype(jnp.float32)).sum(axis=-2)


def sparse_matmul_f32(x: jax.Array, st: SparseQuantizedTensor) -> jax.Array:
    """Block-gathered sparse W4A16 matmul returning f32 (per-block scale)."""
    in_f, out_f = st.shape
    g = st.group_size
    *lead, tokens, _ = x.shape
    xb = x.reshape(-1, in_f // g, g).astype(jnp.float32)
    w = _unpack_rows(st.packed, jnp.float32)                   # (T,S,128,128)
    xg = jnp.take(xb, st.block_idx, axis=1)                    # (N,T,S,128)
    part = jnp.einsum("ntsg,tsgo->ntso", xg, w,
                      preferred_element_type=jnp.float32)
    out = (part * st.scales.astype(jnp.float32)[None]).sum(axis=2)
    return out.reshape(*lead, tokens, out_f)


def ffn_w4a16_xla(
    x: jax.Array,
    gate,
    up,
    down,
    *,
    activation: str = "swiglu",
    up_bias: jax.Array | None = None,
    down_bias: jax.Array | None = None,
) -> jax.Array:
    """Blocked-XLA twin of the fused kernel (any weight mix).

    Same numerics contract as the Pallas kernels: per-quant-group (the block
    axis) scale-after-dot in f32, activation and gating on the f32
    accumulators, hidden state cast to the compute dtype only for the down
    contraction.  Unpacks int4 straight to the f32 dot operand — no
    intermediate 16-bit weight matrix — which is what makes it faster than
    the unfused 3-matmul path at decode shapes on CPU."""
    _check_gated_bias(activation in GATED_ACTIVATIONS, up_bias, down_bias)

    def mm(x_, w):
        if isinstance(w, QuantizedTensor):
            return w4a16_matmul_f32(x_, w)
        if isinstance(w, SparseQuantizedTensor):
            return sparse_matmul_f32(x_, w)
        return jax.lax.dot_general(
            x_.astype(jnp.float32), w.astype(jnp.float32),
            (((x_.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if activation == "swiglu":
        h = jax.nn.silu(mm(x, gate)) * mm(x, up)
    elif activation == "geglu":
        h = jax.nn.gelu(mm(x, gate), approximate=True) * mm(x, up)
    elif activation == "gelu":
        u = mm(x, up)
        if up_bias is not None:
            u = u + up_bias.astype(jnp.float32)
        h = jax.nn.gelu(u, approximate=True)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    out = mm(h.astype(x.dtype), down)
    if down_bias is not None:
        out = out + down_bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dispatch predicate
# ---------------------------------------------------------------------------

def fused_variant(x, gate, up, down, activation, up_bias, down_bias):
    """Which fused Pallas kernel fits these operands, if any.

    Returns ``"quant"`` / ``"sparse"`` / ``"fp"`` / ``None`` — a STATIC
    decision (types, shapes, group sizes, the tile_uniform flag), so the
    choice is stable under jit and never adds executables."""
    gated = activation in GATED_ACTIVATIONS
    if activation not in ACTIVATIONS:
        return None
    if gated and (up_bias is not None or down_bias is not None):
        return None
    if len(up.shape) != 2 or len(down.shape) != 2:
        return None
    if gated and (gate is None or len(gate.shape) != 2):
        return None
    in_f, f = up.shape
    out_f = down.shape[1]
    if x.shape[-1] != in_f or down.shape[0] != f:
        return None
    if in_f % GROUP_SIZE or f % GROUP_SIZE or out_f % GROUP_SIZE:
        return None
    ws = ((gate, up, down) if gated else (up, down))

    if all(isinstance(w, QuantizedTensor) for w in ws):
        if all(w.group_size == GROUP_SIZE for w in ws):
            return "quant"
        return None
    if (isinstance(up, SparseQuantizedTensor)
            and (not gated or isinstance(gate, SparseQuantizedTensor))):
        if not isinstance(down, (QuantizedTensor, SparseQuantizedTensor)):
            return None
        if up.group_size != GROUP_SIZE or down.group_size != GROUP_SIZE:
            return None
        if gated and (gate.shape != up.shape
                      or gate.kept_blocks != up.kept_blocks
                      or gate.group_size != GROUP_SIZE):
            return None
        if isinstance(down, QuantizedTensor):
            return "sparse"
        if down.tile_uniform:
            return "sparse"
        return None
    if all(isinstance(w, jax.Array) and jnp.issubdtype(w.dtype, jnp.floating)
           for w in ws):
        return "fp"
    return None
