"""Pallas TPU kernel: fused attention, bf16 operands (EdgeLLM MODE-0).

The paper's FP16*FP16 unit handles every matmul whose second operand is
*dynamically generated* (Q·Kᵀ and P·V against the KV cache) — those can never
be pre-quantized.  On TPU that is the flash-attention kernel: K/V stream
through VMEM block by block while the softmax statistics (m, l) and the
output accumulator stay resident, the same stationary-accumulator discipline
as the G-VSA array.

Supports causal masking, sliding windows (Mixtral SWA), GQA/MQA head
grouping, decode alignment (q block occupies the last ``sq`` positions of the
``skv`` context), and non-causal cross-attention (Whisper).

Grid: ``(batch*q_heads, sq/bq, skv/bk)`` with the KV axis innermost
("arbitrary"); fully-masked KV blocks are skipped with ``pl.when`` — the
TPU version of the paper's "MHA latency grows quadratically" mitigation,
halving work under causal masks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams, default_interpret

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30
_STATS = 128  # lane-replicated softmax statistics width


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, causal, window, q_offset, bq, bk):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = q_offset + iq * bq
    k_start = ik * bk
    # block-level skip: under a causal mask, blocks strictly above the
    # diagonal contribute nothing; under a window, blocks too far in the
    # past contribute nothing either.
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(live)
    def _step():
        q = q_ref[0]                                       # (bq, d)
        k = k_ref[0]                                       # (bk, d)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]                              # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)          # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)

        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (bq, d)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == pl.num_programs(2) - 1)
    def _done():
        l = l_ref[:, :1]
        l_safe = jnp.where(l == 0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_kv", "interpret"))
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 256,
    block_kv: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused attention.  q (b, hq, sq, d); k/v (b, hkv, skv, d); GQA via
    hq % hkv == 0.  Causal alignment: q block sits at the end of the context.
    ``interpret=None`` derives from the backend (Mosaic on TPU).
    """
    if interpret is None:
        interpret = default_interpret()
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"hq={hq} not a multiple of hkv={hkv}")
    rep = hq // hkv
    scale_v = scale if scale is not None else float(1.0 / (d ** 0.5))

    bq = min(block_q, sq)
    bk = min(block_kv, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"sq={sq} % bq={bq} or skv={skv} % bk={bk} != 0")
    q_offset = skv - sq

    q3 = q.reshape(b * hq, sq, d)
    k3 = k.reshape(b * hkv, skv, d)
    v3 = v.reshape(b * hkv, skv, d)

    def kv_index(bh, iq, ik):
        return (bh // hq) * hkv + (bh % hq) // rep

    kernel = functools.partial(
        _kernel, scale=scale_v, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, sq // bq, skv // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (kv_index(bh, iq, ik), ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (kv_index(bh, iq, ik), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _STATS), jnp.float32),
            pltpu.VMEM((bq, _STATS), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(b, hq, sq, d)
