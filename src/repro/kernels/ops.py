"""Public jit'd entry points for the kernel package.

Each op dispatches between:

* ``impl="pallas"``   — the Pallas TPU kernel (``interpret=True`` on CPU, a
  real Mosaic lowering on TPU).  This is the performance path.
* ``impl="xla"``      — a pure-XLA implementation with the *same numerics
  contract* (group-exact scale-after-dot).  This is what the 512-device
  dry-run lowers (Pallas cannot target the CPU dry-run backend), and the
  fallback for shapes the kernels don't tile.

The op-graph compiler (``core/compiler.py``) selects the impl per operator;
models only ever call these wrappers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor
from repro.core.sparsity import SparseQuantizedTensor
from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.pallas_compat import default_interpret
from repro.kernels.sparse_w4a16 import sparse_w4a16_matmul_pallas
from repro.kernels.w4a16_matmul import w4a16_matmul_pallas

__all__ = ["w4a16_matmul", "sparse_w4a16_matmul", "attention",
           "decode_attention", "mixed_attention"]

# one backend probe for the whole package: the kernels resolve their
# interpret=None default through the same (cached) function
_ON_TPU = not default_interpret()


def w4a16_matmul(x: jax.Array, qt: QuantizedTensor, *, impl: str = "auto") -> jax.Array:
    """x @ dequant(qt); group-exact W4A16 numerics on every path."""
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        return w4a16_matmul_pallas(x, qt)
    if impl == "xla":
        return _ref.w4a16_matmul_ref(x, qt)
    raise ValueError(f"unknown impl {impl!r}")


def sparse_w4a16_matmul(
    x: jax.Array, st: SparseQuantizedTensor, *, impl: str = "auto"
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        return sparse_w4a16_matmul_pallas(x, st)
    if impl == "xla":
        # gather-then-dense-dot: same block gather the kernel does, expressed
        # as XLA take + einsum (keeps the sparse byte/FLOP savings visible to
        # cost_analysis)
        in_f, out_f = st.shape
        g = st.group_size
        *lead, tokens, _ = x.shape
        xb = x.reshape(-1, in_f // g, g)
        # unpack kept blocks
        lo = (st.packed & 0xF).astype(jnp.int8)
        hi = (st.packed >> 4).astype(jnp.int8)
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        w = jnp.concatenate([lo, hi], axis=2).astype(jnp.bfloat16)  # (T,S,128,128)
        xg = jnp.take(xb, st.block_idx, axis=1)          # (N, T, S, 128)
        part = jnp.einsum("ntsg,tsgo->ntso", xg.astype(jnp.float32),
                          w.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        out = (part * st.scales.astype(jnp.float32)[None]).sum(axis=2)
        out = out.reshape(xb.shape[0], out_f)
        return out.astype(x.dtype).reshape(*lead, tokens, out_f)
    raise ValueError(f"unknown impl {impl!r}")


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    impl: str = "auto",
) -> jax.Array:
    """Fused attention (MODE-0). q (b,hq,sq,d), k/v (b,hkv,skv,d)."""
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale)
    if impl == "xla":
        if k.shape[2] >= 2048:
            # chunked flash recurrence: O(chunk^2) temporaries instead of
            # O(s^2) — the dense oracle at 32k context costs ~TB/device
            from repro.kernels.xla_attention import attention_chunked
            return attention_chunked(q, k, v, causal=causal, window=window,
                                     scale=scale)
        return _ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    raise ValueError(f"unknown impl {impl!r}")


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    length: jax.Array | int,
    *,
    window: int | None = None,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    impl: str = "auto",
) -> jax.Array:
    """One-token decode attention against a preallocated KV cache.

    q (b, hq, 1, d); caches (b, hkv, MAX, d) — fp, or int8 with
    ``k_scale``/``v_scale`` (b, hkv, MAX, 1), in which case dequant is fused
    into the attention (scale-after-dot; the cache is read at 1 byte/value).

    * ``impl="pallas"`` — the flash-decoding kernel: per-row KV-block
      skipping, bytes and FLOPs scale with each row's actual context.
    * ``impl="xla"``    — the length-blocked twin: a while_loop over KV
      blocks bounded by max(lengths), per-row masking.  The hot path on CPU
      and in the distributed serve_step (length masks keep addresses static
      under jit — the paper's MAX-token trick).
    * ``impl="ref"``    — the dense full-cache oracle (dequantizes the whole
      cache first when quantized): the numerics ground truth and the
      bandwidth baseline ``benchmarks/decode_bench.py`` measures against.
    """
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        from repro.kernels.decode_flash import (
            DEFAULT_BLOCK_KV, decode_flash_attention_pallas, kv_block_size)
        if kv_block_size(k_cache.shape[2], DEFAULT_BLOCK_KV) >= 8:
            return decode_flash_attention_pallas(
                q, k_cache, v_cache, length, window=window, scale=scale,
                k_scale=k_scale, v_scale=v_scale)
        impl = "xla"  # cache length tiles too poorly for the kernel
    if impl == "xla":
        from repro.kernels.xla_attention import decode_attention_blocked
        return decode_attention_blocked(
            q, k_cache, v_cache, length, window=window, scale=scale,
            k_scale=k_scale, v_scale=v_scale)
    if impl == "ref":
        k_full, v_full = k_cache, v_cache
        if k_scale is not None:
            # the seed's path: materialize a full-precision cache copy
            from repro.models.attention import dequantize_kv
            k_full = dequantize_kv(k_cache, k_scale, q.dtype)
            v_full = dequantize_kv(v_cache, v_scale, q.dtype)
        return _ref.decode_attention_ref(
            q, k_full, v_full, length, window=window, scale=scale)
    raise ValueError(f"unknown impl {impl!r}")


def mixed_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    q_lens: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    impl: str = "auto",
) -> jax.Array:
    """Mixed prefill/decode attention against a preallocated KV cache.

    The chunked generalization of ``decode_attention``: q (b, hq, C, d)
    carries ``q_lens[b]`` live queries per row (1 = a decoding row, up to C
    = a row mid-prefill), ``lengths`` (b,) is the valid context *including*
    this step's chunk, and intra-chunk causality is masked per query — one
    dispatch advances a mixed batch (the serving tick's shape contract).

    * ``impl="pallas"`` — the flash-decoding kernel with a chunk q-block:
      per-row KV-block skipping, the chunk rides the same DMA pipeline.
    * ``impl="xla"``    — the length-blocked twin (``mixed_attention_blocked``),
      sharing its block walker with the decode path.
    * ``impl="ref"``    — the dense full-cache oracle.
    """
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        from repro.kernels.decode_flash import (
            DEFAULT_BLOCK_KV, kv_block_size, mixed_flash_attention_pallas)
        if kv_block_size(k_cache.shape[2], DEFAULT_BLOCK_KV) >= 8:
            return mixed_flash_attention_pallas(
                q, k_cache, v_cache, lengths, q_lens, window=window,
                scale=scale, k_scale=k_scale, v_scale=v_scale)
        impl = "xla"  # cache length tiles too poorly for the kernel
    if impl == "xla":
        from repro.kernels.xla_attention import mixed_attention_blocked
        return mixed_attention_blocked(
            q, k_cache, v_cache, lengths, q_lens, window=window, scale=scale,
            k_scale=k_scale, v_scale=v_scale)
    if impl == "ref":
        k_full, v_full = k_cache, v_cache
        if k_scale is not None:
            from repro.models.attention import dequantize_kv
            k_full = dequantize_kv(k_cache, k_scale, q.dtype)
            v_full = dequantize_kv(v_cache, v_scale, q.dtype)
        return _ref.mixed_attention_ref(
            q, k_full, v_full, lengths, q_lens, window=window, scale=scale)
    raise ValueError(f"unknown impl {impl!r}")
