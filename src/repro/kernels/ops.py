"""Public jit'd entry points for the kernel package.

Each op dispatches between:

* ``impl="pallas"``   — the Pallas TPU kernel (``interpret=True`` on CPU, a
  real Mosaic lowering on TPU).  This is the performance path.
* ``impl="xla"``      — a pure-XLA implementation with the *same numerics
  contract* (group-exact scale-after-dot).  This is what the 512-device
  dry-run lowers (Pallas cannot target the CPU dry-run backend), and the
  fallback for shapes the kernels don't tile.

The op-graph compiler (``core/compiler.py``) selects the impl per operator;
models only ever call these wrappers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor
from repro.core.sparsity import SparseQuantizedTensor
from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.pallas_compat import default_interpret
from repro.kernels.sparse_w4a16 import sparse_w4a16_matmul_pallas
from repro.kernels.w4a16_matmul import w4a16_matmul_pallas

__all__ = ["w4a16_matmul", "sparse_w4a16_matmul", "ffn_w4a16", "attention",
           "decode_attention", "mixed_attention", "gather_paged_cache"]

# one backend probe for the whole package: the kernels resolve their
# interpret=None default through the same (cached) function
_ON_TPU = not default_interpret()


def w4a16_matmul(x: jax.Array, qt: QuantizedTensor, *, impl: str = "auto") -> jax.Array:
    """x @ dequant(qt); group-exact W4A16 numerics on every path."""
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        return w4a16_matmul_pallas(x, qt)
    if impl == "xla":
        return _ref.w4a16_matmul_ref(x, qt)
    raise ValueError(f"unknown impl {impl!r}")


def sparse_w4a16_matmul(
    x: jax.Array, st: SparseQuantizedTensor, *, impl: str = "auto"
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        return sparse_w4a16_matmul_pallas(x, st)
    if impl == "xla":
        # gather-then-dense-dot: same block gather the kernel does, expressed
        # as XLA take + einsum (keeps the sparse byte/FLOP savings visible to
        # cost_analysis); shared with the fused-FFN twin
        from repro.kernels.ffn_fused import sparse_matmul_f32
        return sparse_matmul_f32(x, st).astype(x.dtype)
    raise ValueError(f"unknown impl {impl!r}")


def ffn_w4a16(
    x: jax.Array,
    gate,
    up,
    down,
    *,
    activation: str = "swiglu",
    up_bias: jax.Array | None = None,
    down_bias: jax.Array | None = None,
    impl: str = "auto",
) -> jax.Array:
    """Whole FFN — ``down( act(x@gate) * (x@up) )`` — as ONE operator.

    Weights may be dense arrays, ``QuantizedTensor``s (W4A16) or
    ``SparseQuantizedTensor``s (log-scale sparse); ``activation`` is
    swiglu/geglu (gated) or gelu (ungated, optional biases).

    * ``impl="pallas"`` — the fused kernel (``kernels/ffn_fused.py``): one
      dispatch per MLP, the ``(tokens, d_ff)`` hidden state never leaves
      VMEM.  Falls back to the twin for operand mixes the kernel doesn't
      tile (non-128 quant groups, non-tile-uniform sparse down, ...).
    * ``impl="xla"``    — the blocked twin: same numerics contract
      (f32 scale-after-dot per quant group, f32 activation), no 16-bit
      weight materialization.  The hot path on CPU and in the dry run.
      Plain 16-bit weights keep the seed's exact unfused composition.
    * ``impl="ref"``    — the unfused 3-matmul oracle.
    """
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    gated = activation in ("swiglu", "geglu")
    if gated and (up_bias is not None or down_bias is not None):
        raise ValueError("gated activations take no FFN biases")
    ws = (gate, up, down) if gated else (up, down)
    quantized = any(
        isinstance(w, (QuantizedTensor, SparseQuantizedTensor)) for w in ws)
    if impl == "ref" or (impl == "xla" and not quantized):
        return _ref.ffn_ref(x, gate, up, down, activation=activation,
                            up_bias=up_bias, down_bias=down_bias)
    from repro.kernels import ffn_fused
    if impl == "pallas":
        variant = ffn_fused.fused_variant(
            x, gate, up, down, activation, up_bias, down_bias)
        if variant == "quant":
            return ffn_fused.ffn_fused_w4a16_pallas(
                x, gate if gated else None, up, down, activation=activation,
                up_bias=up_bias, down_bias=down_bias)
        if variant == "sparse":
            return ffn_fused.ffn_fused_sparse_pallas(
                x, gate if gated else None, up, down, activation=activation,
                up_bias=up_bias, down_bias=down_bias)
        if variant == "fp":
            return ffn_fused.ffn_fused_dense_pallas(
                x, gate if gated else None, up, down, activation=activation,
                up_bias=up_bias, down_bias=down_bias)
        impl = "xla"
    if impl == "xla":
        if not quantized:
            return _ref.ffn_ref(x, gate, up, down, activation=activation,
                                up_bias=up_bias, down_bias=down_bias)
        return ffn_fused.ffn_w4a16_xla(
            x, gate, up, down, activation=activation,
            up_bias=up_bias, down_bias=down_bias)
    raise ValueError(f"unknown impl {impl!r}")


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    impl: str = "auto",
) -> jax.Array:
    """Fused attention (MODE-0). q (b,hq,sq,d), k/v (b,hkv,skv,d)."""
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale)
    if impl == "xla":
        if k.shape[2] >= 2048:
            # chunked flash recurrence: O(chunk^2) temporaries instead of
            # O(s^2) — the dense oracle at 32k context costs ~TB/device
            from repro.kernels.xla_attention import attention_chunked
            return attention_chunked(q, k, v, causal=causal, window=window,
                                     scale=scale)
        return _ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    raise ValueError(f"unknown impl {impl!r}")


def gather_paged_cache(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize a paged pool ``(P, g, bs, ...)`` as the contiguous
    per-slot cache ``(b, g, n_pages*bs, ...)`` a dense oracle expects —
    the layout inverse of the engine's block leasing (null-block pages
    gather finite garbage that true-length masking hides, exactly like
    stale rows in the slot layout)."""
    g = jnp.take(pool, page_table, axis=0)        # (b, n_pages, g, bs, ...)
    b, npg, heads, bs = g.shape[:4]
    g = jnp.moveaxis(g, 2, 1)                     # (b, g, n_pages, bs, ...)
    return g.reshape(b, heads, npg * bs, *g.shape[4:])


def _paged_kernel_ok(pool: jax.Array) -> bool:
    return pool.shape[2] >= 8    # page size tiles the kernel's KV block


def _materialize_ref_cache(q, k_cache, v_cache, k_scale, v_scale, page_table):
    """The ref oracle's operand prep: gather a paged pool contiguous, then
    drop any int8 quantization via a full-precision copy (the seed's path)."""
    k_full, v_full = k_cache, v_cache
    ks_full, vs_full = k_scale, v_scale
    if page_table is not None:
        k_full = gather_paged_cache(k_full, page_table)
        v_full = gather_paged_cache(v_full, page_table)
        if k_scale is not None:
            ks_full = gather_paged_cache(ks_full, page_table)
            vs_full = gather_paged_cache(vs_full, page_table)
    if k_scale is not None:
        from repro.models.attention import dequantize_kv
        k_full = dequantize_kv(k_full, ks_full, q.dtype)
        v_full = dequantize_kv(v_full, vs_full, q.dtype)
    return k_full, v_full


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    length: jax.Array | int,
    *,
    window: int | None = None,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    impl: str = "auto",
    page_table: jax.Array | None = None,
) -> jax.Array:
    """One-token decode attention against a preallocated KV cache.

    q (b, hq, 1, d); caches (b, hkv, MAX, d) — fp, or int8 with
    ``k_scale``/``v_scale`` (b, hkv, MAX, 1), in which case dequant is fused
    into the attention (scale-after-dot; the cache is read at 1 byte/value).
    With ``page_table`` (b, n_pages) the caches are shared paged pools
    ``(P, hkv, bs, d)`` (scales ``(P, hkv, bs, 1)``) and every impl
    translates logical blocks through the table.

    * ``impl="pallas"`` — the flash-decoding kernel: per-row KV-block
      skipping, bytes and FLOPs scale with each row's actual context.
    * ``impl="xla"``    — the length-blocked twin: a while_loop over KV
      blocks bounded by max(lengths), per-row masking.  The hot path on CPU
      and in the distributed serve_step (length masks keep addresses static
      under jit — the paper's MAX-token trick).
    * ``impl="ref"``    — the dense full-cache oracle (dequantizes the whole
      cache first when quantized; gathers a paged pool contiguous first):
      the numerics ground truth and the bandwidth baseline
      ``benchmarks/decode_bench.py`` measures against.
    """
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        from repro.kernels.decode_flash import (
            DEFAULT_BLOCK_KV, decode_flash_attention_pallas, kv_block_size)
        ok = (_paged_kernel_ok(k_cache) if page_table is not None
              else kv_block_size(k_cache.shape[2], DEFAULT_BLOCK_KV) >= 8)
        if ok:
            return decode_flash_attention_pallas(
                q, k_cache, v_cache, length, window=window, scale=scale,
                k_scale=k_scale, v_scale=v_scale, page_table=page_table)
        impl = "xla"  # cache length tiles too poorly for the kernel
    if impl == "xla":
        from repro.kernels.xla_attention import decode_attention_blocked
        return decode_attention_blocked(
            q, k_cache, v_cache, length, window=window, scale=scale,
            k_scale=k_scale, v_scale=v_scale, page_table=page_table)
    if impl == "ref":
        k_full, v_full = _materialize_ref_cache(
            q, k_cache, v_cache, k_scale, v_scale, page_table)
        return _ref.decode_attention_ref(
            q, k_full, v_full, length, window=window, scale=scale)
    raise ValueError(f"unknown impl {impl!r}")


def mixed_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    q_lens: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    impl: str = "auto",
    page_table: jax.Array | None = None,
) -> jax.Array:
    """Mixed prefill/decode attention against a preallocated KV cache.

    The chunked generalization of ``decode_attention``: q (b, hq, C, d)
    carries ``q_lens[b]`` live queries per row (1 = a decoding row, up to C
    = a row mid-prefill), ``lengths`` (b,) is the valid context *including*
    this step's chunk, and intra-chunk causality is masked per query — one
    dispatch advances a mixed batch (the serving tick's shape contract).
    ``page_table`` switches all three impls to the paged pool layout.

    * ``impl="pallas"`` — the flash-decoding kernel with a chunk q-block:
      per-row KV-block skipping, the chunk rides the same DMA pipeline.
    * ``impl="xla"``    — the length-blocked twin (``mixed_attention_blocked``),
      sharing its block walker with the decode path.
    * ``impl="ref"``    — the dense full-cache oracle.
    """
    if impl == "auto":
        impl = "pallas" if _ON_TPU else "xla"
    if impl == "pallas":
        from repro.kernels.decode_flash import (
            DEFAULT_BLOCK_KV, kv_block_size, mixed_flash_attention_pallas)
        ok = (_paged_kernel_ok(k_cache) if page_table is not None
              else kv_block_size(k_cache.shape[2], DEFAULT_BLOCK_KV) >= 8)
        if ok:
            return mixed_flash_attention_pallas(
                q, k_cache, v_cache, lengths, q_lens, window=window,
                scale=scale, k_scale=k_scale, v_scale=v_scale,
                page_table=page_table)
        impl = "xla"  # cache length tiles too poorly for the kernel
    if impl == "xla":
        from repro.kernels.xla_attention import mixed_attention_blocked
        return mixed_attention_blocked(
            q, k_cache, v_cache, lengths, q_lens, window=window, scale=scale,
            k_scale=k_scale, v_scale=v_scale, page_table=page_table)
    if impl == "ref":
        k_full, v_full = _materialize_ref_cache(
            q, k_cache, v_cache, k_scale, v_scale, page_table)
        return _ref.mixed_attention_ref(
            q, k_full, v_full, lengths, q_lens, window=window, scale=scale)
    raise ValueError(f"unknown impl {impl!r}")
