"""Pallas API compatibility.

``pltpu.TPUCompilerParams`` (jax 0.4.x) was renamed ``pltpu.CompilerParams``
in later releases; the fields the kernels use (``dimension_semantics``) are
identical.

Also home of :func:`default_interpret` — every kernel in this package
resolves ``interpret=None`` through it, so direct callers get the Mosaic
lowering on TPU and the interpreter elsewhere without passing a flag.
"""

import functools

import jax
from jax.experimental.pallas import tpu as pltpu


@functools.cache
def default_interpret() -> bool:
    """True (interpret mode) unless a TPU backend is attached."""
    return not any(d.platform == "tpu" for d in jax.devices())


def token_block(n_tok: int, block_tokens: int) -> int:
    """Decode-shaped token-block size for the matmul-family kernels.

    A batch-1 decode step carries ONE live token row; the old
    ``min(block_tokens, max(8, n_tok))`` rule padded it to an 8-row block —
    8x wasted activation DMA and MXU issue on the serving hot path.  Small
    token counts now get an exact-fit block (no padding at all up to
    ``block_tokens``); only prefill-sized calls tile at ``block_tokens`` and
    pad the remainder."""
    return n_tok if n_tok <= block_tokens else block_tokens

try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:
    try:
        CompilerParams = pltpu.TPUCompilerParams
    except AttributeError as e:  # pre-dataclass jax versions
        raise ImportError(
            "this jax version exposes neither pltpu.CompilerParams nor "
            "pltpu.TPUCompilerParams; the Pallas kernels need jax >= 0.4.31"
        ) from e
