"""Pallas API compatibility.

``pltpu.TPUCompilerParams`` (jax 0.4.x) was renamed ``pltpu.CompilerParams``
in later releases; the fields the kernels use (``dimension_semantics``) are
identical.
"""

from jax.experimental.pallas import tpu as pltpu

try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:
    try:
        CompilerParams = pltpu.TPUCompilerParams
    except AttributeError as e:  # pre-dataclass jax versions
        raise ImportError(
            "this jax version exposes neither pltpu.CompilerParams nor "
            "pltpu.TPUCompilerParams; the Pallas kernels need jax >= 0.4.31"
        ) from e
