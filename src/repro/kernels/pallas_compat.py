"""Pallas API compatibility.

``pltpu.TPUCompilerParams`` (jax 0.4.x) was renamed ``pltpu.CompilerParams``
in later releases; the fields the kernels use (``dimension_semantics``) are
identical.

Also home of :func:`default_interpret` — every kernel in this package
resolves ``interpret=None`` through it, so direct callers get the Mosaic
lowering on TPU and the interpreter elsewhere without passing a flag.
"""

import functools

import jax
from jax.experimental.pallas import tpu as pltpu


@functools.cache
def default_interpret() -> bool:
    """True (interpret mode) unless a TPU backend is attached."""
    return not any(d.platform == "tpu" for d in jax.devices())

try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:
    try:
        CompilerParams = pltpu.TPUCompilerParams
    except AttributeError as e:  # pre-dataclass jax versions
        raise ImportError(
            "this jax version exposes neither pltpu.CompilerParams nor "
            "pltpu.TPUCompilerParams; the Pallas kernels need jax >= 0.4.31"
        ) from e
