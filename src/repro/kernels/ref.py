"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are tested against
(``tests/test_kernels.py`` sweeps shapes/dtypes and asserts allclose).  They
are deliberately written in the most obvious dense form — readability over
speed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, dequantize
from repro.core.sparsity import SparseQuantizedTensor, sparse_dequantize

__all__ = [
    "w4a16_matmul_ref",
    "sparse_w4a16_matmul_ref",
    "ffn_ref",
    "attention_ref",
    "decode_attention_ref",
    "mixed_attention_ref",
]


def w4a16_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Group-exact oracle of the FP16*INT4 unit (EdgeLLM MODE-1).

    Matches the kernel's numerics exactly: per 128-group integer-exact bf16
    matmul with f32 accumulation, scale applied to the per-group partial sum
    (the paper's Stage-3 Scale multiply).
    """
    in_f, out_f = qt.shape
    g = qt.group_size
    q = dequantize(
        QuantizedTensor(qt.packed, jnp.ones_like(qt.scales), qt.shape, g),
        jnp.bfloat16,
    )  # integer values, exactly representable in bf16
    xg = x.reshape(*x.shape[:-1], in_f // g, g)
    qg = q.reshape(in_f // g, g, out_f)
    # f32 upcast is exact for bf16 inputs; avoids CPU DotThunk gaps while
    # matching MXU bf16xbf16->f32 numerics bit for bit.
    partial = jnp.einsum(
        "...kg,kgo->...ko", xg.astype(jnp.float32), qg.astype(jnp.float32),
        preferred_element_type=jnp.float32)
    out = (partial * qt.scales.astype(jnp.float32)).sum(axis=-2)
    return out.astype(x.dtype)


def sparse_w4a16_matmul_ref(x: jax.Array, st: SparseQuantizedTensor) -> jax.Array:
    """Oracle for the block-sparse W4A16 matmul: dense matmul against the
    scattered-back dense weight, with per-group scale-after-dot numerics."""
    in_f, out_f = st.shape
    g = st.group_size
    w = sparse_dequantize(st, jnp.float32)
    # group-exact like the kernel: separate integer part and scale
    scales_full = jnp.zeros((in_f // g, out_f), jnp.float32)
    tiles = jnp.arange(out_f // g)
    # scatter per-block scales back to (n_blocks, out)
    sc = jnp.zeros((out_f // g, in_f // g, g), jnp.float32)
    sc = sc.at[tiles[:, None], st.block_idx].set(st.scales.astype(jnp.float32))
    scales_full = jnp.transpose(sc, (1, 0, 2)).reshape(in_f // g, out_f)
    safe = jnp.where(scales_full == 0, 1.0, scales_full)
    q = (w / jnp.repeat(safe, g, axis=0)).astype(jnp.bfloat16)
    xg = x.reshape(*x.shape[:-1], in_f // g, g)
    qg = q.reshape(in_f // g, g, out_f)
    partial = jnp.einsum(
        "...kg,kgo->...ko", xg.astype(jnp.float32), qg.astype(jnp.float32),
        preferred_element_type=jnp.float32)
    out = (partial * scales_full).sum(axis=-2)
    return out.astype(x.dtype)


def ffn_ref(
    x: jax.Array,
    gate,
    up,
    down,
    *,
    activation: str = "swiglu",
    up_bias: jax.Array | None = None,
    down_bias: jax.Array | None = None,
) -> jax.Array:
    """UNFUSED FFN oracle: three independent matmuls + XLA elementwise ops.

    Exactly the seed's ``mlp_apply`` composition (per-weight-type dispatch,
    activations in the compute dtype) — the numerics ground truth AND the
    bandwidth baseline ``benchmarks/ffn_bench.py`` measures the fused
    datapath against."""

    def mm(x_, w, b=None):
        if isinstance(w, QuantizedTensor):
            y = w4a16_matmul_ref(x_, w)
        elif isinstance(w, SparseQuantizedTensor):
            y = sparse_w4a16_matmul_ref(x_, w)
        else:
            ww = w.astype(x_.dtype) if w.dtype != x_.dtype else w
            y = jax.lax.dot_general(
                x_, ww, (((x_.ndim - 1,), (0,)), ((), ())))
            y = y.astype(x_.dtype)
        if b is not None:
            y = y + b.astype(y.dtype)
        return y

    if activation == "swiglu":
        h = jax.nn.silu(mm(x, gate)) * mm(x, up)
        return mm(h, down)
    if activation == "geglu":
        h = jax.nn.gelu(mm(x, gate), approximate=True) * mm(x, up)
        return mm(h, down)
    if activation == "gelu":
        h = jax.nn.gelu(mm(x, up, up_bias), approximate=True)
        return mm(h, down, down_bias)
    raise ValueError(f"unknown activation {activation!r}")


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    f32_softmax: bool = True,
) -> jax.Array:
    """Dense attention oracle (EdgeLLM MODE-0, FP16*FP16 path).

    Shapes: q (b, hq, sq, d), k/v (b, hkv, skv, d) with hq % hkv == 0 (GQA).
    ``window`` = sliding-window size (Mixtral SWA); None = full.
    Causal alignment assumes q occupies the *last* sq positions of the skv
    context (decode-friendly).
    """
    from repro.parallel.hints import hint

    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    if rep > 1:
        # jnp.repeat breaks SPMD head-sharding propagation — re-pin the
        # repeated K/V and the score matrix to the model axis (16x
        # replicated attention FLOPs otherwise; EXPERIMENTS.md §Perf it.1)
        k = hint(jnp.repeat(k, rep, axis=1), "batch", "heads", None, None)
        v = hint(jnp.repeat(v, rep, axis=1), "batch", "heads", None, None)
    q = hint(q, "batch", "heads", None, None)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = hint(logits, "batch", "heads", None, None)
    skv = k.shape[2]
    q_pos = jnp.arange(sq) + (skv - sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    if not f32_softmax:
        logits = logits.astype(q.dtype).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd",
                     probs.astype(q.dtype).astype(jnp.float32),
                     v.astype(jnp.float32))
    out = hint(out.astype(q.dtype), "batch", "heads", None, None)
    return out


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    length: jax.Array | int,
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Single-step decode attention oracle.

    q (b, hq, 1, d); caches (b, hkv, max_len, d); ``length`` = #valid tokens
    (the new token's position is length - 1).
    """
    from repro.parallel.hints import hint

    b, hq, _, d = q.shape
    hkv, max_len = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hkv
    # decode = flash-decoding layout: KV sequence stays sharded over the
    # model axis; the softmax reductions below become model-axis collectives.
    # GQA is a grouped einsum (q packed (b, hkv, rep, d)) — repeating K/V to
    # hq heads would stream rep x the cache bytes every step.
    k = hint(k_cache, "batch", None, "seq_mp", None)
    v = hint(v_cache, "batch", None, "seq_mp", None)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qg = q.reshape(b, hkv, rep, d)
    logits = jnp.einsum("bgrd,bgkd->bgrk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = hint(logits, "batch", None, None, "seq_mp")
    pos = jnp.arange(max_len)
    valid = pos[None, :] < jnp.asarray(length).reshape(-1, 1)
    if window is not None:
        valid &= pos[None, :] >= (jnp.asarray(length).reshape(-1, 1) - window)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrk,bgkd->bgrd",
                     probs.astype(q.dtype).astype(jnp.float32),
                     v.astype(jnp.float32))
    return out.reshape(b, hq, 1, d).astype(q.dtype)


def mixed_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    q_lens: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Mixed prefill/decode attention oracle (chunked q against the cache).

    q (b, hq, C, d); caches (b, hkv, max_len, d); ``lengths`` (b,) = valid
    context per row INCLUDING this step's chunk; ``q_lens`` (b,) = live
    queries per row (query j sits at position ``lengths - q_lens + j``;
    dead queries j >= q_lens return exact zeros).
    """
    b, hq, c, d = q.shape
    hkv, max_len = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    q_lens = jnp.broadcast_to(jnp.asarray(q_lens, jnp.int32).reshape(-1), (b,))
    qg = q.reshape(b, hkv, rep, c, d)
    logits = jnp.einsum("bgrqd,bgkd->bgrqk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(max_len)
    j = jnp.arange(c)
    q_pos = (lengths - q_lens)[:, None] + j[None, :]                  # (b, c)
    valid = (pos[None, None, :] < jnp.minimum(lengths, max_len)[:, None, None])
    valid &= pos[None, None, :] <= q_pos[:, :, None]                  # causal
    valid &= (j[None, :] < q_lens[:, None])[..., None]                # dead q
    if window is not None:
        valid &= pos[None, None, :] > q_pos[:, :, None] - window
    logits = jnp.where(valid[:, None, None], logits, -jnp.inf)
    # dead queries are all -inf rows: normalize against a safe l, return 0
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - jnp.maximum(m, -1e30))
    p = jnp.where(valid[:, None, None], p, 0.0)
    l = p.sum(axis=-1, keepdims=True)
    probs = p / jnp.where(l == 0, 1.0, l)
    out = jnp.einsum("bgrqk,bgkd->bgrqd",
                     probs.astype(q.dtype).astype(jnp.float32),
                     v_cache.astype(jnp.float32))
    return out.reshape(b, hq, c, d).astype(q.dtype)
