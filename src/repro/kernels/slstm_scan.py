"""Pallas TPU kernel: sLSTM recurrence with VMEM-resident weights.

The xlstm-1.3b train cell's dominant roofline term is the strictly
sequential sLSTM scan: in XLA-land each of the 4096 timesteps re-streams the
recurrent matrix R from HBM (measured: the memory term is ~10⁴ s/step for
the full train cell — EXPERIMENTS.md §Perf xlstm).  This kernel is the
designed fix: R is block-diagonal per head ((h, dh, 4·dh) ≈ 8 MB bf16 for
xlstm-1.3b), which FITS IN VMEM — so the kernel loads it once per grid
step and runs the whole time loop against the resident copy.  HBM traffic
collapses to the gates_x stream (read once) + hidden-state outputs.

This replays the paper's central lesson — "size the compute unit so the
memory system, not the schedule, is the limit" — on a layer the paper never
met: the FPGA keeps INT4 weights streaming from HBM at full rate; here we
keep recurrent weights OUT of HBM entirely.

Grid: (batch, L / Lc) with the time axis "arbitrary"; the (c, n, h, m)
state lives in VMEM scratch and persists across time chunks.  Numerics ==
``repro.models.xlstm._slstm_step`` scan (tested).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

__all__ = ["slstm_scan_pallas"]


def _kernel(gx_ref, r_ref, b_ref, out_ref, c_ref, n_ref, h_ref, m_ref,
            *, lc: int, heads: int, dh: int):
    t_chunk = pl.program_id(1)

    @pl.when(t_chunk == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        h_ref[...] = jnp.zeros_like(h_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    r = r_ref[...].astype(jnp.float32)              # (h, dh, 4dh) — resident
    bias = b_ref[...].astype(jnp.float32)           # (h, 4dh)

    def step(t, _):
        gx = gx_ref[0, t].astype(jnp.float32)       # (h, 4dh)
        hid = h_ref[...]
        recur = jax.lax.dot_general(
            hid[:, None, :], r, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)[:, 0, :]   # (h, 4dh)
        gates = gx + recur + bias
        z_t = jnp.tanh(gates[:, :dh])
        i_t = gates[:, dh:2 * dh]
        f_t = gates[:, 2 * dh:3 * dh]
        o_t = jax.nn.sigmoid(gates[:, 3 * dh:])
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m_ref[...], i_t)
        i_act = jnp.exp(i_t - m_new)
        f_act = jnp.exp(logf + m_ref[...] - m_new)
        c_new = f_act * c_ref[...] + i_act * z_t
        n_new = jnp.maximum(f_act * n_ref[...] + i_act, jnp.exp(-m_new))
        h_new = o_t * c_new / n_new
        c_ref[...] = c_new
        n_ref[...] = n_new
        h_ref[...] = h_new
        m_ref[...] = m_new
        out_ref[0, t] = h_new.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, lc, step, 0)


@functools.partial(jax.jit, static_argnames=("time_chunk", "interpret"))
def slstm_scan_pallas(
    gates_x: jax.Array,      # (b, L, h, 4*dh) — precomputed input gates
    r_gates: jax.Array,      # (h, dh, 4*dh) block-diagonal recurrent weights
    b_gates: jax.Array,      # (h, 4*dh)
    *,
    time_chunk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Returns hidden states (b, L, h, dh)."""
    b, L, heads, g4 = gates_x.shape
    dh = g4 // 4
    lc = min(time_chunk, L)
    if L % lc:
        raise ValueError(f"L={L} not a multiple of time_chunk={lc}")

    kernel = functools.partial(_kernel, lc=lc, heads=heads, dh=dh)
    out = pl.pallas_call(
        kernel,
        grid=(b, L // lc),
        in_specs=[
            pl.BlockSpec((1, lc, heads, g4), lambda i, t: (i, t, 0, 0)),
            pl.BlockSpec((heads, dh, g4), lambda i, t: (0, 0, 0)),
            pl.BlockSpec((heads, g4), lambda i, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lc, heads, dh), lambda i, t: (i, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, L, heads, dh), gates_x.dtype),
        scratch_shapes=[pltpu.VMEM((heads, dh), jnp.float32)] * 3
        + [pltpu.VMEM((heads, dh), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(gates_x, r_gates, b_gates)
    return out
