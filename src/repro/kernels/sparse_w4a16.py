"""Pallas TPU kernel: log-scale block-sparse W4A16 matmul (EdgeLLM §III-C).

The paper's sparse path: masks select which activation data enters the PE
array; power-of-two densities keep the PEs 100 % busy; HBM traffic shrinks
with density (Fig. 5).  TPU restatement:

* sparsity granularity = one 128-channel weight block shared across a
  128-wide output tile (DESIGN.md §2 — DBB "larger blocks" taken to MXU
  scale);
* the kept-block indices (the paper's address-in-block encoding) are scalars
  prefetched into SMEM via ``PrefetchScalarGridSpec``; the **activation
  BlockSpec's index_map reads them**, so the sparse gather happens in the
  DMA engine while the MXU runs the previous block — this is precisely the
  paper's "sparse DMA picks out the necessary activation data" mechanism;
* every surviving grid step is a dense (bt×128)·(128×128) MXU matmul →
  100 % utilization at any sparsity, the paper's core hardware claim;
* the grid simply has ``density × 8`` fewer contraction steps per group, so
  compute *and* weight traffic shrink together — on the FPGA this was the
  time-unrolled schedule, on TPU it is a shorter grid.

Numerics identical to the dense kernel: integer-exact bf16 MXU dot, f32
accumulation, per-block scale applied to the partial sum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import (
    CompilerParams, default_interpret, token_block)

from repro.core.quant import GROUP_SIZE
from repro.core.sparsity import SparseQuantizedTensor

__all__ = ["sparse_w4a16_matmul_pallas"]

_HALF = GROUP_SIZE // 2


def _unpack_block(packed_u8: jax.Array) -> jax.Array:
    lo = (packed_u8 & 0xF).astype(jnp.int8)
    hi = (packed_u8 >> 4).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    return jnp.concatenate([lo, hi], axis=0).astype(jnp.bfloat16)


def _kernel(idx_ref, x_ref, packed_ref, scale_ref, o_ref, acc_ref):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_block(packed_ref[0, 0])                    # (128, 128) bf16
    part = jax.lax.dot_general(
        x_ref[...], w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] += part * scale_ref[0].astype(jnp.float32)

    @pl.when(s == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_tokens", "interpret"))
def sparse_w4a16_matmul_pallas(
    x: jax.Array,
    st: SparseQuantizedTensor,
    *,
    block_tokens: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """``x @ sparse_dequant(st)`` via the scalar-prefetch block-gather kernel.

    ``x``: (..., tokens, in_features).  Out tile fixed at 128 (= sparsity
    granularity); contraction grid has S = density * n_blocks steps.
    ``interpret=None`` derives from the backend (Mosaic on TPU).
    """
    if interpret is None:
        interpret = default_interpret()
    in_f, out_f = st.shape
    *lead, tokens, xin = x.shape
    if xin != in_f:
        raise ValueError(f"contraction mismatch {xin} vs {in_f}")
    x2 = x.reshape(-1, in_f)
    n_tok = x2.shape[0]
    bt = token_block(n_tok, block_tokens)  # exact fit at decode, no 8-row pad
    pad = (-n_tok) % bt
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))

    out_tiles, S = st.block_idx.shape
    grid = (x2.shape[0] // bt, out_tiles, S)

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # activation block chosen by the prefetched kept-block index
                pl.BlockSpec(
                    (bt, GROUP_SIZE),
                    lambda t, o, s, idx_ref: (t, idx_ref[o, s])),
                pl.BlockSpec(
                    (1, 1, _HALF, GROUP_SIZE),
                    lambda t, o, s, idx_ref: (o, s, 0, 0)),
                pl.BlockSpec(
                    (1, 1, GROUP_SIZE),
                    lambda t, o, s, idx_ref: (o, s, 0)),
            ],
            out_specs=pl.BlockSpec(
                (bt, GROUP_SIZE), lambda t, o, s, idx_ref: (t, o)),
            scratch_shapes=[pltpu.VMEM((bt, GROUP_SIZE), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], out_f), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(st.block_idx, x2, st.packed, st.scales)
    if pad:
        out = out[:n_tok]
    return out.reshape(*lead, tokens, out_f)
