"""Pallas TPU kernel: dense W4A16 block-quant matmul (EdgeLLM MODE-1).

The paper's FP16*INT4 PE array, restated for the MXU:

* weights live in HBM as packed int4 nibbles (2/byte) + one 16-bit scale per
  128-channel group — the paper's scale/wt package;
* each grid step streams one 128-deep weight block into VMEM, unpacks it with
  one mask + one shift + one sublane concat (the sublane-pair packing from
  ``core.quant``), and runs a fully dense (bt×128)·(128×bo) MXU matmul;
* int4 values are *exactly* representable in bf16, so the matmul is
  integer-exact; the per-group FP16 scale multiplies the **partial sum**
  (paper Fig. 4 Stage-3 "Scale value" multiply) — numerically identical to
  the FPGA's keep-full-mantissa-then-rescale datapath, and strictly more
  accurate than dequantize-to-bf16-then-dot;
* the accumulator stays resident in a VMEM scratch across the contraction
  grid axis — the G-VSA "partial sums never leave the array" property.

Roofline intent (paper Fig. 3): at decode (bt small) the kernel moves
``in·out/2`` weight bytes + ``in·out/64`` scale bytes per call and does
``2·bt·in·out`` FLOPs — arithmetic intensity ≈ bt·4 FLOP/byte, memory-bound
until bt ≈ 100, exactly the regime the paper sizes its PE bandwidth for.

VMEM budget per step: x (bt·128·2) + packed (64·bo) + scales (2·bo) + acc
(bt·bo·4) bytes; defaults (bt=256, bo=512) ≈ 1.1 MB « 16 MB v5e VMEM,
leaving room for Mosaic's double buffering of the streamed weight blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import (
    CompilerParams, default_interpret, token_block)

from repro.core.quant import GROUP_SIZE, QuantizedTensor

__all__ = ["w4a16_matmul_pallas"]

_HALF = GROUP_SIZE // 2  # 64 packed rows per 128-row group


def _unpack_group(packed_u8: jax.Array) -> jax.Array:
    """(64, bo) uint8 nibbles -> (128, bo) int4 values as bf16 (exact)."""
    lo = (packed_u8 & 0xF).astype(jnp.int8)
    hi = (packed_u8 >> 4).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    return jnp.concatenate([lo, hi], axis=0).astype(jnp.bfloat16)


def _kernel(x_ref, packed_ref, scale_ref, o_ref, acc_ref):
    g = pl.program_id(2)

    @pl.when(g == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_group(packed_ref[...])                     # (128, bo) bf16, integer-exact
    part = jax.lax.dot_general(
        x_ref[...], w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                      # (bt, bo) f32
    acc_ref[...] += part * scale_ref[...].astype(jnp.float32)  # (1, bo) scale bcast

    @pl.when(g == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_tokens", "block_out", "interpret"))
def w4a16_matmul_pallas(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    block_tokens: int = 256,
    block_out: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """``x @ dequant(qt)`` via the Pallas MODE-1 kernel.

    ``x``: (..., tokens, in_features) bf16/f16/f32.  Returns x.dtype.
    ``interpret=None`` derives from the backend (Mosaic on TPU, interpreter
    elsewhere), so direct callers never run the interpreter on TPU.
    """
    if interpret is None:
        interpret = default_interpret()
    in_f, out_f = qt.shape
    if qt.group_size != GROUP_SIZE:
        raise ValueError("kernel assumes 128-channel groups")
    *lead, tokens, xin = x.shape
    if xin != in_f:
        raise ValueError(f"contraction mismatch {xin} vs {in_f}")
    x2 = x.reshape(-1, in_f)
    n_tok = x2.shape[0]

    bt = token_block(n_tok, block_tokens)  # exact fit at decode, no 8-row pad
    # pad tokens to a multiple of bt
    pad = (-n_tok) % bt
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    bo = min(block_out, out_f)
    if out_f % bo:
        raise ValueError(f"out_features {out_f} not a multiple of block_out {bo}")
    n_groups = in_f // GROUP_SIZE

    grid = (x2.shape[0] // bt, out_f // bo, n_groups)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, GROUP_SIZE), lambda t, o, g: (t, g)),
            pl.BlockSpec((_HALF, bo), lambda t, o, g: (g, o)),
            pl.BlockSpec((1, bo), lambda t, o, g: (g, o)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda t, o, g: (t, o)),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], out_f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bo), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x2, qt.packed, qt.scales)
    if pad:
        out = out[:n_tok]
    return out.reshape(*lead, tokens, out_f)
