"""Memory-efficient attention in pure XLA (the dry-run / CPU twin of the
Pallas flash kernel).

Dense ``softmax(QKᵀ)V`` materializes the (sq × skv) score matrix — at the
prefill_32k cell that is up to 1.5 TB/device of temporaries (measured,
EXPERIMENTS.md §Perf it.6).  This implementation is the standard
flash-attention recurrence expressed with ``lax.scan`` over KV chunks:

* outer loop over Q chunks is a *python* loop, so each Q chunk gets its own
  statically-sized KV scan — causal masking prunes whole KV chunks at trace
  time (true FLOP skipping, like the Pallas kernel's ``pl.when`` guard),
  and sliding windows (Mixtral SWA) prune both ends;
* the inner scan carries (m, l, acc) running softmax statistics in f32;
* peak temp = O(sq_chunk × kv_chunk) per head — a few hundred MB at 32k
  instead of hundreds of GB.

Numerics match ``attention_ref`` to bf16 tolerance (tested in
tests/test_kernels.py::TestXlaChunkedAttention).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.parallel.hints import hint

_NEG_INF = -1e30


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    chunk_q: int = 1024,
    chunk_kv: int = 1024,
) -> jax.Array:
    """q (b,hq,sq,d), k/v (b,hkv,skv,d); GQA via repeat.  Causal alignment:
    q occupies the last sq positions of the skv context."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = hq // hkv
    if rep > 1:
        k = hint(jnp.repeat(k, rep, axis=1), "batch", "heads", None, None)
        v = hint(jnp.repeat(v, rep, axis=1), "batch", "heads", None, None)
    q = hint(q, "batch", "heads", None, None)
    scale_v = scale if scale is not None else float(1.0 / (d ** 0.5))
    q_offset = skv - sq

    cq = min(chunk_q, sq)
    ck = min(chunk_kv, skv)
    # pad seq dims to chunk multiples
    pad_q = (-sq) % cq
    pad_k = (-skv) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = q.shape[2] // cq
    n_k = k.shape[2] // ck

    qf = q.astype(jnp.float32)

    def q_chunk_out(iq: int) -> jax.Array:
        q_blk = jax.lax.dynamic_slice_in_dim(qf, iq * cq, cq, axis=2)
        q_start = q_offset + iq * cq
        q_end = q_start + cq - 1
        # static chunk pruning (trace-time): causal upper bound, window lower
        hi = n_k if not causal else min(n_k, (q_end // ck) + 1)
        lo = 0
        if window is not None:
            lo = max(0, (q_start - window + 1) // ck)
        hi = max(hi, lo + 1)
        idxs = jnp.arange(lo, hi)

        def body(carry, ik):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ik * ck, ck, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ik * ck, ck, axis=2)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk,
                           k_blk.astype(jnp.float32)) * scale_v
            q_pos = q_start + jnp.arange(cq)
            k_pos = ik * ck + jnp.arange(ck)
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            # mask out kv padding
            mask &= (k_pos < skv)[None, :]
            s = jnp.where(mask[None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v_blk.dtype),
                            v_blk).astype(jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hq, cq), _NEG_INF, jnp.float32),
            jnp.zeros((b, hq, cq), jnp.float32),
            jnp.zeros((b, hq, cq, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(body, init, idxs)
        l = jnp.where(l == 0, 1.0, l)
        return (acc / l[..., None]).astype(q.dtype)

    outs = [q_chunk_out(i) for i in range(n_q)]
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
    if pad_q:
        out = out[:, :, :sq]
    return hint(out, "batch", "heads", None, None)
