"""Memory-efficient attention in pure XLA (the dry-run / CPU twin of the
Pallas flash kernel).

Dense ``softmax(QKᵀ)V`` materializes the (sq × skv) score matrix — at the
prefill_32k cell that is up to 1.5 TB/device of temporaries (measured,
EXPERIMENTS.md §Perf it.6).  This implementation is the standard
flash-attention recurrence expressed with ``lax.scan`` over KV chunks:

* outer loop over Q chunks is a *python* loop, so each Q chunk gets its own
  statically-sized KV scan — causal masking prunes whole KV chunks at trace
  time (true FLOP skipping, like the Pallas kernel's ``pl.when`` guard),
  and sliding windows (Mixtral SWA) prune both ends;
* the inner scan carries (m, l, acc) running softmax statistics in f32;
* peak temp = O(sq_chunk × kv_chunk) per head — a few hundred MB at 32k
  instead of hundreds of GB.

Numerics match ``attention_ref`` to bf16 tolerance (tested in
tests/test_kernels.py::TestXlaChunkedAttention).

This module also holds the *decode* twin: ``decode_attention_blocked`` runs
the same (m, l, acc) recurrence over KV blocks of a preallocated MAX-token
cache, but with a ``lax.while_loop`` whose trip count is
``ceil(max(lengths)/bk)`` — compute scales with the *actual* batched context
instead of MAX (the Pallas kernel in ``decode_flash.py`` additionally skips
per-row).  ``mixed_attention_blocked`` is the chunked-prefill generalization
of the same loop: per-row ``q_lens`` queries per step (1 for decoding rows,
C for rows mid-prefill) with intra-chunk causal masking, so one dispatch
advances a mixed prefill/decode batch.  Both run on the shared block walker
``decode_blocked_partials``; its per-block inner, ``decode_softmax_partials``,
is shared with the shard_map path (``parallel/decode_attn.py``): one
numerics contract — grouped-einsum GQA (never ``jnp.repeat`` of the cache)
and int8-KV scale-after-dot — on every decode path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.hints import hint

_NEG_INF = -1e30
DEFAULT_DECODE_BLOCK_KV = 256  # KV tile of the blocked decode while_loop


def decode_softmax_partials(
    q5: jax.Array,
    k: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    *,
    scale: float,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Flash-decoding partial stats over one KV slice.

    ``q5`` (b, g, r, sq, d) — GQA query group packed per KV head (sq = 1 for
    plain decode, C for a prefill chunk); ``k``/``v`` (b, g, t, d) in fp or
    int8; ``valid`` (b, t) bool — or (b, sq, t) for per-query masks (chunked
    causal); ``k_scale``/``v_scale`` (b, g, t) f32 for int8 KV
    (scale-after-dot, Fig. 4 Stage-3).  Returns ``(m, l, acc)`` of shapes
    (b,g,r,sq), (b,g,r,sq), (b,g,r,sq,d) — ready for the log-sum-exp merge
    (across blocks or across sequence shards).
    """
    if valid.ndim == 2:
        vmask = valid[:, None, None, None, :]
    else:
        vmask = valid[:, None, None, :, :]
    if k_scale is not None:
        logits = jnp.einsum("bgrqd,bgkd->bgrqk", q5, k.astype(q5.dtype),
                            preferred_element_type=jnp.float32)
        logits = logits * k_scale[:, :, None, None, :] * scale
    else:
        logits = jnp.einsum("bgrqd,bgkd->bgrqk", q5.astype(k.dtype), k,
                            preferred_element_type=jnp.float32) * scale
    logits = jnp.where(vmask, logits, _NEG_INF)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(vmask, p, 0.0)
    l = p.sum(axis=-1)
    if v_scale is not None:
        pv = (p * v_scale[:, :, None, None, :]).astype(q5.dtype)
        acc = jnp.einsum("bgrqk,bgkd->bgrqd", pv, v.astype(q5.dtype),
                         preferred_element_type=jnp.float32)
    else:
        acc = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
    return m, l, acc


def decode_blocked_partials(
    q5: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    n_valid: jax.Array,
    *,
    scale: float,
    q_pos: jax.Array | None = None,
    window: int | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    block_kv: int = DEFAULT_DECODE_BLOCK_KV,
    page_table: jax.Array | None = None,
    block_home: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Flash-decoding partials over a blocked KV walk (the shared loop).

    ``q5`` (b, g, rep, sq, d); caches (b, g, T, d); ``n_valid`` (b,) = number
    of valid leading cache positions per row; ``q_pos`` (b, sq) = absolute
    position of each query (enables intra-chunk causal + per-query window
    masking; a negative entry marks a dead query — everything masked, l == 0),
    or None when every query may see every valid position (the shard-local
    partial case).  ``k_scale``/``v_scale`` (b, g, T) f32 for int8 KV.

    Paged layout: ``page_table`` (b, n_pages) of physical block ids turns
    each walk step into a pool gather — caches are shared pools
    ``(P, g, bs, d)`` (scales ``(P, g, bs)``), the KV tile IS the page size,
    and logical block ``ib`` of row ``b`` reads ``pool[page_table[b, ib]]``.
    Entries past a row's live range point at the null block; its data is
    finite and fully masked, so partials stay bit-identical to the
    contiguous walk over the same token values.

    Sharded pools (the shard_map paged path): ``block_home`` is the first
    GLOBAL pool row this caller holds — the pool operand is one shard's
    contiguous run of ``k_cache.shape[0]`` "home" rows out of the full pool.
    Table entries are still global ids; each is translated to a home-local
    row, and blocks homed on OTHER shards are masked to exact zeros (their
    gather index is clamped in-range, the validity mask kills the values),
    so every logical block is counted by exactly one shard and the partials
    are ready for the cross-shard log-sum-exp merge.

    A ``lax.while_loop`` walks KV blocks and stops after the last block any
    row still needs, so bytes and FLOPs scale with ``max(n_valid)`` instead
    of T.  Blocks a row has outgrown contribute exact zeros (masked p) and
    exact-1 rescales, so the partials are bit-identical whatever the
    batch-max trip count — batched results can't drift from batch-1.
    Returns ``(m, l, acc)`` of shapes (b,g,rep,sq)/(b,g,rep,sq)/(b,g,rep,sq,d)
    ready for the log-sum-exp merge (with other blocks or sequence shards).
    """
    b, g, rep, sq, d = q5.shape
    if page_table is not None:
        # the pool's block extent is the page size; max_len is the page
        # table's addressable span (bs always divides it by construction)
        bk = k_cache.shape[2]
        max_len = page_table.shape[1] * bk
    else:
        max_len = k_cache.shape[2]
        # bk need not divide max_len: the final block's slice start is clamped
        # and its already-covered positions masked out (dynamic_slice can't
        # overrun, and exactness survives because masked p is exactly 0)
        bk = min(block_kv, max_len)
    n_valid = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32).reshape(-1), (b,))

    n_live = (jnp.max(n_valid) + bk - 1) // bk              # traced trip count
    if window is None or q_pos is None:
        start = jnp.int32(0)
    else:
        # first block any query's window reaches (dead queries pull the min
        # toward 0 — conservative, never wrong)
        start = jnp.maximum(jnp.min(q_pos) - window + 1, 0) // bk
    pos_base = jnp.arange(bk)

    def body(carry):
        ib, m, l, acc = carry
        block_start = ib * bk
        if page_table is not None:
            # logical → physical: gather each row's block from the pool
            ids = jax.lax.dynamic_slice_in_dim(
                page_table, ib, 1, axis=1)[:, 0]            # (b,)
            if block_home is not None:
                # global id → home-local row; non-home blocks clamp to a
                # resident row and are fully masked below
                local_rows = k_cache.shape[0]
                ids = ids - block_home
                in_home = (ids >= 0) & (ids < local_rows)
                ids = jnp.clip(ids, 0, local_rows - 1)
            else:
                in_home = None
            kb = jnp.take(k_cache, ids, axis=0)             # (b, g, bk, d)
            vb = jnp.take(v_cache, ids, axis=0)
            ksb = None if k_scale is None else jnp.take(k_scale, ids, axis=0)
            vsb = None if v_scale is None else jnp.take(v_scale, ids, axis=0)
            pos = block_start + pos_base
        else:
            off = jnp.minimum(block_start, max_len - bk)   # clamp final block
            kb = jax.lax.dynamic_slice_in_dim(k_cache, off, bk, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(v_cache, off, bk, axis=2)
            ksb = None if k_scale is None else jax.lax.dynamic_slice_in_dim(
                k_scale, off, bk, axis=2)
            vsb = None if v_scale is None else jax.lax.dynamic_slice_in_dim(
                v_scale, off, bk, axis=2)
            pos = off + pos_base
        # mask positions a clamped final block re-covers (pos < block_start)
        valid = (pos[None, :] >= block_start) & \
                (pos[None, :] < n_valid[:, None])           # (b, bk)
        if page_table is not None and in_home is not None:
            valid &= in_home[:, None]
        if q_pos is not None:
            valid = valid[:, None, :] & \
                (pos[None, None, :] <= q_pos[:, :, None])   # (b, sq, bk)
            if window is not None:
                valid &= pos[None, None, :] > (q_pos[:, :, None] - window)
        mb, lb, accb = decode_softmax_partials(
            q5, kb, vb, valid, scale=scale, k_scale=ksb, v_scale=vsb)
        m_new = jnp.maximum(m, mb)
        alpha = jnp.exp(m - m_new)
        beta = jnp.exp(mb - m_new)
        l_new = l * alpha + lb * beta
        acc_new = acc * alpha[..., None] + accb * beta[..., None]
        return ib + 1, m_new, l_new, acc_new

    init = (start,
            jnp.full((b, g, rep, sq), _NEG_INF, jnp.float32),
            jnp.zeros((b, g, rep, sq), jnp.float32),
            jnp.zeros((b, g, rep, sq, d), jnp.float32))
    _, m, l, acc = jax.lax.while_loop(lambda c: c[0] < n_live, body, init)
    return m, l, acc


def decode_attention_blocked(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    block_kv: int = DEFAULT_DECODE_BLOCK_KV,
    page_table: jax.Array | None = None,
) -> jax.Array:
    """Length-blocked decode attention (the XLA hot path).

    Same contract as ``decode_flash_attention_pallas``: q (b, hq, 1, d),
    caches (b, hkv, MAX, d), ``lengths`` scalar or (b,).  With
    ``page_table`` (b, n_pages) the caches are shared pools
    ``(P, hkv, bs, d)`` and each walk step gathers the row's physical block.
    A while_loop walks KV blocks and stops after the last block any row
    still needs, so a 128-token context in a 2048-slot cache does 1/16th of
    the dense ref's work — see ``decode_blocked_partials`` for the
    exactness argument.
    """
    b, hq, sq, d = q.shape
    hkv = k_cache.shape[1]
    paged = page_table is not None
    max_len = (page_table.shape[1] * k_cache.shape[2] if paged
               else k_cache.shape[2])
    rep = hq // hkv
    scale_v = scale if scale is not None else float(1.0 / (d ** 0.5))
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))

    if not paged:
        # pool leaves have no (batch, seq) axes to hint; the sharded decode
        # path stays on the slot layout
        k_cache = hint(k_cache, "batch", None, "seq_mp", None)
        v_cache = hint(v_cache, "batch", None, "seq_mp", None)
    q5 = q.reshape(b, hkv, rep, 1, d)
    scale_shape = (k_cache.shape[0], hkv, k_cache.shape[2]) if paged else \
        (b, hkv, max_len)
    ks3 = None if k_scale is None else k_scale.reshape(scale_shape)
    vs3 = None if v_scale is None else v_scale.reshape(scale_shape)

    _, l, acc = decode_blocked_partials(
        q5, k_cache, v_cache, jnp.clip(lengths, 0, max_len),
        scale=scale_v, q_pos=(lengths - 1)[:, None], window=window,
        k_scale=ks3, v_scale=vs3, block_kv=block_kv, page_table=page_table)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def mixed_attention_blocked(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    q_lens: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    block_kv: int = DEFAULT_DECODE_BLOCK_KV,
    page_table: jax.Array | None = None,
) -> jax.Array:
    """Mixed prefill/decode attention: per-row variable query counts.

    q (b, hq, C, d) — C is the chunk bucket; row b's valid queries are
    ``q[:, :, :q_lens[b]]`` (the rest is padding and returns zeros).
    ``lengths`` (b,) = total valid context per row INCLUDING the chunk, so
    query j of row b sits at absolute position ``lengths[b] - q_lens[b] + j``
    and attends causally: cache positions ``<=`` its own.  ``q_lens[b] == 1``
    is exactly single-token decode; a decoding row and a mid-prefill row
    coexist in one dispatch — the serving tick's mixed batch.  With
    ``page_table`` the caches are shared pools (paged layout).
    """
    b, hq, c, d = q.shape
    hkv = k_cache.shape[1]
    paged = page_table is not None
    max_len = (page_table.shape[1] * k_cache.shape[2] if paged
               else k_cache.shape[2])
    rep = hq // hkv
    scale_v = scale if scale is not None else float(1.0 / (d ** 0.5))
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    q_lens = jnp.broadcast_to(
        jnp.asarray(q_lens, jnp.int32).reshape(-1), (b,))

    if not paged:
        k_cache = hint(k_cache, "batch", None, "seq_mp", None)
        v_cache = hint(v_cache, "batch", None, "seq_mp", None)
    q5 = q.reshape(b, hkv, rep, c, d)
    scale_shape = (k_cache.shape[0], hkv, k_cache.shape[2]) if paged else \
        (b, hkv, max_len)
    ks3 = None if k_scale is None else k_scale.reshape(scale_shape)
    vs3 = None if v_scale is None else v_scale.reshape(scale_shape)

    j = jnp.arange(c)
    q_pos = (lengths - q_lens)[:, None] + j[None, :]         # (b, C)
    q_pos = jnp.where(j[None, :] < q_lens[:, None], q_pos, -1)  # dead queries

    _, l, acc = decode_blocked_partials(
        q5, k_cache, v_cache, jnp.clip(lengths, 0, max_len),
        scale=scale_v, q_pos=q_pos, window=window,
        k_scale=ks3, v_scale=vs3, block_kv=block_kv, page_table=page_table)
    # dead queries have l == 0 (everything masked) -> exact zeros out
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, c, d).astype(q.dtype)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    chunk_q: int = 1024,
    chunk_kv: int = 1024,
) -> jax.Array:
    """q (b,hq,sq,d), k/v (b,hkv,skv,d); GQA via repeat.  Causal alignment:
    q occupies the last sq positions of the skv context."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = hq // hkv
    # GQA via grouped einsum — repeating K/V to hq heads would materialize
    # rep x the cache bytes per layer (see decode_softmax_partials)
    k = hint(k, "batch", "heads", None, None)
    v = hint(v, "batch", "heads", None, None)
    q = hint(q, "batch", "heads", None, None)
    scale_v = scale if scale is not None else float(1.0 / (d ** 0.5))
    q_offset = skv - sq

    cq = min(chunk_q, sq)
    ck = min(chunk_kv, skv)
    # pad seq dims to chunk multiples
    pad_q = (-sq) % cq
    pad_k = (-skv) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = q.shape[2] // cq
    n_k = k.shape[2] // ck

    # GQA group packing: (b, hkv, rep, sq_padded, d)
    qf = q.reshape(b, hkv, rep, q.shape[2], d).astype(jnp.float32)

    def q_chunk_out(iq: int) -> jax.Array:
        q_blk = jax.lax.dynamic_slice_in_dim(qf, iq * cq, cq, axis=3)
        q_start = q_offset + iq * cq
        q_end = q_start + cq - 1
        # static chunk pruning (trace-time): causal upper bound, window lower
        hi = n_k if not causal else min(n_k, (q_end // ck) + 1)
        lo = 0
        if window is not None:
            lo = max(0, (q_start - window + 1) // ck)
        hi = max(hi, lo + 1)
        idxs = jnp.arange(lo, hi)

        def body(carry, ik):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ik * ck, ck, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ik * ck, ck, axis=2)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", q_blk,
                           k_blk.astype(jnp.float32)) * scale_v
            q_pos = q_start + jnp.arange(cq)
            k_pos = ik * ck + jnp.arange(ck)
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            # mask out kv padding
            mask &= (k_pos < skv)[None, :]
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(v_blk.dtype),
                            v_blk).astype(jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, rep, cq), _NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, rep, cq), jnp.float32),
            jnp.zeros((b, hkv, rep, cq, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(body, init, idxs)
        l = jnp.where(l == 0, 1.0, l)
        out = (acc / l[..., None]).astype(q.dtype)
        return out.reshape(b, hq, cq, d)

    outs = [q_chunk_out(i) for i in range(n_q)]
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
    if pad_q:
        out = out[:, :, :sq]
    return hint(out, "batch", "heads", None, None)
