import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and dump memory/cost/collective artifacts.

The two lines above MUST stay first — jax locks the device count at first
init.  Do not import this module from tests (it would poison their device
count); it is a __main__ entry point only.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k \
        [--multi-pod] [--quant dense|strategy2|none] [--out artifacts/]
    python -m repro.launch.dryrun --all [--multi-pod] --out artifacts/
        (spawns one subprocess per cell for isolation)

Each cell writes ``<out>/<arch>__<shape>__<mesh>__<quant>.json`` with:
memory_analysis, cost_analysis (per-device FLOPs/bytes), collective stats
parsed from the optimized HLO, the three roofline terms and MODEL_FLOPS.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, quant: str | None,
             outdir: str, accum_steps: int = 8, remat: str | None = None,
             tag_suffix: str = "", kv_quant: str | None = None) -> dict:
    import jax
    from repro.configs import get_config, skip_reason
    from repro.configs.shapes import SHAPES
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis as ra

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape}__{mesh_name}__{quant or 'bf16'}{tag_suffix}"
    record: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "quant": quant or "bf16", "status": "pending",
        "accum_steps": accum_steps, "remat": remat, "tag": tag,
    }

    reason = skip_reason(arch, shape)
    if reason:
        record.update(status="skipped", reason=reason)
        _dump(outdir, tag, record)
        return record

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.size
        overrides = {}
        if remat:
            overrides["remat"] = remat
        if kv_quant:
            overrides["kv_quant"] = kv_quant
        cfg = get_config(arch, **overrides)
        cell = SHAPES[shape]
        t0 = time.time()
        bundle = steps.build_cell(arch, shape, mesh, quant=quant,
                                  accum_steps=accum_steps,
                                  cfg_overrides=overrides or None)
        lowered = steps.lower_cell(bundle, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        print(f"[{tag}] memory_analysis:", mem, flush=True)
        cost = compiled.cost_analysis()
        print(f"[{tag}] cost_analysis flops={cost.get('flops')} "
              f"bytes={cost.get('bytes accessed')}", flush=True)
        hlo = compiled.as_text()
        # loop-aware HLO cost model (cost_analysis counts while bodies once)
        from repro.roofline.hlo_parser import analyze_hlo
        parsed = analyze_hlo(hlo)

        roof = ra.Roofline(
            flops=float(parsed["flops"]),
            hbm_bytes=float(parsed["mem_bytes"]),
            collective_bytes=float(parsed["collective_wire_bytes"]),
        )
        record.update(
            status="ok",
            n_devices=n_dev,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory_analysis={
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "peak_bytes_est": int(mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes),
            },
            cost_analysis={k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float)) and not k.startswith("bytes accessed operand")},
            hlo_cost={k: v for k, v in parsed.items() if k != "collectives"},
            collectives=parsed["collectives"],
            roofline=roof.as_dict(),
            model_flops=ra.model_flops(cfg, cell, n_dev),
            hlo_bytes=len(hlo),
        )
        if os.environ.get("REPRO_SAVE_HLO"):
            import zstandard
            os.makedirs(outdir, exist_ok=True)
            with open(os.path.join(outdir, f"{tag}.hlo.zst"), "wb") as fz:
                fz.write(zstandard.ZstdCompressor(level=9).compress(
                    hlo.encode()))
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    _dump(outdir, tag, record)
    return record


def _dump(outdir: str, tag: str, record: dict) -> None:
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, f"{tag}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    print(f"[{tag}] -> {record['status']} ({path})", flush=True)


def _spawn_all(multi_pod: bool, quant: str | None, outdir: str,
               skip_existing: bool, jobs: int = 1) -> None:
    from repro.configs import ARCHS
    from repro.configs.shapes import SHAPES

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    procs: list = []
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{mesh_name}__{quant or 'bf16'}"
        path = os.path.join(outdir, f"{tag}.json")
        if skip_existing and os.path.exists(path):
            try:
                with open(path) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        print(f"[{tag}] cached, skipping", flush=True)
                        continue
            except Exception:
                pass
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", outdir]
        if multi_pod:
            cmd.append("--multi-pod")
        if quant:
            cmd += ["--quant", quant]
        while len([p for p in procs if p.poll() is None]) >= jobs:
            time.sleep(2)
        print(f"[driver] launching {tag}", flush=True)
        procs.append(subprocess.Popen(cmd))
    for p in procs:
        p.wait()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default="default",
                    help="none|dense|strategy1|strategy2|strategy3; "
                         "default = dense for serve shapes")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--no-skip-existing", action="store_true")
    ap.add_argument("--accum", type=int, default=8)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--kv-quant", default=None, help="none|int8 KV cache")
    args = ap.parse_args()

    quant = {"default": "dense", "none": None}.get(args.quant, args.quant)
    if args.all:
        _spawn_all(args.multi_pod, quant, args.out,
                   skip_existing=not args.no_skip_existing, jobs=args.jobs)
        return
    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all)")
    rec = run_cell(args.arch, args.shape, args.multi_pod, quant, args.out,
                   accum_steps=args.accum, remat=args.remat,
                   tag_suffix=args.tag, kv_quant=args.kv_quant)
    if rec["status"] == "error":
        print(rec.get("traceback", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
