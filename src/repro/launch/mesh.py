"""Production mesh construction.

Functions, not module-level constants, so importing never touches jax device
state (required by the dry-run protocol).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod: (data=16, model=16); two pods: (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
