"""Serving launcher: ``python -m repro.launch.serve --arch qwen-7b ...``

Builds a quantized model (the paper's compiler), starts the slot-based
continuous-batching engine and runs a synthetic request workload — the
container-scale stand-in for the paper's LAN client/server deployment.
One jitted decode call advances all slots per step; finished rows are
evicted and refilled from the queue mid-flight.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.compiler import quantize_model, quantized_bytes
from repro.models import api
from repro.serving.engine import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen-7b")
    ap.add_argument("--strategy", default="strategy2",
                    choices=["none", "dense", "strategy1", "strategy2",
                             "strategy3"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--kv-layout", default="slot", choices=["slot", "paged"],
                    help="paged = shared block pool + per-slot page tables")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="shared-pool blocks (0 = batch * pages per slot)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding: prompt-lookup drafts "
                         "verified through the mixed dispatch")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per verify row (with --spec)")
    ap.add_argument("--drafter", default="plookup",
                    help="draft proposer registry name (serving/draft.py)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="share cached prompt-prefix KV blocks across "
                         "requests (paged transformer families)")
    ap.add_argument("--system-prompt-len", type=int, default=24,
                    help="shared synthetic system-prompt tokens prepended "
                         "to every request (exercises --prefix-cache)")
    # resilience / lifecycle knobs (ISSUE 8)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline in seconds after submit; "
                         "expired requests finish status=deadline_missed "
                         "(queued or mid-flight)")
    ap.add_argument("--priority", type=int, default=0,
                    help="priority assigned to every synthetic request "
                         "(higher admits/keeps first under preemption)")
    ap.add_argument("--max-preemptions", type=int, default=0,
                    help="evict-and-requeue bound per request; 0 disables "
                         "preemption (stall-only admission, the old "
                         "behavior).  Preemption is lossless: accepted "
                         "output folds into the prompt and, under "
                         "--prefix-cache, the victim's KV blocks are "
                         "donated so re-admission is a page-table copy")
    ap.add_argument("--audit-every", type=int, default=0,
                    help="run Engine.audit() (allocator partition, "
                         "reservation, page-table coherence) every N "
                         "ticks; 0 disables")
    ap.add_argument("--chaos", action="store_true",
                    help="attach a seeded ChaosMonkey (serving/chaos.py): "
                         "deterministic fault injection into this run")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-deny-rate", type=float, default=0.05,
                    help="P(reservation denied) per admission attempt")
    ap.add_argument("--chaos-preempt-rate", type=float, default=0.05,
                    help="P(forced preemption) per tick (needs "
                         "--max-preemptions > 0)")
    ap.add_argument("--chaos-nan-rate", type=float, default=0.01,
                    help="P(logits row -> NaN) per advancing row; faulted "
                         "rows quarantine with status=error")
    # durability knobs (ISSUE 9)
    ap.add_argument("--snapshot-dir", default=None,
                    help="durable state root: atomic point-in-time engine "
                         "snapshots plus a write-ahead request journal, "
                         "fsync'd once per tick")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot every N ticks (0 = only the baseline "
                         "snapshot at startup; needs --snapshot-dir)")
    ap.add_argument("--restore", action="store_true",
                    help="recover the engine from --snapshot-dir (latest "
                         "complete snapshot + journal replay) instead of "
                         "starting fresh; in-flight requests resume and no "
                         "new synthetic requests are submitted")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.restore and not args.snapshot_dir:
        ap.error("--restore requires --snapshot-dir")

    kv = dict(kv_layout=args.kv_layout, kv_block_size=args.kv_block_size,
              kv_pool_blocks=args.kv_pool_blocks)
    cfg = (get_config(args.arch, **kv) if args.full
           else get_smoke_config(args.arch, **kv))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    if args.strategy != "none":
        params = quantize_model(params, args.strategy)
    print(f"arch={cfg.name} packed={quantized_bytes(params)/1e6:.1f} MB "
          f"strategy={args.strategy}")

    chaos = None
    if args.chaos:
        from repro.serving.chaos import ChaosConfig, ChaosMonkey
        chaos = ChaosMonkey(ChaosConfig(
            seed=args.chaos_seed, deny_rate=args.chaos_deny_rate,
            preempt_rate=args.chaos_preempt_rate,
            nan_rate=args.chaos_nan_rate))
    if args.restore:
        engine = Engine.restore(args.snapshot_dir, params, chaos=chaos)
        d = engine.durability_stats()
        live = len(engine._queue) + sum(s.req is not None
                                        for s in engine._slots)
        print(f"restored from {args.snapshot_dir} (epoch {d['epoch']}): "
              f"{live} live requests resume, {d['restored_terminal']} "
              f"already terminal replayed from the journal")
    else:
        engine = Engine(cfg, params, batch_size=args.batch,
                        max_len=args.max_len,
                        spec_k=args.spec_k if args.spec else 0,
                        drafter=args.drafter, prefix_cache=args.prefix_cache,
                        max_preemptions=args.max_preemptions,
                        audit_every=args.audit_every, chaos=chaos,
                        snapshot_dir=args.snapshot_dir,
                        snapshot_every=args.snapshot_every)
    if args.spec and not engine.spec_k:
        print(f"speculation requested but family {cfg.family!r} has no "
              "rewindable sequence dimension — plain decode fallback")
    if args.prefix_cache and not engine.prefix_sharing:
        print(f"prefix cache requested but family {cfg.family!r} / layout "
              f"{cfg.kv_layout!r} cannot share KV blocks — running without")
    if not args.restore:
        rng = np.random.default_rng(0)
        system = (rng.integers(0, cfg.vocab_size, args.system_prompt_len)
                  if args.prefix_cache else rng.integers(0, cfg.vocab_size, 0))
        for rid in range(args.requests):
            user = rng.integers(0, cfg.vocab_size, int(rng.integers(4, 32)))
            engine.submit(Request(
                rid=rid,
                prompt=np.concatenate([system, user]).astype(np.int32),
                max_new_tokens=args.max_new_tokens,
                priority=args.priority, deadline_s=args.deadline_s))
    done = engine.run()
    if not done.drained:
        print(f"NOT drained: truncated={done.truncated} "
              f"stalled={done.stalled} in_flight={done.in_flight} "
              f"queued={done.queued}")
    print("summary:", Engine.summarize(done))
    r = engine.resilience_stats()
    print(f"resilience: {r['preemptions']} preemptions "
          f"(bound {r['max_preemptions']}/req), "
          f"{r['deadline_misses']} deadline misses, "
          f"{r['row_faults']} quarantined rows, {r['audits']} audits"
          + (f", chaos={r['chaos']}" if chaos is not None else ""))
    if args.snapshot_dir:
        d = engine.durability_stats()
        print(f"durability: {d['snapshots_taken']} snapshots under "
              f"{d['snapshot_dir']} (epoch {d['epoch']}, every "
              f"{d['snapshot_every'] or 'startup-only'} ticks), "
              f"journal={'on' if d['journal'] else 'off'} — recover with "
              f"--restore --snapshot-dir {d['snapshot_dir']}")
    print(f"scheduler: {engine.steps} ticks, {engine.dispatches} dispatches "
          f"(1 per tick, {engine.mixed_ticks} mixed), slot occupancy "
          f"{engine.slot_occupancy:.2f}")
    if engine.spec_k:
        s = engine.spec_stats()
        print(f"speculation: K={s['spec_k']} drafter={args.drafter} — "
              f"{s['accepted_tokens']}/{s['draft_tokens']} drafts accepted "
              f"({s['acceptance_rate']:.2f}), "
              f"{s['accepted_per_dispatch']:.2f} accepted tokens/dispatch "
              f"over {s['spec_ticks']} verify ticks, "
              f"{s['rewinds']} rewinds")
    print(f"compile cache: {sorted(engine.cache_compiles.keys())} "
          f"({engine.cache_compiles.hits} hits, "
          f"misses by kind {engine.cache_compiles.misses_by_name})")
    if engine.paged:
        print(f"paged KV: {engine.pool_blocks} blocks x "
              f"{engine.block_size} tokens, peak resident "
              f"{engine.peak_resident_tokens} tokens, "
              f"{engine.admission_stalls} admission stalls, "
              f"pool {engine.pool_stats()}")
    if engine.prefix_sharing:
        p = engine.prefix_stats()
        print(f"prefix cache: {p['hits']} hits "
              f"({p['hit_tokens']} prompt tokens reused), "
              f"{p['shared_blocks']} shared blocks, "
              f"{p['cow_copies']} CoW copies, "
              f"{p['cached_blocks']} cached, {p['evictions']} evicted")


if __name__ == "__main__":
    main()
