"""Step builders: (arch × shape × mesh × mode) -> jit-able fn + ShapeDtypeStruct
inputs + shardings.  Everything is shape-level (jax.eval_shape) — no arrays
are ever allocated, which is what lets the 512-device dry-run lower
mixtral-8x22b training on a CPU host.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, skip_reason
from repro.configs.shapes import SHAPES, ShapeCell
from repro.core import compiler as core_compiler
from repro.models import api
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.parallel import sharding as shd
from repro.train import trainer


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; shannon/kernels pattern)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict[str, Any]:
    """Model inputs for one shape cell, as weak-type-correct structs."""
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cell.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), jnp.float32)
        return specs
    if cell.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), jnp.float32)
        return specs
    if cell.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "lengths": jax.ShapeDtypeStruct((), i32),
        }
    raise ValueError(cell.kind)


def cache_len_for(cfg: ModelConfig, cell: ShapeCell) -> int:
    """KV cache length: SWA archs cap at the window (rolling buffer)."""
    if cfg.window is not None:
        return min(cell.seq_len, cfg.window)
    return cell.seq_len


# ---------------------------------------------------------------------------
# per-mode step builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower one cell."""
    fn: Callable
    args: tuple                      # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def _params_shape(cfg: ModelConfig, dtype=None):
    c = dataclasses.replace(cfg, dtype=dtype) if dtype is not None else cfg
    return jax.eval_shape(lambda: api.init_params(c, jax.random.PRNGKey(0)))


def build_train(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                accum_steps: int = 8) -> StepBundle:
    opt = AdamW(lr=cosine_schedule(3e-4, 100, 10_000))
    params_shape = _params_shape(cfg, jnp.float32)
    grad_specs = shd.param_specs(params_shape, mesh, "train")
    step = trainer.make_train_step(cfg, opt, accum_steps=accum_steps,
                                   grad_specs=grad_specs)
    opt_shape = jax.eval_shape(opt.init, params_shape)
    batch_shape = input_specs(cfg, cell)
    rng_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)

    p_sh = shd.shardings_for(params_shape, mesh, "train")
    o_sh = shd.shardings_for(opt_shape, mesh, "train")
    b_sh = shd.batch_shardings(batch_shape, mesh)
    r_sh = NamedSharding(mesh, P())
    m_sh = jax.tree.map(
        lambda _: NamedSharding(mesh, P()),
        jax.eval_shape(step, params_shape, opt_shape, batch_shape, rng_shape)[2])

    return StepBundle(
        fn=step,
        args=(params_shape, opt_shape, batch_shape, rng_shape),
        in_shardings=(p_sh, o_sh, b_sh, r_sh),
        out_shardings=(p_sh, o_sh, m_sh),
        donate_argnums=(0, 1),
    )


def _serve_params_shape(cfg: ModelConfig, quant: str | None):
    base = _params_shape(cfg)             # cfg.dtype (bf16)
    if quant is None:
        return base
    return jax.eval_shape(
        functools.partial(core_compiler.quantize_model, strategy=quant), base)


def build_prefill(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                  quant: str | None = None) -> StepBundle:
    max_len = cache_len_for(cfg, cell)
    params_shape = _serve_params_shape(cfg, quant)
    batch_shape = input_specs(cfg, cell)

    def fn(params, batch):
        return api.prefill(cfg, params, batch, max_len)

    p_sh = shd.shardings_for(params_shape, mesh, "serve")
    b_sh = shd.batch_shardings(batch_shape, mesh)
    out_shape = jax.eval_shape(fn, params_shape, batch_shape)
    logits_sh = shd.batch_shardings(out_shape[0], mesh)
    cache_sh = shd.kv_cache_specs(out_shape[1], mesh, cell.global_batch)

    return StepBundle(
        fn=fn,
        args=(params_shape, batch_shape),
        in_shardings=(p_sh, b_sh),
        out_shardings=(logits_sh, cache_sh),
    )


def build_decode(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                 quant: str | None = None) -> StepBundle:
    max_len = cache_len_for(cfg, cell)
    b = cell.global_batch
    params_shape = _serve_params_shape(cfg, quant)
    cache_shape = jax.eval_shape(
        lambda: api.init_cache(cfg, b, max_len))
    specs = input_specs(cfg, cell)

    def fn(params, cache, tokens, lengths):
        return api.decode_step(cfg, params, cache, tokens, lengths)

    p_sh = shd.shardings_for(params_shape, mesh, "serve")
    c_sh = shd.kv_cache_specs(cache_shape, mesh, b)
    t_sh = shd.batch_shardings(specs["tokens"], mesh)
    l_sh = NamedSharding(mesh, P())
    out_shape = jax.eval_shape(fn, params_shape, cache_shape,
                               specs["tokens"], specs["lengths"])
    logits_sh = shd.batch_shardings(out_shape[0], mesh)

    return StepBundle(
        fn=fn,
        args=(params_shape, cache_shape, specs["tokens"], specs["lengths"]),
        in_shardings=(p_sh, c_sh, t_sh, l_sh),
        out_shardings=(logits_sh, c_sh),
        donate_argnums=(1,),
    )


def build_cell(arch: str, shape: str, mesh: Mesh, *,
               quant: str | None = "dense", accum_steps: int = 8,
               cfg_overrides: dict | None = None) -> StepBundle:
    """quant: None = bf16 serving; 'dense' = paper W4A16; 'strategyN' =
    W4A16 + log-scale sparsity (serving modes only — training is bf16)."""
    reason = skip_reason(arch, shape)
    if reason:
        raise ValueError(f"cell ({arch}, {shape}) skipped: {reason}")
    cfg = get_config(arch, **(cfg_overrides or {}))
    cell = SHAPES[shape]
    if cell.kind == "train":
        return build_train(cfg, cell, mesh, accum_steps=accum_steps)
    if cell.kind == "prefill":
        return build_prefill(cfg, cell, mesh, quant=quant)
    return build_decode(cfg, cell, mesh, quant=quant)


def lower_cell(bundle: StepBundle, mesh: Mesh):
    """jit + lower (no compile) under the mesh (+ activation-hint context)."""
    from repro.parallel.hints import use_mesh

    jitted = jax.jit(
        bundle.fn,
        in_shardings=bundle.in_shardings,
        out_shardings=bundle.out_shardings,
        donate_argnums=bundle.donate_argnums,
    )
    with use_mesh(mesh):
        return jitted.lower(*bundle.args)
