"""Training launcher: ``python -m repro.launch.train --arch qwen3-8b ...``

Laptop-scale by default (reduced config on host devices); pass
``--full`` on a real pod to use the assignment-exact config.  Wraps the
fault-tolerant resumable loop (checkpoint every N steps, preemption-safe,
straggler watchdog) around the sharded train step.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamW, cosine_schedule
from repro.parallel import sharding as shd
from repro.parallel.hints import use_mesh
from repro.train import checkpoint as ckpt
from repro.train.fault import PreemptionGuard, StragglerWatchdog
from repro.train.trainer import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="assignment-exact config (pod-scale)")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full else
           get_smoke_config(args.arch, dtype=jnp.float32))
    mesh = make_host_mesh(model=args.model_parallel)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt = AdamW(lr=cosine_schedule(3e-4, 20, args.steps))
    params, opt_state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    specs = shd.param_specs(jax.eval_shape(lambda: params), mesh, "train")
    step_fn = jax.jit(make_train_step(cfg, opt, accum_steps=args.accum,
                                      grad_specs=specs))

    data = SyntheticTokens(DataConfig(cfg.vocab_size, args.seq, args.batch))
    start = ckpt.latest_step(args.ckpt_dir) or 0
    if start:
        state, _ = ckpt.restore(args.ckpt_dir, start,
                                {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start}")

    wd = StragglerWatchdog()
    prefetch = Prefetcher(lambda s: jax.tree.map(jnp.asarray, data.batch(s)),
                          start_step=start)
    with PreemptionGuard() as guard, use_mesh(mesh):
        t0 = time.time()
        for step, batch in prefetch:
            if step >= args.steps:
                break
            ts = time.time()
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jax.random.PRNGKey(step))
            wd.observe(time.time() - ts)
            if step % 10 == 0:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"({time.time()-t0:.0f}s)")
            if guard.preempted or (step + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, step + 1,
                          {"params": params, "opt": opt_state})
                if guard.preempted:
                    print("preempted -> checkpointed, exiting")
                    break
    prefetch.close()
    print(f"done; straggler incidents={wd.incidents}")


if __name__ == "__main__":
    main()
