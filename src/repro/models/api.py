"""Unified model API: one entry per family, dispatched by config.

    init_params(cfg, key)                      -> params pytree
    forward(cfg, params, batch)                -> (logits, aux)
    init_cache(cfg, batch, max_len)            -> cache pytree
    prefill(cfg, params, batch, max_len)       -> (last logits, cache)
    decode_step(cfg, params, cache, tok, len)  -> (logits, cache)

``batch`` is a dict: {"tokens": (B,S)} plus family extras
({"frames": (B,F,d)} for audio, optional {"vision_embeds"} for vlm).

Slot-based serving surface (continuous batching, EdgeLLM §IV-B):

    cache_slot_axes(cfg)                       -> pytree of ints
    insert_request(cfg, cache, row, slot)      -> cache with row at slot
    evict_slot(cfg, cache, slot, max_len)      -> cache with slot reset
    request_cache(cfg, params, batch, max_len) -> batch-1 admission cache
    mixed_step(cfg, params, cache, tokens, lengths, q_lens)

``init_cache(cfg, B, max_len)`` allocates ONE resident cache whose request
dimension is a *slot* index.  ``decode_step`` advances every slot at once
with per-row ``lengths: (B,)``.  ``mixed_step`` is its chunked-prefill
generalization: row ``b`` advances by ``q_lens[b]`` tokens this tick — 1
for a decoding row, up to C (the chunk bucket) for a row mid-prefill — so
prompt admission rides the SAME dispatch as decode instead of a separate
batch-1 prefill that head-of-line-blocks the batch.  Because chunks run
through the cache-updating step path, recurrent families (ssm/hybrid)
materialize the TRUE post-prompt state (closing the old forward-as-prefill
gap).  ``evict_slot`` re-inserts a freshly-initialized row — for recurrent
families this is the per-row state reset that makes slot reuse safe; and
``request_cache`` builds the batch-1 row chunked admission starts from
(pristine state, plus the request's cross-attention K/V for audio).  All
slot ops are jit-safe with a traced ``slot`` (one executable per batch
size, not per slot).

Paged KV (``cfg.kv_layout == "paged"``): KV leaves become ONE shared block
pool addressed through a per-slot page table that rides into each dispatch
(``page_table=`` on decode_step/mixed_step; None = the linear default of a
default-sized pool).  Pool leaves have no slot axis — ``cache_slot_axes``
marks them ``-1`` and insert/evict/per-row selects skip them; writes that
must not land are routed to the pool's null block (``write_mask``).  The
engine owns allocation (see serving/engine.py); this module keeps the
layout invisible to numerics.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer, whisper, xlstm_stack, zamba
from repro.models.config import ModelConfig

Params = dict[str, Any]

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm")


def init_params(cfg: ModelConfig, key) -> Params:
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.init_params(cfg, key)
    if cfg.family == "ssm":
        return xlstm_stack.init_params(cfg, key)
    if cfg.family == "hybrid":
        return zamba.init_params(cfg, key)
    if cfg.family == "audio":
        return whisper.init_params(cfg, key)
    raise ValueError(f"unknown family {cfg.family!r}")


def forward(cfg: ModelConfig, params: Params, batch: dict) -> tuple:
    tokens = batch["tokens"]
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.forward(
            cfg, params, tokens, vision_embeds=batch.get("vision_embeds"))
    if cfg.family == "ssm":
        return xlstm_stack.forward(cfg, params, tokens)
    if cfg.family == "hybrid":
        return zamba.forward(cfg, params, tokens)
    if cfg.family == "audio":
        return whisper.forward(cfg, params, batch["frames"], tokens)
    raise ValueError(f"unknown family {cfg.family!r}")


def loss_fn(cfg: ModelConfig, params: Params, batch: dict,
            aux_weight: float = 0.01):
    """Next-token cross-entropy + MoE aux loss."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    logits = logits[:, : labels.shape[1]]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is not None:
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    else:
        loss = nll.mean()
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.init_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return xlstm_stack.init_cache(cfg, batch, max_len)
    if cfg.family == "hybrid":
        return zamba.init_cache(cfg, batch, max_len)
    if cfg.family == "audio":
        return whisper.init_cache(cfg, batch, max_len)
    raise ValueError(f"unknown family {cfg.family!r}")


def has_paged_kv(cfg: ModelConfig) -> bool:
    """Whether this config's cache carries paged (shared-pool) KV leaves.
    The ssm family is pure recurrent state — O(1) in context — so paging is
    a no-op there and the engine keeps its slot bookkeeping."""
    return cfg.kv_layout == "paged" and cfg.family != "ssm"


def cache_slot_axes(cfg: ModelConfig) -> Params:
    """Pytree (cache structure) of ints: the request-slot axis of each leaf.
    ``-1`` marks paged shared-pool leaves (no slot axis — insert/evict and
    per-row selects must skip them; masked writes are routed to the null
    block instead of being reverted)."""
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.cache_slot_axes(cfg)
    if cfg.family == "ssm":
        return xlstm_stack.cache_slot_axes(cfg)
    if cfg.family == "hybrid":
        return zamba.cache_slot_axes(cfg)
    if cfg.family == "audio":
        return whisper.cache_slot_axes(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def insert_request(cfg: ModelConfig, cache: Params, row_cache: Params,
                   slot) -> Params:
    """Scatter a batch-1 cache (one prefilled request) into ``slot``.

    ``slot`` may be a traced int32 scalar — the scatter is a
    ``dynamic_update_slice_in_dim`` per leaf, so one jitted executable
    serves every slot of a given batch size.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def ins(dst, row, axis):
        if axis < 0:        # shared paged pool: nothing per-slot to scatter
            return dst
        return jax.lax.dynamic_update_slice_in_dim(
            dst, row.astype(dst.dtype), slot, axis=axis)

    return jax.tree.map(ins, cache, row_cache, cache_slot_axes(cfg))


def evict_slot(cfg: ModelConfig, cache: Params, slot, max_len: int) -> Params:
    """Reset one slot to its freshly-initialized state.

    KV rows are masked by ``lengths`` anyway, but recurrent families carry
    state that must return to its init value (e.g. the mLSTM stabilizer
    ``m = -1e30``) before the slot hosts the next request.
    """
    return insert_request(cfg, cache, init_cache(cfg, 1, max_len), slot)


def _bulk_prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  max_len: int):
    """Whole-prompt prefill through the mixed-step chunk writer: one call
    whose chunk IS the prompt (``q_lens[b] = S``), writing K/V at true
    positions and — because ``mixed_step`` is bit-identical to sequential
    ``decode_step`` — materializing the TRUE post-prompt state for every
    family.  This is the bulk generalization of the serving chunk writer:
    recurrent families no longer need a token-by-token loop to get an exact
    state, and paged caches (which have no full-sequence ``attn_prefill``)
    prefill through their normal write path under the default page table."""
    b, s = tokens.shape
    if s > max_len:
        raise ValueError(f"prompt length {s} exceeds max_len {max_len}")
    cache = init_cache(cfg, b, max_len)
    lengths = jnp.zeros((b,), jnp.int32)
    q_lens = jnp.full((b,), s, jnp.int32)
    return mixed_step(cfg, params, cache, tokens, lengths, q_lens)


def prefill(cfg: ModelConfig, params: Params, batch: dict, max_len: int):
    tokens = batch["tokens"]
    if cfg.family in _TRANSFORMER_FAMILIES:
        if cfg.kv_layout == "paged":
            # shared-pool caches have no full-sequence attn_prefill; the
            # bulk chunk writer routes the whole prompt through the paged
            # scatter under the default (linear) page table
            return _bulk_prefill(cfg, params, tokens, max_len)
        return transformer.prefill(cfg, params, tokens, max_len)
    if cfg.family == "audio":
        return whisper.prefill(cfg, params, batch["frames"], tokens, max_len)
    if cfg.family in ("ssm", "hybrid"):
        # TRUE post-prompt recurrent state in one dispatch (the old
        # forward-as-prefill surface returned a FRESH state and pushed
        # offline evals into a token-by-token decode loop)
        return _bulk_prefill(cfg, params, tokens, max_len)
    raise ValueError(f"unknown family {cfg.family!r}")


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, lengths, *, page_table=None,
                write_mask=None):
    """``page_table``/``write_mask`` apply to paged-KV caches only: the
    table routes K/V placement (None = the linear default covering a
    default-sized pool) and the mask sends a row's write to the null block
    — a pool has no slot axis for callers to select-revert over."""
    kw = {"page_table": page_table, "write_mask": write_mask}
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.decode_step(cfg, params, cache, tokens, lengths,
                                       **kw)
    if cfg.family == "ssm":
        return xlstm_stack.decode_step(cfg, params, cache, tokens, lengths)
    if cfg.family == "hybrid":
        return zamba.decode_step(cfg, params, cache, tokens, lengths, **kw)
    if cfg.family == "audio":
        return whisper.decode_step(cfg, params, cache, tokens, lengths, **kw)
    raise ValueError(f"unknown family {cfg.family!r}")


def request_cache(cfg: ModelConfig, params: Params, batch: dict,
                  max_len: int) -> Params:
    """Batch-1 cache a request's chunked admission starts from.

    Pure-KV families get a pristine ``init_cache`` row (stale KV in a reused
    slot is invisible behind true-length masking, so the engine can even
    skip inserting it — see ``needs_admission_insert``).  Audio additionally
    carries the request's cross-attention K/V, encoded once from its frames.
    """
    if cfg.family == "audio":
        return whisper.request_cache(cfg, params, batch["frames"], max_len)
    return init_cache(cfg, 1, max_len)


def needs_admission_insert(cfg: ModelConfig) -> bool:
    """Whether chunked admission must scatter ``request_cache`` into the
    slot before streaming the prompt.  Recurrent families carry state the
    previous occupant mutated (the mLSTM ``m`` stabilizer, Mamba conv/SSM
    state) and audio carries per-request cross-KV; pure-KV families need
    nothing — their stale rows hide behind true-length masking, so
    admission costs ZERO extra dispatches.
    """
    return cfg.family in ("ssm", "hybrid", "audio")


def supports_speculation(cfg: ModelConfig) -> bool:
    """Whether draft-then-verify serving can run on this config.

    Speculation needs a REWINDABLE sequence dimension: after a partial
    accept the engine shrinks ``lengths[b]`` and the rejected tail must
    become invisible.  Pure-KV families (transformer + whisper, whose
    decoder self-attention is plain KV and whose cross-KV is static per
    request) get this for free — stale cache positions past ``lengths``
    already hide behind true-length masking, so rollback is host-side
    bookkeeping only.  Recurrent families (ssm, hybrid) fold every token
    irreversibly into O(1) state — there is nothing to rewind to — so the
    engine must fall back to plain decode for them.
    """
    return cfg.family in _TRANSFORMER_FAMILIES + ("audio",)


def supports_prefix_cache(cfg: ModelConfig) -> bool:
    """Whether cross-request prefix sharing can run on this config.

    Sharing maps one physical KV block into many page tables, so it needs
    (1) the paged layout and (2) K/V that is a pure function of the token
    prefix.  Transformer families qualify: position ``p``'s K/V depends
    only on tokens ``0..p`` (and the fixed params), and ``mixed_step`` is
    bitwise equal to sequential decode, so a cached block is bit-identical
    to what the admitted request would recompute.  Audio does NOT — its
    decoder hidden states fold in per-request encoder output through cross
    attention, so equal token prefixes do not imply equal K/V.  Recurrent
    families (ssm, hybrid) carry per-slot state a shared block cannot
    capture.
    """
    return cfg.kv_layout == "paged" and cfg.family in _TRANSFORMER_FAMILIES


def export_cache(cfg: ModelConfig, cache: Params) -> Params:
    """Device→host capture of every cache leaf, bitwise.

    Serving snapshots persist the resident cache (paged pool + int8 scales,
    or the slot cache) through the checkpoint leaf codec, which stores bf16
    and fp8 leaves as unsigned bit views — so the round-trip is exact, not
    a value-level cast.  This helper is just the tree-wide ``device_get``;
    the codec lives in ``train/checkpoint.py``.
    """
    return jax.tree.map(lambda leaf: np.asarray(jax.device_get(leaf)), cache)


def copy_pool_block(cfg: ModelConfig, cache: Params, src, dst) -> Params:
    """Copy one physical KV block ``src`` -> ``dst`` across every paged pool
    leaf (all layers, scales included) — the device half of copy-on-write.

    Serving writes are append-only, so sharing needs at most ONE copy per
    admission: when the uncovered suffix starts mid-block, the engine leases
    ``dst`` fresh and duplicates the shared block before the first chunk
    write lands over its tail.  ``src``/``dst`` may be traced int32 scalars
    (one executable regardless of which blocks move).  Transformer-family
    pool leaves are ``(n_layers, P+1, hkv, bs, hd)`` — the pool axis is 1.
    """
    if not supports_prefix_cache(cfg):
        raise ValueError(
            f"copy_pool_block needs a prefix-shareable config, got "
            f"family={cfg.family!r} kv_layout={cfg.kv_layout!r}")
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def cp(leaf, axis):
        if axis != -1:          # per-slot leaf: nothing pooled to copy
            return leaf
        row = jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(leaf, row, dst, axis=1)

    return jax.tree.map(cp, cache, cache_slot_axes(cfg))


def _mixed_step_scan(cfg: ModelConfig, params: Params, cache: Params,
                     tokens: jax.Array, lengths, q_lens, page_table=None,
                     all_logits: bool = False):
    """Generic mixed step for recurrent/stateful families.

    Scans the chunk axis INSIDE one jitted call (still one device dispatch
    per serving tick), advancing each row only while ``j < q_lens[b]`` via a
    per-row select over the cache pytree — recurrences are order-exact, so
    the resulting state is bit-identical to feeding the tokens one
    ``decode_step`` at a time.  This is what materializes the TRUE
    post-prompt recurrent state for ssm/hybrid during chunked admission.

    Paged KV leaves (axis ``-1``) have no slot axis to select over; their
    inactive-row writes are instead masked at the source (``write_mask``
    routes them to the null block), so the select keeps the new pool as-is.
    """
    b, c = tokens.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    q_lens = jnp.asarray(q_lens, jnp.int32)
    axes = cache_slot_axes(cfg)
    paged = has_paged_kv(cfg)

    def body(carry, j):
        cur, logits = carry
        active = j < q_lens                                      # (B,)
        tok = jax.lax.dynamic_slice_in_dim(tokens, j, 1, axis=1)
        # inactive rows re-run their final position; their writes are
        # reverted by the select below, so this is just shape plumbing
        step_len = lengths + jnp.minimum(j + 1, jnp.maximum(q_lens, 1))
        lg, new = decode_step(cfg, params, cur, tok, step_len,
                              page_table=page_table,
                              write_mask=active if paged else None)

        def sel(n, old, ax):
            if ax < 0:          # paged pool: writes already null-routed
                return n
            shape = [1] * n.ndim
            shape[ax] = b
            return jnp.where(active.reshape(shape), n, old)

        cur = jax.tree.map(sel, new, cur, axes)
        if all_logits:
            # verify surface: keep every position's logits (B, C, V); rows
            # past their q_len keep zeros (their step re-ran the final
            # position — masked here so callers see a clean pad)
            logits = jax.lax.dynamic_update_slice(
                logits,
                jnp.where(active[:, None], lg.astype(logits.dtype),
                          0)[:, None],
                (0, j, 0))
        else:
            logits = jnp.where((j == q_lens - 1)[:, None],
                               lg.astype(logits.dtype), logits)
        return (cur, logits), None

    shape = (b, c, cfg.vocab_size) if all_logits else (b, cfg.vocab_size)
    init_logits = jnp.zeros(shape, cfg.dtype)
    (cache, logits), _ = jax.lax.scan(
        body, (cache, init_logits), jnp.arange(c, dtype=jnp.int32))
    return logits, cache


def mixed_step(cfg: ModelConfig, params: Params, cache: Params,
               tokens: jax.Array, lengths, q_lens, *, page_table=None,
               all_logits: bool = False):
    """Advance every row by a per-row token count in ONE dispatch.

    tokens (B, C); ``lengths`` (B,) = valid cache tokens BEFORE this step;
    ``q_lens`` (B,) = live tokens per row this tick (0 = idle slot, 1 =
    decoding row, up to C = mid-prefill row, left-aligned in its chunk).
    Returns (logits (B, V) of each row's last live token, new cache) — or,
    with ``all_logits=True``, logits (B, C, V) for EVERY chunk position
    (the speculative-decoding verify surface: position j scores the token
    after ``tokens[b, j]``, so a K-token draft is accepted/rejected from
    this one dispatch).  ``page_table`` (B, pages) routes paged-KV
    placement (None = the linear default table of a default-sized pool).

    Transformer families run the fused chunk-attention path (one KV stream
    for the whole mixed batch); recurrent/stateful families scan the chunk
    axis in-executable (``_mixed_step_scan``).  ``C == 1`` delegates to
    ``decode_step`` (bit-identical to the classic pure-decode tick when
    every row is live), with a per-row select keeping ``q_lens == 0`` rows
    exactly untouched (paged pool leaves mask at the write instead).
    """
    if tokens.shape[1] == 1:
        b = tokens.shape[0]
        lengths = jnp.broadcast_to(
            jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
        q_lens = jnp.broadcast_to(
            jnp.asarray(q_lens, jnp.int32).reshape(-1), (b,))
        active = q_lens > 0
        paged = has_paged_kv(cfg)
        logits, new = decode_step(cfg, params, cache, tokens,
                                  lengths + jnp.maximum(q_lens, 1),
                                  page_table=page_table,
                                  write_mask=active if paged else None)

        def sel(n, old, ax):
            if ax < 0:          # paged pool: writes already null-routed
                return n
            shape = [1] * n.ndim
            shape[ax] = b
            return jnp.where(active.reshape(shape), n, old)

        new = jax.tree.map(sel, new, cache, cache_slot_axes(cfg))
        out = jnp.where(active[:, None], logits, jnp.zeros_like(logits))
        return (out[:, None] if all_logits else out), new
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.mixed_step(cfg, params, cache, tokens, lengths,
                                      q_lens, page_table=page_table,
                                      all_logits=all_logits)
    if cfg.family in ("ssm", "hybrid", "audio"):
        return _mixed_step_scan(cfg, params, cache, tokens, lengths, q_lens,
                                page_table=page_table, all_logits=all_logits)
    raise ValueError(f"unknown family {cfg.family!r}")
