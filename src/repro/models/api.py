"""Unified model API: one entry per family, dispatched by config.

    init_params(cfg, key)                      -> params pytree
    forward(cfg, params, batch)                -> (logits, aux)
    init_cache(cfg, batch, max_len)            -> cache pytree
    prefill(cfg, params, batch, max_len)       -> (last logits, cache)
    decode_step(cfg, params, cache, tok, len)  -> (logits, cache)

``batch`` is a dict: {"tokens": (B,S)} plus family extras
({"frames": (B,F,d)} for audio, optional {"vision_embeds"} for vlm).

Slot-based serving surface (continuous batching, EdgeLLM §IV-B):

    cache_slot_axes(cfg)                       -> pytree of ints
    insert_request(cfg, cache, row, slot)      -> cache with row at slot
    evict_slot(cfg, cache, slot, max_len)      -> cache with slot reset

``init_cache(cfg, B, max_len)`` allocates ONE resident cache whose request
dimension is a *slot* index.  A prefill runs at batch 1 and its cache is
scattered into a free slot (``insert_request``); ``decode_step`` then
advances every slot at once with per-row ``lengths: (B,)``.  ``evict_slot``
re-inserts a freshly-initialized row — for recurrent families this is the
per-row state reset that makes slot reuse safe.  All three are jit-safe with
a traced ``slot`` (one executable per batch size, not per slot).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer, whisper, xlstm_stack, zamba
from repro.models.config import ModelConfig

Params = dict[str, Any]

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm")


def init_params(cfg: ModelConfig, key) -> Params:
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.init_params(cfg, key)
    if cfg.family == "ssm":
        return xlstm_stack.init_params(cfg, key)
    if cfg.family == "hybrid":
        return zamba.init_params(cfg, key)
    if cfg.family == "audio":
        return whisper.init_params(cfg, key)
    raise ValueError(f"unknown family {cfg.family!r}")


def forward(cfg: ModelConfig, params: Params, batch: dict) -> tuple:
    tokens = batch["tokens"]
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.forward(
            cfg, params, tokens, vision_embeds=batch.get("vision_embeds"))
    if cfg.family == "ssm":
        return xlstm_stack.forward(cfg, params, tokens)
    if cfg.family == "hybrid":
        return zamba.forward(cfg, params, tokens)
    if cfg.family == "audio":
        return whisper.forward(cfg, params, batch["frames"], tokens)
    raise ValueError(f"unknown family {cfg.family!r}")


def loss_fn(cfg: ModelConfig, params: Params, batch: dict,
            aux_weight: float = 0.01):
    """Next-token cross-entropy + MoE aux loss."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    logits = logits[:, : labels.shape[1]]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is not None:
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    else:
        loss = nll.mean()
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.init_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return xlstm_stack.init_cache(cfg, batch, max_len)
    if cfg.family == "hybrid":
        return zamba.init_cache(cfg, batch, max_len)
    if cfg.family == "audio":
        return whisper.init_cache(cfg, batch, max_len)
    raise ValueError(f"unknown family {cfg.family!r}")


def cache_slot_axes(cfg: ModelConfig) -> Params:
    """Pytree (cache structure) of ints: the request-slot axis of each leaf."""
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.cache_slot_axes(cfg)
    if cfg.family == "ssm":
        return xlstm_stack.cache_slot_axes(cfg)
    if cfg.family == "hybrid":
        return zamba.cache_slot_axes(cfg)
    if cfg.family == "audio":
        return whisper.cache_slot_axes(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def insert_request(cfg: ModelConfig, cache: Params, row_cache: Params,
                   slot) -> Params:
    """Scatter a batch-1 cache (one prefilled request) into ``slot``.

    ``slot`` may be a traced int32 scalar — the scatter is a
    ``dynamic_update_slice_in_dim`` per leaf, so one jitted executable
    serves every slot of a given batch size.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def ins(dst, row, axis):
        return jax.lax.dynamic_update_slice_in_dim(
            dst, row.astype(dst.dtype), slot, axis=axis)

    return jax.tree.map(ins, cache, row_cache, cache_slot_axes(cfg))


def evict_slot(cfg: ModelConfig, cache: Params, slot, max_len: int) -> Params:
    """Reset one slot to its freshly-initialized state.

    KV rows are masked by ``lengths`` anyway, but recurrent families carry
    state that must return to its init value (e.g. the mLSTM stabilizer
    ``m = -1e30``) before the slot hosts the next request.
    """
    return insert_request(cfg, cache, init_cache(cfg, 1, max_len), slot)


def prefill(cfg: ModelConfig, params: Params, batch: dict, max_len: int):
    tokens = batch["tokens"]
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.prefill(cfg, params, tokens, max_len)
    if cfg.family == "audio":
        return whisper.prefill(cfg, params, batch["frames"], tokens, max_len)
    if cfg.family in ("ssm", "hybrid"):
        # recurrent families prefill by teacher-forcing the full forward and
        # materializing the state via sequential decode of the last token
        # only when needed; for benchmarking we expose forward-as-prefill.
        logits, _ = forward(cfg, params, batch)
        cache = init_cache(cfg, tokens.shape[0], max_len)
        return logits[:, -1], cache
    raise ValueError(f"unknown family {cfg.family!r}")


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, lengths):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.decode_step(cfg, params, cache, tokens, lengths)
    if cfg.family == "ssm":
        return xlstm_stack.decode_step(cfg, params, cache, tokens, lengths)
    if cfg.family == "hybrid":
        return zamba.decode_step(cfg, params, cache, tokens, lengths)
    if cfg.family == "audio":
        return whisper.decode_step(cfg, params, cache, tokens, lengths)
    raise ValueError(f"unknown family {cfg.family!r}")
