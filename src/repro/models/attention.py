"""Attention module: GQA/MQA, RoPE/M-RoPE, qk-norm, SWA, KV cache.

Train/prefill path goes through ``ops.attention`` (Pallas flash kernel on
TPU, dense oracle on CPU); decode path uses ``ops.decode_attention`` against
a preallocated MAX-token cache (the paper's static-address trick, §IV-B).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers
from repro.models.layers import Params, dense_init, linear


def attn_init(key, cfg, d_model: int | None = None) -> Params:
    d = d_model or cfg.d_model
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, hq * hd, cfg.dtype),
        "wk": dense_init(ks[1], d, hkv * hd, cfg.dtype),
        "wv": dense_init(ks[2], d, hkv * hd, cfg.dtype),
        "wo": dense_init(ks[3], hq * hd, d, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((hkv * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((hkv * hd,), cfg.dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.dtype)
    return p


def _project_qkv(cfg, p: Params, x: jax.Array, positions):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    uk = cfg.use_kernels
    q = linear(x, p["wq"], p.get("bq"), use_kernels=uk).reshape(b, s, hq, hd)
    k = linear(x, p["wk"], p.get("bk"), use_kernels=uk).reshape(b, s, hkv, hd)
    v = linear(x, p["wv"], p.get("bv"), use_kernels=uk).reshape(b, s, hkv, hd)
    q = q.transpose(0, 2, 1, 3)   # (b, h, s, d)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = layers.rmsnorm(q, p["q_norm"])
        k = layers.rmsnorm(k, p["k_norm"])
    if cfg.rope_type == "standard":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q = layers.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def attn_apply(cfg, p: Params, x: jax.Array, positions, *,
               causal: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    o = ops.attention(q, k, v, causal=causal, window=cfg.window,
                      impl="pallas" if cfg.use_kernels else "xla")
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return linear(o, p["wo"], use_kernels=cfg.use_kernels)


# -- paged KV layout ---------------------------------------------------------
#
# The slot cache reserves ``max_len`` rows per slot; the paged layout leases
# fixed-size blocks from ONE shared pool instead.  Per layer the pool leaf is
# ``(n_blocks + 1, hkv, block_size, hd)`` — the LAST block is the null block:
# writes that must not land (dead chunk queries, masked decode rows) are
# routed there, and page-table entries of pages a slot has not leased point
# there too, so a stale table can never alias a live block.  The page table
# ``(B, pages_per_slot)`` of physical block ids is HOST-managed (the engine
# allocates/frees blocks) and rides into each dispatch as a plain operand —
# logical position ``p`` of slot ``b`` lives at
# ``pool[page_table[b, p // bs], :, p % bs]``.
#
# Rewind contract (speculative rollback): shrinking a row's ``lengths[b]``
# is ALWAYS safe — every read masks by length, so stale K/V past the new
# length (rejected draft tokens) is invisible and later writes overwrite it
# in place.  Blocks wholly past ``ceil(new_len / bs)`` may be returned to
# the pool, provided their page-table entries are re-pointed at the null
# block FIRST (a freed block must never stay reachable through a stale
# table row); the partially-used tail block must stay leased.

def paged_blocks_for(length: int, block_size: int) -> int:
    """Blocks needed to cover ``length`` logical tokens (ceil division) —
    the one formula the engine's lease/reserve/rewind accounting shares."""
    return -(-length // block_size)


def paged_geometry(cfg, max_len: int) -> tuple[int, int]:
    """(block_size, pages_per_slot) for a paged cache addressing ``max_len``
    logical positions per slot (the last page may be partially addressable)."""
    bs = cfg.kv_block_size
    return bs, -(-max_len // bs)


def paged_pool_blocks(cfg, batch: int, max_len: int) -> int:
    """Usable (non-null) pool blocks: ``cfg.kv_pool_blocks`` or the slot
    layout's exact capacity ``batch * pages_per_slot``."""
    _, n_pages = paged_geometry(cfg, max_len)
    return cfg.kv_pool_blocks or batch * n_pages


def default_page_table(batch: int, pool_blocks: int) -> jax.Array:
    """Linear identity table for a default-sized pool (blocks 0..B*pages-1,
    slot ``b`` owning the contiguous run ``b*pages .. (b+1)*pages-1``) — the
    layout bit-equivalent to the slot cache.  ``pool_blocks`` is the pool
    leaf's leading dim INCLUDING the null block."""
    n_pages = (pool_blocks - 1) // batch
    return jnp.arange(batch * n_pages, dtype=jnp.int32).reshape(batch, n_pages)


def init_kv_cache_paged(cfg, batch: int, max_len: int) -> Params:
    """Shared-pool paged KV leaves (one layer): ``(P+1, hkv, bs, hd)``."""
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    bs, _ = paged_geometry(cfg, max_len)
    p = paged_pool_blocks(cfg, batch, max_len) + 1   # + null block (last)
    if cfg.kv_quant == "int8":
        return {
            "k": jnp.zeros((p, hkv, bs, hd), jnp.int8),
            "v": jnp.zeros((p, hkv, bs, hd), jnp.int8),
            "k_scale": jnp.zeros((p, hkv, bs, 1), jnp.float32),
            "v_scale": jnp.zeros((p, hkv, bs, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((p, hkv, bs, hd), cfg.dtype),
        "v": jnp.zeros((p, hkv, bs, hd), cfg.dtype),
    }


def _paged_token_write(pool: jax.Array, new: jax.Array, page_table: jax.Array,
                       pos: jax.Array, mask: jax.Array | None) -> jax.Array:
    """Scatter one token per row into the pool.  ``new`` (b, hkv, w);
    ``pos`` (b,) logical positions; rows with ``mask == False`` are routed to
    the null block (last pool row) — their write never lands."""
    b = new.shape[0]
    bs = pool.shape[2]
    null = pool.shape[0] - 1
    blk = jnp.take_along_axis(page_table, (pos // bs)[:, None], axis=1)[:, 0]
    if mask is not None:
        blk = jnp.where(mask, blk, null)
    return pool.at[blk, :, pos % bs].set(new.astype(pool.dtype))


def _paged_chunk_write(pool: jax.Array, new: jax.Array, page_table: jax.Array,
                       starts: jax.Array, q_lens: jax.Array) -> jax.Array:
    """Per-row variable-length chunk scatter through the page table.

    ``new`` (b, hkv, C, w); row ``b`` writes its first ``q_lens[b]`` chunk
    tokens at logical positions ``starts[b] ..``; dead chunk positions are
    routed to the null block, so a ``q_lens == 0`` row is exactly a no-op —
    the paged counterpart of ``_chunk_write``'s read-modify-write masking.
    """
    b, _, c, _ = new.shape
    bs = pool.shape[2]
    null = pool.shape[0] - 1
    n_pos = page_table.shape[1] * bs
    j = jnp.arange(c, dtype=jnp.int32)
    pos = jnp.clip(starts[:, None] + j[None, :], 0, n_pos - 1)   # (b, C)
    live = j[None, :] < q_lens[:, None]
    blk = jnp.take_along_axis(page_table, pos // bs, axis=1)     # (b, C)
    blk = jnp.where(live, blk, null)
    vals = new.transpose(0, 2, 1, 3)                             # (b, C, hkv, w)
    return pool.at[blk, :, pos % bs].set(vals.astype(pool.dtype))


def init_kv_cache(cfg, batch: int, max_len: int, d_model=None) -> Params:
    if cfg.kv_layout == "paged":
        return init_kv_cache_paged(cfg, batch, max_len)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_quant == "int8":
        # per-(token, head) absmax scale over head_dim — the paper's
        # block-scale packing applied to the dynamic operand (beyond-paper)
        return {
            "k": jnp.zeros((batch, hkv, max_len, hd), jnp.int8),
            "v": jnp.zeros((batch, hkv, max_len, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, hkv, max_len, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, hkv, max_len, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, hkv, max_len, hd), cfg.dtype),
        "v": jnp.zeros((batch, hkv, max_len, hd), cfg.dtype),
    }


def kv_cache_slot_axes(cfg, axis: int = 1) -> Params:
    """Pytree (matching ``init_kv_cache`` structure) of batch/slot axes.

    Callers stack per-layer caches along leading axes, so the request-slot
    axis of each leaf is ``axis`` (1 for a single (layers, B, ...) stack).
    Consumed by ``models.api.insert_request`` / ``evict_slot``.

    Paged leaves are SHARED pools — no slot axis exists, marked with the
    ``-1`` sentinel: insert/evict/per-row selects skip them (stale pool data
    hides behind true-length masking at block granularity, and writes by
    masked rows are routed to the null block instead of being reverted).
    """
    if cfg.kv_layout == "paged":
        axis = -1
    axes: Params = {"k": axis, "v": axis}
    if cfg.kv_quant == "int8":
        axes["k_scale"] = axis
        axes["v_scale"] = axis
    return axes


def quantize_kv(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(…, hd) -> int8 values + per-vector absmax scale."""
    a = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(a / 127.0, 1e-10)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def attn_prefill(cfg, p: Params, x: jax.Array, positions, cache: Params):
    """Prefill: run full attention AND populate the cache.

    With a sliding-window (rolling) cache smaller than the prompt, only the
    last ``cache_len`` tokens' K/V are retained — exactly the set SWA decode
    will ever attend to."""
    if cfg.kv_layout == "paged":
        raise ValueError(
            "paged KV caches have no full-sequence prefill path — serve "
            "through mixed_step/decode_step (chunked admission); the "
            "standalone api.prefill is a slot-layout/training surface")
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    o = ops.attention(q, k, v, causal=True, window=cfg.window,
                      impl="pallas" if cfg.use_kernels else "xla")
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
    cache_len = cache["k"].shape[2]
    if cache_len < s:
        k = k[:, :, -cache_len:]
        v = v[:, :, -cache_len:]
    if cfg.kv_quant == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                                    (0, 0, 0, 0)),
            "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                                    (0, 0, 0, 0)),
        }
        return out, cache
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, 0, 0)),
    }
    return out, cache


def _chunk_write(cache_leaf: jax.Array, new: jax.Array, starts: jax.Array,
                 q_lens: jax.Array) -> jax.Array:
    """Scatter per-row variable-length chunks into a (B, hkv, L, w) cache.

    Row ``b`` writes ``new[b, :, :q_lens[b]]`` at positions
    ``starts[b] .. starts[b] + q_lens[b] - 1`` — a read-modify-write of one
    C-wide block per row, so positions outside the live span keep their
    current cache values exactly (a q_lens == 0 row is a no-op, and a row
    near the MAX boundary never clobbers valid neighbors the way a clamped
    ``dynamic_update_slice`` of the raw chunk would).  Callers guarantee
    ``starts + q_lens <= L``.
    """
    c = new.shape[2]
    cache_len = cache_leaf.shape[2]
    idx = jnp.arange(c)

    def one(dst, blk, start, ql):
        off = jnp.clip(start, 0, cache_len - c)
        delta = start - off            # 0 unless the block straddles the end
        cur = jax.lax.dynamic_slice_in_dim(dst, off, c, axis=1)
        shifted = jnp.roll(blk, delta, axis=1)
        mask = (idx >= delta) & (idx < delta + ql)
        merged = jnp.where(mask[None, :, None], shifted.astype(dst.dtype), cur)
        return jax.lax.dynamic_update_slice_in_dim(dst, merged, off, axis=1)

    return jax.vmap(one)(cache_leaf, new, jnp.asarray(starts, jnp.int32),
                         jnp.asarray(q_lens, jnp.int32))


def attn_mixed(cfg, p: Params, x: jax.Array, positions, cache: Params,
               lengths: jax.Array, q_lens: jax.Array, *,
               page_table: jax.Array | None = None):
    """Mixed prefill/decode attention step.  x (b, C, d); ``lengths`` (b,) =
    valid cache tokens BEFORE this step; ``q_lens`` (b,) = live new tokens
    per row (1 = decoding row, up to C = mid-prefill row; the rest of the
    chunk is padding).  Writes each row's live K/V at its true positions —
    no left-pad bucket writes — then attends over the cache with intra-chunk
    causal masking.  Requires ``lengths + q_lens <= cache_len`` (the serving
    scheduler's cache-room invariant), which also means a rolling-SWA buffer
    never wraps here — so the rolling case degenerates to the non-rolling
    one and ``cfg.window`` masking applies directly.

    Paged layout: cache leaves are shared pools, ``page_table`` (b, pages)
    routes both the chunk K/V scatter (dead positions to the null block) and
    the kernels' logical→physical block translation.
    """
    b, c, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    lengths = jnp.asarray(lengths, jnp.int32)
    q_lens = jnp.asarray(q_lens, jnp.int32)
    total = lengths + q_lens

    if cfg.kv_layout == "paged":
        if page_table is None:
            page_table = default_page_table(b, cache["k"].shape[0])
        if cfg.kv_quant == "int8":
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            new_cache = {
                "k": _paged_chunk_write(cache["k"], kq, page_table,
                                        lengths, q_lens),
                "v": _paged_chunk_write(cache["v"], vq, page_table,
                                        lengths, q_lens),
                "k_scale": _paged_chunk_write(cache["k_scale"], ks,
                                              page_table, lengths, q_lens),
                "v_scale": _paged_chunk_write(cache["v_scale"], vs,
                                              page_table, lengths, q_lens),
            }
            o = ops.mixed_attention(q, new_cache["k"], new_cache["v"], total,
                                    q_lens, window=cfg.window,
                                    k_scale=new_cache["k_scale"],
                                    v_scale=new_cache["v_scale"],
                                    page_table=page_table)
        else:
            new_cache = {
                "k": _paged_chunk_write(cache["k"], k, page_table,
                                        lengths, q_lens),
                "v": _paged_chunk_write(cache["v"], v, page_table,
                                        lengths, q_lens),
            }
            o = ops.mixed_attention(q, new_cache["k"], new_cache["v"], total,
                                    q_lens, window=cfg.window,
                                    page_table=page_table)
        o = o.transpose(0, 2, 1, 3).reshape(b, c, cfg.n_heads * cfg.head_dim)
        return linear(o, p["wo"], use_kernels=cfg.use_kernels), new_cache

    if cfg.kv_quant == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = {
            "k": _chunk_write(cache["k"], kq, lengths, q_lens),
            "v": _chunk_write(cache["v"], vq, lengths, q_lens),
            "k_scale": _chunk_write(cache["k_scale"], ks, lengths, q_lens),
            "v_scale": _chunk_write(cache["v_scale"], vs, lengths, q_lens),
        }
        o = ops.mixed_attention(q, new_cache["k"], new_cache["v"], total,
                                q_lens, window=cfg.window,
                                k_scale=new_cache["k_scale"],
                                v_scale=new_cache["v_scale"])
    else:
        new_cache = {
            "k": _chunk_write(cache["k"], k, lengths, q_lens),
            "v": _chunk_write(cache["v"], v, lengths, q_lens),
        }
        o = ops.mixed_attention(q, new_cache["k"], new_cache["v"], total,
                                q_lens, window=cfg.window)
    o = o.transpose(0, 2, 1, 3).reshape(b, c, cfg.n_heads * cfg.head_dim)
    out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
    return out, new_cache


def attn_decode(cfg, p: Params, x: jax.Array, positions, cache: Params,
                lengths: jax.Array, *, page_table: jax.Array | None = None,
                write_mask: jax.Array | None = None):
    """One-token decode.  x (b, 1, d); lengths (b,) = context length
    *including* the new token.

    Paged layout: the new K/V scatters through ``page_table`` into the
    shared pool; ``write_mask`` (b,) bool routes masked rows' writes to the
    null block — the paged replacement for the slot layout's per-row
    select-revert (a pool has no slot axis to select over).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x, positions)
    # write the new K/V at position lengths-1 (static max-token addressing).
    lengths = jnp.asarray(lengths)
    # shard_map flash-decoding: cache stays sequence-sharded (slot layout)
    # or block-home-sharded (paged pool), LSE merge across shards
    # (EXPERIMENTS.md §Perf qwen3-decode)
    from repro.parallel import decode_attn
    from repro.parallel.hints import active_mesh
    mesh = active_mesh()
    if cfg.kv_layout == "paged":
        # the sharded gate consults the POOL extent (rows incl. null) and
        # must fire before the single-program paged path; no rolling-SWA
        # variant exists, so windowed configs stay single-program
        if cfg.window is None and decode_attn.usable(
                mesh, b, cfg.n_heads, cfg.n_kv_heads, cache["k"].shape[0],
                lengths, paged=True):
            return _attn_decode_paged_sharded(cfg, p, q, k, v, cache,
                                              lengths, page_table,
                                              write_mask, mesh)
        return _attn_decode_paged(cfg, p, q, k, v, cache, lengths,
                                  page_table, write_mask)
    cache_len = cache["k"].shape[2]
    rolling = cfg.window is not None and cache_len <= cfg.window

    if decode_attn.usable(mesh, b, cfg.n_heads, cfg.n_kv_heads,
                          cache_len, lengths, paged=False):
        scales = ((cache["k_scale"], cache["v_scale"])
                  if cfg.kv_quant == "int8" else None)
        o, new_cache = decode_attn.decode_attention_sharded(
            q, k, v, cache["k"], cache["v"], lengths, mesh, rolling=rolling,
            scales=scales)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
        out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
        return out, new_cache
    if rolling:
        # SWA rolling buffer: slot = (pos mod window).  RoPE is applied
        # before caching, and softmax is permutation-invariant, so slot
        # order inside the buffer is irrelevant.
        write_idx = (lengths - 1) % cache_len
        attn_len = jnp.minimum(lengths, cache_len)
        attn_window = None          # every valid slot participates
    else:
        write_idx = lengths - 1
        attn_len = lengths
        attn_window = cfg.window
    if cfg.kv_quant == "int8":
        # unsharded path: quantized write + FUSED dequant attention — the
        # int8 cache and its scales go straight into ops.decode_attention,
        # which rescales partial sums in-kernel (no full-precision copy)
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        if lengths.ndim == 0:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], kq, (0, 0, write_idx, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], vq, (0, 0, write_idx, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, 0, write_idx, 0)),
                "v_scale": jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, 0, write_idx, 0)),
            }
        else:
            # ragged batch (slot-based serving): per-row scatter
            def upd(c, new, l):
                return jax.lax.dynamic_update_slice(c, new, (0, l, 0))
            new_cache = {
                "k": jax.vmap(upd)(cache["k"], kq, write_idx),
                "v": jax.vmap(upd)(cache["v"], vq, write_idx),
                "k_scale": jax.vmap(upd)(cache["k_scale"], ks, write_idx),
                "v_scale": jax.vmap(upd)(cache["v_scale"], vs, write_idx),
            }
        o = ops.decode_attention(q, new_cache["k"], new_cache["v"], attn_len,
                                 window=attn_window,
                                 k_scale=new_cache["k_scale"],
                                 v_scale=new_cache["v_scale"])
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
        out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
        return out, new_cache
    if lengths.ndim == 0:
        # common serving case (uniform batch): O(1) in-place slice update
        k_new = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, write_idx, 0))
        v_new = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, write_idx, 0))
    else:
        # ragged batch: per-row scatter via vmap'd slice update
        def upd(c, new, l):
            return jax.lax.dynamic_update_slice(c, new, (0, l, 0))
        k_new = jax.vmap(upd)(cache["k"], k.astype(cache["k"].dtype), write_idx)
        v_new = jax.vmap(upd)(cache["v"], v.astype(cache["v"].dtype), write_idx)
    o = ops.decode_attention(q, k_new, v_new, attn_len, window=attn_window)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
    return out, {"k": k_new, "v": v_new}


def _attn_decode_paged_sharded(cfg, p: Params, q, k, v, cache: Params,
                               lengths, page_table, write_mask, mesh):
    """Paged one-token decode across a device mesh: the pool is partitioned
    into block homes (``parallel/decode_attn.paged_homes``), each shard
    writes/attends only blocks it is home to, and the flash-decoding LSE
    merge combines the partials.  The host allocator guarantees page-table
    entries resolve to (shard, local block) consistently with this split."""
    from repro.parallel import decode_attn
    b = q.shape[0]
    if page_table is None:
        page_table = default_page_table(b, cache["k"].shape[0])
    scales = ((cache["k_scale"], cache["v_scale"])
              if cfg.kv_quant == "int8" else None)
    o, new_cache = decode_attn.decode_attention_sharded_paged(
        q, k, v, cache["k"], cache["v"], lengths, page_table, write_mask,
        mesh, scales=scales)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return linear(o, p["wo"], use_kernels=cfg.use_kernels), new_cache


def _attn_decode_paged(cfg, p: Params, q, k, v, cache: Params, lengths,
                       page_table, write_mask):
    """Paged one-token decode: scatter the new K/V through the page table,
    then attend via the paged kernels.  Rolling SWA works transparently —
    the modular slot index is just another logical position the table maps."""
    b = q.shape[0]
    bs = cache["k"].shape[2]
    if page_table is None:
        page_table = default_page_table(b, cache["k"].shape[0])
    n_pos = page_table.shape[1] * bs     # addressable logical positions
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    rolling = cfg.window is not None and n_pos <= cfg.window
    if rolling:
        write_idx = (lengths - 1) % n_pos
        attn_len = jnp.minimum(lengths, n_pos)
        attn_window = None
    else:
        write_idx = jnp.clip(lengths - 1, 0, n_pos - 1)
        attn_len = lengths
        attn_window = cfg.window
    if cfg.kv_quant == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = {
            "k": _paged_token_write(cache["k"], kq[:, :, 0], page_table,
                                    write_idx, write_mask),
            "v": _paged_token_write(cache["v"], vq[:, :, 0], page_table,
                                    write_idx, write_mask),
            "k_scale": _paged_token_write(cache["k_scale"], ks[:, :, 0],
                                          page_table, write_idx, write_mask),
            "v_scale": _paged_token_write(cache["v_scale"], vs[:, :, 0],
                                          page_table, write_idx, write_mask),
        }
        o = ops.decode_attention(q, new_cache["k"], new_cache["v"], attn_len,
                                 window=attn_window,
                                 k_scale=new_cache["k_scale"],
                                 v_scale=new_cache["v_scale"],
                                 page_table=page_table)
    else:
        new_cache = {
            "k": _paged_token_write(cache["k"], k[:, :, 0], page_table,
                                    write_idx, write_mask),
            "v": _paged_token_write(cache["v"], v[:, :, 0], page_table,
                                    write_idx, write_mask),
        }
        o = ops.decode_attention(q, new_cache["k"], new_cache["v"], attn_len,
                                 window=attn_window, page_table=page_table)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return linear(o, p["wo"], use_kernels=cfg.use_kernels), new_cache


# -- cross attention (Whisper decoder) --------------------------------------

def cross_attn_init(key, cfg) -> Params:
    return attn_init(key, cfg)


def cross_attn_apply(cfg, p: Params, x: jax.Array, enc_kv: tuple) -> jax.Array:
    """x (b, s, d) attends to precomputed encoder K/V (b, hkv, s_enc, hd)."""
    b, s, _ = x.shape
    hd, hq = cfg.head_dim, cfg.n_heads
    q = linear(x, p["wq"], p.get("bq"), use_kernels=cfg.use_kernels)
    q = q.reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k, v = enc_kv
    o = ops.attention(q, k, v, causal=False,
                      impl="pallas" if cfg.use_kernels else "xla")
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return linear(o, p["wo"], use_kernels=cfg.use_kernels)


def cross_kv(cfg, p: Params, enc_out: jax.Array) -> tuple:
    """Precompute cross-attention K/V from encoder output (done once)."""
    b, s, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = linear(enc_out, p["wk"], p.get("bk"), use_kernels=cfg.use_kernels)
    v = linear(enc_out, p["wv"], p.get("bv"), use_kernels=cfg.use_kernels)
    return (k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3),
            v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3))
