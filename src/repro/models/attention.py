"""Attention module: GQA/MQA, RoPE/M-RoPE, qk-norm, SWA, KV cache.

Train/prefill path goes through ``ops.attention`` (Pallas flash kernel on
TPU, dense oracle on CPU); decode path uses ``ops.decode_attention`` against
a preallocated MAX-token cache (the paper's static-address trick, §IV-B).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers
from repro.models.layers import Params, dense_init, linear


def attn_init(key, cfg, d_model: int | None = None) -> Params:
    d = d_model or cfg.d_model
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, hq * hd, cfg.dtype),
        "wk": dense_init(ks[1], d, hkv * hd, cfg.dtype),
        "wv": dense_init(ks[2], d, hkv * hd, cfg.dtype),
        "wo": dense_init(ks[3], hq * hd, d, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((hkv * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((hkv * hd,), cfg.dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.dtype)
    return p


def _project_qkv(cfg, p: Params, x: jax.Array, positions):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    uk = cfg.use_kernels
    q = linear(x, p["wq"], p.get("bq"), use_kernels=uk).reshape(b, s, hq, hd)
    k = linear(x, p["wk"], p.get("bk"), use_kernels=uk).reshape(b, s, hkv, hd)
    v = linear(x, p["wv"], p.get("bv"), use_kernels=uk).reshape(b, s, hkv, hd)
    q = q.transpose(0, 2, 1, 3)   # (b, h, s, d)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = layers.rmsnorm(q, p["q_norm"])
        k = layers.rmsnorm(k, p["k_norm"])
    if cfg.rope_type == "standard":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q = layers.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def attn_apply(cfg, p: Params, x: jax.Array, positions, *,
               causal: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    o = ops.attention(q, k, v, causal=causal, window=cfg.window,
                      impl="pallas" if cfg.use_kernels else "xla")
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return linear(o, p["wo"], use_kernels=cfg.use_kernels)


def init_kv_cache(cfg, batch: int, max_len: int, d_model=None) -> Params:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_quant == "int8":
        # per-(token, head) absmax scale over head_dim — the paper's
        # block-scale packing applied to the dynamic operand (beyond-paper)
        return {
            "k": jnp.zeros((batch, hkv, max_len, hd), jnp.int8),
            "v": jnp.zeros((batch, hkv, max_len, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, hkv, max_len, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, hkv, max_len, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, hkv, max_len, hd), cfg.dtype),
        "v": jnp.zeros((batch, hkv, max_len, hd), cfg.dtype),
    }


def kv_cache_slot_axes(cfg, axis: int = 1) -> Params:
    """Pytree (matching ``init_kv_cache`` structure) of batch/slot axes.

    Callers stack per-layer caches along leading axes, so the request-slot
    axis of each leaf is ``axis`` (1 for a single (layers, B, ...) stack).
    Consumed by ``models.api.insert_request`` / ``evict_slot``.
    """
    axes: Params = {"k": axis, "v": axis}
    if cfg.kv_quant == "int8":
        axes["k_scale"] = axis
        axes["v_scale"] = axis
    return axes


def quantize_kv(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(…, hd) -> int8 values + per-vector absmax scale."""
    a = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(a / 127.0, 1e-10)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def attn_prefill(cfg, p: Params, x: jax.Array, positions, cache: Params):
    """Prefill: run full attention AND populate the cache.

    With a sliding-window (rolling) cache smaller than the prompt, only the
    last ``cache_len`` tokens' K/V are retained — exactly the set SWA decode
    will ever attend to."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    o = ops.attention(q, k, v, causal=True, window=cfg.window,
                      impl="pallas" if cfg.use_kernels else "xla")
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
    cache_len = cache["k"].shape[2]
    if cache_len < s:
        k = k[:, :, -cache_len:]
        v = v[:, :, -cache_len:]
    if cfg.kv_quant == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                                    (0, 0, 0, 0)),
            "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                                    (0, 0, 0, 0)),
        }
        return out, cache
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, 0, 0)),
    }
    return out, cache


def _chunk_write(cache_leaf: jax.Array, new: jax.Array, starts: jax.Array,
                 q_lens: jax.Array) -> jax.Array:
    """Scatter per-row variable-length chunks into a (B, hkv, L, w) cache.

    Row ``b`` writes ``new[b, :, :q_lens[b]]`` at positions
    ``starts[b] .. starts[b] + q_lens[b] - 1`` — a read-modify-write of one
    C-wide block per row, so positions outside the live span keep their
    current cache values exactly (a q_lens == 0 row is a no-op, and a row
    near the MAX boundary never clobbers valid neighbors the way a clamped
    ``dynamic_update_slice`` of the raw chunk would).  Callers guarantee
    ``starts + q_lens <= L``.
    """
    c = new.shape[2]
    cache_len = cache_leaf.shape[2]
    idx = jnp.arange(c)

    def one(dst, blk, start, ql):
        off = jnp.clip(start, 0, cache_len - c)
        delta = start - off            # 0 unless the block straddles the end
        cur = jax.lax.dynamic_slice_in_dim(dst, off, c, axis=1)
        shifted = jnp.roll(blk, delta, axis=1)
        mask = (idx >= delta) & (idx < delta + ql)
        merged = jnp.where(mask[None, :, None], shifted.astype(dst.dtype), cur)
        return jax.lax.dynamic_update_slice_in_dim(dst, merged, off, axis=1)

    return jax.vmap(one)(cache_leaf, new, jnp.asarray(starts, jnp.int32),
                         jnp.asarray(q_lens, jnp.int32))


def attn_mixed(cfg, p: Params, x: jax.Array, positions, cache: Params,
               lengths: jax.Array, q_lens: jax.Array):
    """Mixed prefill/decode attention step.  x (b, C, d); ``lengths`` (b,) =
    valid cache tokens BEFORE this step; ``q_lens`` (b,) = live new tokens
    per row (1 = decoding row, up to C = mid-prefill row; the rest of the
    chunk is padding).  Writes each row's live K/V at its true positions —
    no left-pad bucket writes — then attends over the cache with intra-chunk
    causal masking.  Requires ``lengths + q_lens <= cache_len`` (the serving
    scheduler's cache-room invariant), which also means a rolling-SWA buffer
    never wraps here — so the rolling case degenerates to the non-rolling
    one and ``cfg.window`` masking applies directly.
    """
    b, c, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    lengths = jnp.asarray(lengths, jnp.int32)
    q_lens = jnp.asarray(q_lens, jnp.int32)
    total = lengths + q_lens

    if cfg.kv_quant == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = {
            "k": _chunk_write(cache["k"], kq, lengths, q_lens),
            "v": _chunk_write(cache["v"], vq, lengths, q_lens),
            "k_scale": _chunk_write(cache["k_scale"], ks, lengths, q_lens),
            "v_scale": _chunk_write(cache["v_scale"], vs, lengths, q_lens),
        }
        o = ops.mixed_attention(q, new_cache["k"], new_cache["v"], total,
                                q_lens, window=cfg.window,
                                k_scale=new_cache["k_scale"],
                                v_scale=new_cache["v_scale"])
    else:
        new_cache = {
            "k": _chunk_write(cache["k"], k, lengths, q_lens),
            "v": _chunk_write(cache["v"], v, lengths, q_lens),
        }
        o = ops.mixed_attention(q, new_cache["k"], new_cache["v"], total,
                                q_lens, window=cfg.window)
    o = o.transpose(0, 2, 1, 3).reshape(b, c, cfg.n_heads * cfg.head_dim)
    out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
    return out, new_cache


def attn_decode(cfg, p: Params, x: jax.Array, positions, cache: Params,
                lengths: jax.Array):
    """One-token decode.  x (b, 1, d); lengths (b,) = context length
    *including* the new token."""
    b = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x, positions)
    # write the new K/V at position lengths-1 (static max-token addressing).
    lengths = jnp.asarray(lengths)
    cache_len = cache["k"].shape[2]
    rolling = cfg.window is not None and cache_len <= cfg.window

    # shard_map flash-decoding: cache stays sequence-sharded, LSE merge
    # across shards (EXPERIMENTS.md §Perf qwen3-decode)
    from repro.parallel import decode_attn
    from repro.parallel.hints import active_mesh
    mesh = active_mesh()
    if decode_attn.usable(mesh, b, cfg.n_heads, cfg.n_kv_heads,
                          cache_len, lengths):
        scales = ((cache["k_scale"], cache["v_scale"])
                  if cfg.kv_quant == "int8" else None)
        o, new_cache = decode_attn.decode_attention_sharded(
            q, k, v, cache["k"], cache["v"], lengths, mesh, rolling=rolling,
            scales=scales)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
        out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
        return out, new_cache
    if rolling:
        # SWA rolling buffer: slot = (pos mod window).  RoPE is applied
        # before caching, and softmax is permutation-invariant, so slot
        # order inside the buffer is irrelevant.
        write_idx = (lengths - 1) % cache_len
        attn_len = jnp.minimum(lengths, cache_len)
        attn_window = None          # every valid slot participates
    else:
        write_idx = lengths - 1
        attn_len = lengths
        attn_window = cfg.window
    if cfg.kv_quant == "int8":
        # unsharded path: quantized write + FUSED dequant attention — the
        # int8 cache and its scales go straight into ops.decode_attention,
        # which rescales partial sums in-kernel (no full-precision copy)
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        if lengths.ndim == 0:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], kq, (0, 0, write_idx, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], vq, (0, 0, write_idx, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, 0, write_idx, 0)),
                "v_scale": jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, 0, write_idx, 0)),
            }
        else:
            # ragged batch (slot-based serving): per-row scatter
            def upd(c, new, l):
                return jax.lax.dynamic_update_slice(c, new, (0, l, 0))
            new_cache = {
                "k": jax.vmap(upd)(cache["k"], kq, write_idx),
                "v": jax.vmap(upd)(cache["v"], vq, write_idx),
                "k_scale": jax.vmap(upd)(cache["k_scale"], ks, write_idx),
                "v_scale": jax.vmap(upd)(cache["v_scale"], vs, write_idx),
            }
        o = ops.decode_attention(q, new_cache["k"], new_cache["v"], attn_len,
                                 window=attn_window,
                                 k_scale=new_cache["k_scale"],
                                 v_scale=new_cache["v_scale"])
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
        out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
        return out, new_cache
    if lengths.ndim == 0:
        # common serving case (uniform batch): O(1) in-place slice update
        k_new = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, write_idx, 0))
        v_new = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, write_idx, 0))
    else:
        # ragged batch: per-row scatter via vmap'd slice update
        def upd(c, new, l):
            return jax.lax.dynamic_update_slice(c, new, (0, l, 0))
        k_new = jax.vmap(upd)(cache["k"], k.astype(cache["k"].dtype), write_idx)
        v_new = jax.vmap(upd)(cache["v"], v.astype(cache["v"].dtype), write_idx)
    o = ops.decode_attention(q, k_new, v_new, attn_len, window=attn_window)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    out = linear(o, p["wo"], use_kernels=cfg.use_kernels)
    return out, {"k": k_new, "v": v_new}


# -- cross attention (Whisper decoder) --------------------------------------

def cross_attn_init(key, cfg) -> Params:
    return attn_init(key, cfg)


def cross_attn_apply(cfg, p: Params, x: jax.Array, enc_kv: tuple) -> jax.Array:
    """x (b, s, d) attends to precomputed encoder K/V (b, hkv, s_enc, hd)."""
    b, s, _ = x.shape
    hd, hq = cfg.head_dim, cfg.n_heads
    q = linear(x, p["wq"], p.get("bq"), use_kernels=cfg.use_kernels)
    q = q.reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k, v = enc_kv
    o = ops.attention(q, k, v, causal=False,
                      impl="pallas" if cfg.use_kernels else "xla")
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return linear(o, p["wo"], use_kernels=cfg.use_kernels)


def cross_kv(cfg, p: Params, enc_out: jax.Array) -> tuple:
    """Precompute cross-attention K/V from encoder output (done once)."""
    b, s, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = linear(enc_out, p["wk"], p.get("bk"), use_kernels=cfg.use_kernels)
    v = linear(enc_out, p["wv"], p.get("bv"), use_kernels=cfg.use_kernels)
    return (k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3),
            v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3))
