"""Model configuration dataclass shared by every architecture."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes any architecture in the zoo.

    Family selects the block assembly:
      dense  — decoder-only transformer
      moe    — dense with MoE FFN
      ssm    — xLSTM stack (sLSTM + mLSTM blocks)
      hybrid — Zamba2: Mamba2 backbone + shared attention block
      audio  — Whisper encoder-decoder (conv frontend stubbed)
      vlm    — Qwen2-VL backbone (patch frontend stubbed, M-RoPE)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    activation: str = "swiglu"        # swiglu | geglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_type: str = "standard"       # standard | mrope | none
    rope_theta: float = 1_000_000.0
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    window: int | None = None         # sliding-window attention (Mixtral)
    tie_embeddings: bool = False
    embed_scale: bool = False         # Gemma: scale embeddings by sqrt(d)
    logit_softcap: float | None = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (Mamba2 / xLSTM)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    slstm_every: int = 0              # xLSTM: one sLSTM block every N (0 = none)
    shared_attn_every: int = 6        # Zamba2: shared attn block cadence
    n_shared_blocks: int = 2          # Zamba2: number of distinct shared blocks

    # Whisper
    n_encoder_layers: int = 0
    encoder_frames: int = 1500

    # KV cache quantization (beyond-paper: EdgeLLM keeps KV FP16; this
    # extends the block-scale packing to the cache — KIVI-style)
    kv_quant: str = "none"            # none | int8

    # KV cache layout (beyond-paper: EdgeLLM sizes every request for the MAX
    # token count so instruction streams stay static; "paged" keeps that
    # one-data-shape dispatch contract but leases fixed-size blocks from a
    # shared pool via a per-slot page table, so short requests stop paying
    # for long ones — vLLM-style paging on top of the slot cache)
    kv_layout: str = "slot"           # slot | paged
    kv_block_size: int = 16           # tokens per page (paged layout only)
    kv_pool_blocks: int = 0           # shared-pool blocks (0 = B * pages/slot)

    # numerics / execution
    dtype: Any = jnp.bfloat16
    remat: str = "block"              # none | block
    scan_layers: bool = True
    use_kernels: bool = False         # Pallas path (CPU tests use XLA path)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.kv_layout not in ("slot", "paged"):
            raise ValueError(f"unknown kv_layout {self.kv_layout!r}")
        if self.kv_layout == "paged" and self.kv_block_size < 1:
            raise ValueError("kv_block_size must be >= 1 for paged layout")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, hq, hkv = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * hd * hq + 2 * d * hd * hkv + hd * hq * d
        if self.activation in ("swiglu", "geglu"):
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        if self.is_moe:
            ffn = self.n_experts * ffn + d * self.n_experts  # + router
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":   # xLSTM blocks
            per = self._xlstm_block_params()
            return self.n_layers * per + emb
        if self.family == "hybrid":
            mamba = self._mamba_block_params()
            n_shared = self.n_layers // self.shared_attn_every
            shared = self.n_shared_blocks * (attn + 3 * d * f)
            return self.n_layers * mamba + shared + emb
        if self.family == "audio":
            enc = self.n_encoder_layers * (attn + ffn)
            dec = self.n_layers * (2 * attn + ffn)  # self + cross
            return enc + dec + emb
        return self.n_layers * (attn + ffn) + emb

    def _mamba_block_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)
        conv = (di + 2 * n) * self.ssm_conv
        out_proj = di * d
        return in_proj + conv + out_proj + 2 * h

    def _xlstm_block_params(self) -> int:
        d = self.d_model
        di = 2 * d
        # mLSTM block: up 2*di, qkv from di, gates, out di*d
        return d * 2 * di + di * 3 * di // 2 + di * d + 6 * di

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        ffn_all = self.n_experts * 3 * d * f
        ffn_active = self.top_k * 3 * d * f
        return self.param_count() - self.n_layers * (ffn_all - ffn_active)
