"""Shared neural-net layers (functional, pytree params).

Every weight matmul goes through :func:`linear`, which dispatches on the
parameter type: dense array, :class:`QuantizedTensor` (W4A16 path) or
:class:`SparseQuantizedTensor` (log-scale sparse path).  This is how the
paper's technique is a *first-class* feature: quantizing a model for serving
is a pure pytree transform (see ``repro.core.compiler.quantize_model``) and
no model code changes.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor
from repro.core.sparsity import SparseQuantizedTensor
from repro.kernels import ops

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_f: int, out_f: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_f)
    return (jax.random.normal(key, (in_f, out_f), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# linear dispatch (dense | W4A16 | sparse W4A16)
# ---------------------------------------------------------------------------

def linear(x: jax.Array, w, b=None, *, use_kernels: bool = False) -> jax.Array:
    if isinstance(w, QuantizedTensor):
        y = ops.w4a16_matmul(x, w, impl="pallas" if use_kernels else "xla")
    elif isinstance(w, SparseQuantizedTensor):
        y = ops.sparse_w4a16_matmul(x, w, impl="pallas" if use_kernels else "xla")
    else:
        # plain compute-dtype dot: the MXU accumulates f32 internally either
        # way, but preferred_element_type=f32 + cast would put every
        # backward dx all-reduce in f32 — 2x wire bytes (§Perf it.5)
        if w.dtype != x.dtype:
            w = w.astype(x.dtype)
        y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())))
        y = y.astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_init(cfg, d: int | None = None) -> Params:
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"gamma": jnp.ones((d,), cfg.dtype)}
    return {"gamma": jnp.ones((d,), cfg.dtype), "beta": jnp.zeros((d,), cfg.dtype)}


def apply_norm(cfg, p: Params, x: jax.Array) -> jax.Array:
    if "beta" in p:
        return layernorm(x, p["gamma"], p["beta"])
    return rmsnorm(x, p["gamma"])


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (b, h, s, d); positions (b, s) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                             # (d/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (b,1,s,d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, ...]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL §3.1).

    positions (3, b, s): temporal / height / width position ids.  The d/2
    frequency slots are split into ``sections`` (summing to d/2); each section
    rotates by its own positional stream.  Text tokens carry t == h == w, in
    which case M-RoPE degenerates to standard RoPE (tested).
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                             # (half,)
    # build per-slot position stream: (b, s, half)
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                        total_repeat_length=half)            # (half,)
    pos = jnp.transpose(positions, (1, 2, 0)).astype(jnp.float32)  # (b,s,3)
    pos_per_slot = jnp.take_along_axis(
        pos, jnp.broadcast_to(sec_id, pos.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1)                                             # (b,s,half)
    angles = pos_per_slot[:, None] * freqs                   # (b,1,s,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positions_for(cfg, batch: int, seq: int, offset=0) -> jax.Array:
    """Canonical position ids for the config's rope type."""
    base = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    base = jnp.broadcast_to(base, (batch, seq))
    if cfg.rope_type == "mrope":
        return jnp.broadcast_to(base[None], (3, batch, seq))
    return base


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "gate": dense_init(ks[0], d, f, cfg.dtype),
            "up": dense_init(ks[1], d, f, cfg.dtype),
            "down": dense_init(ks[2], f, d, cfg.dtype),
        }
    return {
        "up": dense_init(ks[0], d, f, cfg.dtype),
        "up_bias": jnp.zeros((f,), cfg.dtype),
        "down": dense_init(ks[1], f, d, cfg.dtype),
        "down_bias": jnp.zeros((d,), cfg.dtype),
    }


def mlp_apply(cfg, p: Params, x: jax.Array) -> jax.Array:
    """One MLP = ONE operator.

    ``ops.ffn_w4a16`` dispatches the whole FFN: the fused Pallas kernel for
    quantized weights under ``cfg.use_kernels`` (one dispatch per MLP,
    hidden state resident in VMEM), the blocked-XLA twin for quantized
    weights elsewhere, and the seed's exact unfused composition for plain
    16-bit weights — the latter ALSO under ``use_kernels``, because the
    training path must stay differentiable and keep ``linear``'s dot
    numerics (custom-VJP-free Pallas calls don't differentiate)."""
    quantized = any(
        isinstance(p.get(k), (QuantizedTensor, SparseQuantizedTensor))
        for k in ("gate", "up", "down"))
    return ops.ffn_w4a16(
        x, p.get("gate"), p["up"], p["down"], activation=cfg.activation,
        up_bias=p.get("up_bias"), down_bias=p.get("down_bias"),
        impl="pallas" if (cfg.use_kernels and quantized) else "xla")
