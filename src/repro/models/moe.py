"""Mixture-of-Experts FFN (Mixtral 8×top-2, Granite 40e×top-8).

Two execution paths:

* **shard_map path** (training under a mesh) — the production path.  XLA's
  automatic partitioner replicates the vmapped dispatch gather/scatter
  buffers and contraction-shards the expert matmuls (measured on
  mixtral-8x22b train: 4.5 TB/device of all-reduce + 1.2 TB of replicated
  scatter-add per step — EXPERIMENTS.md §Perf iteration 2).  shard_map makes
  the intent explicit instead:

      - tokens stay on their data shard (dispatch is 100 % local — the
        paper's "no data rearrangement" discipline applied to routing);
      - expert weights are TP-sharded over ``model`` on the hidden axis and
        FSDP-sharded over ``data``; the data shards are all-gathered once
        per layer (the ZeRO-3 gather), its transpose is the grads'
        reduce-scatter;
      - gate/up are column-parallel, down is row-parallel with one psum —
        exactly Megatron discipline, two collectives per MoE layer.

* **local path** (no mesh / quantized serving) — plain vmapped dispatch;
  also the numerical oracle the shard_map path is tested against.

Dispatch is capacity-based per group (= per sequence): C = ceil(S · top_k /
E · capacity_factor), overflow drops to a trash row.  Router in f32 +
GShard load-balance aux loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.quant import QuantizedTensor
from repro.core.sparsity import SparseQuantizedTensor
from repro.kernels import ops
from repro.models.layers import Params, dense_init, linear
from repro.parallel.compat import shard_map
from repro.parallel.hints import active_mesh


def moe_init(key, cfg) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(cfg.dtype),
        "up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(cfg.dtype),
        "down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) / jnp.sqrt(f)).astype(cfg.dtype),
    }


def capacity(cfg, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.moe_capacity_factor)
    return max(cfg.top_k, min(c, tokens_per_group))


# ---------------------------------------------------------------------------
# routing + dispatch (local to one shard / one process)
# ---------------------------------------------------------------------------

def _route(cfg, router, x):
    """x (B, S, d) -> (topw, topi (B, S, k), me, ce).

    me/ce are the per-expert mean prob / token fraction (GShard aux terms),
    returned unreduced so the shard_map path can pmean them across shards
    BEFORE the product (exact global aux, not a mean-of-products)."""
    e, k = cfg.n_experts, cfg.top_k
    bsz, seq, _ = x.shape
    # router matmul in the compute dtype (a f32 matmul here would inject a
    # f32 dx psum per layer — §Perf it.4); softmax statistics in f32
    logits = linear(x, router.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(
        jnp.ones((bsz * seq * k,), jnp.float32)) / (bsz * seq * k)
    return topw, topi, me, ce


def _aux_loss(cfg, me, ce):
    return cfg.n_experts * jnp.sum(me * ce)


def _dispatch_compute(cfg, x, topi, topw, expert_fn):
    """Group-local gather dispatch.  x (B, S, d); expert_fn maps
    (E, C, d) -> (E, C, d_out)."""
    bsz, seq, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(cfg, seq)

    def group(xg, ig, wg):
        flat_e = ig.reshape(-1)                               # (S*k,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)
        pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = pos < cap
        dst = jnp.where(keep, flat_e * cap + pos, e * cap)
        src = jnp.repeat(jnp.arange(seq), k)
        buf = jnp.zeros((e * cap + 1, d), xg.dtype).at[dst].set(xg[src])
        return buf[: e * cap].reshape(e, cap, d), (dst, src, keep, wg)

    hidden, meta = jax.vmap(group)(x, topi, topw)             # (B, E, C, d)
    out_e = expert_fn(hidden)                                  # (B, E, C, d_out)
    d_out = out_e.shape[-1]

    def combine(oe, m):
        dst, src, keep, wg = m
        flat = jnp.concatenate(
            [oe.reshape(-1, d_out), jnp.zeros((1, d_out), oe.dtype)])
        gathered = flat[dst] * (wg.reshape(-1)[:, None] *
                                keep[:, None]).astype(oe.dtype)
        return jnp.zeros((x.shape[1], d_out), oe.dtype).at[src].add(gathered)

    return jax.vmap(combine)(out_e, meta)


# ---------------------------------------------------------------------------
# local (single-shard / quantized-serving) path
# ---------------------------------------------------------------------------

def _moe_apply_local(cfg, p: Params, x: jax.Array):
    topw, topi, me, ce = _route(cfg, p["router"], x)
    aux = _aux_loss(cfg, me, ce)

    def expert_fn(hidden):  # (B, E, C, d)
        def ff(h, gw, uw, dw):
            if any(isinstance(w, (QuantizedTensor, SparseQuantizedTensor))
                   for w in (gw, uw, dw)):
                # quantized serving experts: whole FFN as one op (fused
                # kernel on TPU, blocked-XLA twin elsewhere)
                return ops.ffn_w4a16(
                    h, gw, uw, dw, activation="swiglu",
                    impl="pallas" if cfg.use_kernels else "xla")
            a = jax.nn.silu(linear(h, gw, use_kernels=cfg.use_kernels)) * linear(
                h, uw, use_kernels=cfg.use_kernels)
            return linear(a, dw, use_kernels=cfg.use_kernels)

        return jax.vmap(jax.vmap(ff, in_axes=(0, 0, 0, 0)),
                        in_axes=(0, None, None, None))(
            hidden, p["gate"], p["up"], p["down"])

    out = _dispatch_compute(cfg, x, topi, topw, expert_fn)
    return out, aux


# ---------------------------------------------------------------------------
# shard_map (quantized serving) path
# ---------------------------------------------------------------------------

def _moe_apply_shard_map_quant(cfg, p: Params, x: jax.Array, mesh):
    """Serve-mode MoE with W4A16 experts under shard_map.

    The vmapped local path lets XLA's partitioner replicate the dispatch
    buffers across the model axis (1.5 TB/device temp on mixtral
    prefill_32k — §Perf it.8).  Here: experts TP-sharded over ``model`` on
    the hidden axis (packed nibbles + per-group scales shard together),
    dispatch runs redundantly per model shard (index math only), one psum
    after combine.  No FSDP gathers — serve weights replicate over data.

    Each expert's FFN dispatches through ``ops.ffn_w4a16`` (the fused
    Pallas kernel on TPU, the blocked-XLA twin elsewhere) — the dense
    dequantize-everything oracle is no longer in this hot loop.
    """
    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    M = mesh.shape["model"]
    gate, up, down = p["gate"], p["up"], p["down"]
    e = cfg.n_experts
    d = cfg.d_model
    f = cfg.d_ff
    gs_col = gate.group_size
    gs_row = down.group_size

    def local_fn(x_l, router, g_pk, g_sc, u_pk, u_sc, d_pk, d_sc):
        topw, topi, me, ce = _route(cfg, router, x_l)
        aux = _aux_loss(cfg, jax.lax.pmean(me, da), jax.lax.pmean(ce, da))

        f_loc = f // M

        def expert_fn(hidden):  # (B_l, E, C, d)
            def one(h, gp, gsc, upk, usc, dpk, dsc):
                gl = QuantizedTensor(gp, gsc, (d, f_loc), gs_col)
                ul = QuantizedTensor(upk, usc, (d, f_loc), gs_col)
                dl = QuantizedTensor(dpk, dsc, (f_loc, d), gs_row)
                return ops.ffn_w4a16(
                    h, gl, ul, dl, activation="swiglu",
                    impl="pallas" if cfg.use_kernels else "xla")

            return jax.vmap(one, in_axes=(1, 0, 0, 0, 0, 0, 0), out_axes=1)(
                hidden, g_pk, g_sc, u_pk, u_sc, d_pk, d_sc)

        out = _dispatch_compute(cfg, x_l, topi, topw, expert_fn)
        out = jax.lax.psum(out, "model")   # row-parallel down partials
        return out, aux

    col_pk = P(None, None, "model")       # (E, d/2, f)
    col_sc = P(None, None, "model")       # (E, d/gs, f)
    row_pk = P(None, "model", None)       # (E, f/2, d)
    row_sc = P(None, "model", None)       # (E, f/gs, d)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(da, None, None), P(), col_pk, col_sc, col_pk, col_sc,
                  row_pk, row_sc),
        out_specs=(P(da, None, None), P()),
    )
    return fn(x, p["router"], gate.packed, gate.scales, up.packed, up.scales,
              down.packed, down.scales)


# ---------------------------------------------------------------------------
# shard_map (training) path
# ---------------------------------------------------------------------------

def _moe_apply_shard_map(cfg, p: Params, x: jax.Array, mesh):
    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    wspec = da + ("model",)

    def local_fn(x_l, router, gate_l, up_l, down_l):
        # ZeRO-3 gather of the data-sharded expert weights (transpose =
        # reduce-scatter of their grads)
        gate = jax.lax.all_gather(gate_l, da, axis=2, tiled=True)
        up = jax.lax.all_gather(up_l, da, axis=2, tiled=True)
        down = jax.lax.all_gather(down_l, da, axis=1, tiled=True)

        topw, topi, me, ce = _route(cfg, router, x_l)
        # exact global aux: average the statistics, then take the product
        aux = _aux_loss(cfg, jax.lax.pmean(me, da), jax.lax.pmean(ce, da))

        def expert_fn(hidden):  # (B_l, E, C, d)
            # column-parallel gate/up (f/model local), row-parallel down.
            # NOTE: no psum here — the combine below is linear in the expert
            # outputs, so the Megatron row-parallel reduction moves AFTER
            # combine, shrinking its payload from E·C slots to S tokens
            # (capacity_factor × top_k / 1 ≈ 2.5× on mixtral; §Perf it.3)
            # compute-dtype operands AND outputs: f32 casts here get hoisted
            # before the FSDP all-gathers (2x gather bytes) and put the
            # d_hidden backward psum in f32 (2x wire) — §Perf it.4/5.  The
            # MXU still accumulates each dot in f32 internally.
            h = jnp.einsum("becd,edf->becf", hidden, gate)
            u = jnp.einsum("becd,edf->becf", hidden, up)
            a = jax.nn.silu(h.astype(jnp.float32)).astype(hidden.dtype) * u
            return jnp.einsum("becf,efd->becd", a, down)

        out = _dispatch_compute(cfg, x_l, topi, topw, expert_fn)
        out = jax.lax.psum(out, "model")                      # Megatron row sum
        return out, aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(da, None, None), P(), P(None, None, wspec),
                  P(None, None, wspec), P(None, wspec, None)),
        out_specs=(P(da, None, None), P()),
    )
    return fn(x, p["router"], p["gate"], p["up"], p["down"])


def moe_apply(cfg, p: Params, x: jax.Array):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names or (
            x.shape[0] % _data_size(mesh)):
        return _moe_apply_local(cfg, p, x)
    M = mesh.shape["model"]
    if isinstance(p["gate"], (jax.Array, jax.ShapeDtypeStruct)):
        if cfg.d_ff % (_data_size(mesh) * M) == 0:
            return _moe_apply_shard_map(cfg, p, x, mesh)
    elif isinstance(p["gate"], QuantizedTensor):
        f, gs_row = cfg.d_ff, p["down"].group_size
        if f % M == 0 and (f // 2) % M == 0 and (f // gs_row) % M == 0:
            return _moe_apply_shard_map_quant(cfg, p, x, mesh)
    return _moe_apply_local(cfg, p, x)


def _data_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
