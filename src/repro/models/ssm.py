"""Mamba2 (SSD) layer — training (chunked scan) + decode (recurrent step).

Used by the Zamba2 hybrid backbone.  The SSD state-space recurrence is

    h_t = exp(dt_t · A) · h_{t-1} + dt_t · B_t ⊗ x_t          (state (H,P,N))
    y_t = C_t · h_t + D · x_t

Training uses the chunked algorithm (Mamba2 paper §6): intra-chunk quadratic
attention-like term + inter-chunk state recurrence via ``lax.scan`` over
chunks.  Decode is the O(1) recurrent update.  All state math runs in f32;
projections follow the model dtype (and are quantizable — they are static
weights, so the paper's W4A16 path applies; the scan itself is
activation-side, like the paper's FP16*FP16 MHA mode — DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, linear, rmsnorm

CHUNK = 128


def mamba_init(key, cfg) -> Params:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + h, cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * 0.1).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), cfg.dtype),
        "out_proj": dense_init(ks[4], di, d, cfg.dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  xbc (B, L, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def _split_proj(cfg, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def mamba_apply(cfg, p: Params, x: jax.Array) -> jax.Array:
    """Full-sequence SSD (training/prefill).  x (B, L, d_model)."""
    bsz, L, _ = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim

    proj = linear(x, p["in_proj"], use_kernels=cfg.use_kernels)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(bsz, L, h, ph)
    B = xbc[..., di:di + n]                                  # (B, L, N), G=1
    C = xbc[..., di + n:]                                    # (B, L, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, L, H)
    A = -jnp.exp(p["A_log"])                                 # (H,)

    y = _ssd_chunked(xs.astype(jnp.float32), dt, A,
                     B.astype(jnp.float32), C.astype(jnp.float32))
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, L, di).astype(x.dtype)
    y = rmsnorm(y, p["norm"]) * jax.nn.silu(z)
    return linear(y, p["out_proj"], use_kernels=cfg.use_kernels)


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., Q) -> (..., Q, Q) lower-tri pairwise sums: out[i,j]=sum(a[j+1..i])."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]               # sum(a[j+1..i])
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(xs, dt, A, B, C, chunk: int = CHUNK):
    """Chunked SSD.  xs (b,L,H,P) f32; dt (b,L,H); A (H,); B,C (b,L,N)."""
    b, L, h, ph = xs.shape
    n = B.shape[-1]
    q = min(chunk, L)
    nc = L // q
    assert L % q == 0, (L, q)

    xs_c = xs.reshape(b, nc, q, h, ph)
    dt_c = dt.reshape(b, nc, q, h)
    B_c = B.reshape(b, nc, q, n)
    C_c = C.reshape(b, nc, q, n)

    a_c = dt_c * A[None, None, None, :]                      # (b,nc,q,h) log-decay
    seg = _segsum(jnp.moveaxis(a_c, -1, 2))                  # (b,nc,h,q,q)
    Lmat = jnp.exp(seg)

    # intra-chunk: Y[i] = sum_{j<=i} (C_i·B_j) L[i,j] dt_j x_j
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)             # (b,nc,q,q)
    w = cb[:, :, None] * Lmat * dt_c.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", w, xs_c)

    # chunk-final states: S_c = sum_j exp(acum_last - acum_j) dt_j B_j x_j^T
    acum = jnp.cumsum(a_c, axis=2)                           # (b,nc,q,h)
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)        # (b,nc,q,h)
    S = jnp.einsum("bcqh,bcqn,bcqhp->bchnp",
                   decay_to_end * dt_c, B_c, xs_c)           # (b,nc,h,n,p)

    # inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(acum[:, :, -1, :])                 # (b,nc,h)

    def step(hprev, inp):
        dec, s = inp                                          # (b,h), (b,h,n,p)
        hnew = hprev * dec[..., None, None] + s
        return hnew, hprev

    h0 = jnp.zeros((b, h, n, ph), jnp.float32)
    _, hstates = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S, 1, 0)))
    hstates = jnp.moveaxis(hstates, 0, 1)                    # (b,nc,h,n,p) state BEFORE chunk

    # inter-chunk output: Y[i] += C_i · (exp(acum_i) * H_c)
    in_decay = jnp.exp(acum)                                 # (b,nc,q,h)
    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", C_c, hstates, in_decay)
    return (y_intra + y_inter).reshape(b, L, h, ph)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def mamba_cache_init(cfg, batch: int) -> Params:
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), cfg.dtype),
        "state": jnp.zeros((batch, h, n, cfg.ssm_head_dim), jnp.float32),
    }


def mamba_decode(cfg, p: Params, x: jax.Array, cache: Params):
    """One token.  x (B, 1, d_model)."""
    bsz = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim

    proj = linear(x, p["in_proj"], use_kernels=cfg.use_kernels)
    z, xbc, dt = _split_proj(cfg, proj)                      # (B,1,*)
    window = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)
    conv_out = (window * p["conv_w"][None]).sum(axis=1, keepdims=True)
    xbc = jax.nn.silu(conv_out + p["conv_b"][None, None, :])
    new_conv = window[:, 1:, :]

    xs = xbc[..., :di].reshape(bsz, h, ph)
    B = xbc[..., di:di + n].reshape(bsz, n)
    C = xbc[..., di + n:].reshape(bsz, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32).reshape(bsz, h) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    decay = jnp.exp(dt * A)                                  # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, B, xs.astype(jnp.float32))
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C, state)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rmsnorm(y, p["norm"]) * jax.nn.silu(z)
    out = linear(y, p["out_proj"], use_kernels=cfg.use_kernels)
    return out, {"conv": new_conv, "state": state}
