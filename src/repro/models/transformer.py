"""Decoder-only transformer assembly (dense / MoE / VLM families).

Layers are stacked along a leading axis and executed with ``lax.scan``
(compact HLO — essential for the 512-device dry-run of 56-layer models) with
optional per-block remat.  The same block parameters serve three entry
points: ``forward`` (training), ``prefill`` (populate KV cache) and
``decode_step`` (one token against the cache).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe
from repro.models.config import ModelConfig
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig) -> Params:
    ka, kf = jax.random.split(key)
    p: Params = {
        "ln_attn": layers.norm_init(cfg),
        "attn": attention.attn_init(ka, cfg),
        "ln_mlp": layers.norm_init(cfg),
    }
    if cfg.is_moe:
        p["moe"] = moe.moe_init(kf, cfg)
    else:
        p["mlp"] = layers.mlp_init(kf, cfg)
    return p


def stack_blocks(key, cfg: ModelConfig, n: int, init_fn) -> Params:
    keys = jax.random.split(key, n)
    blocks = [init_fn(k, cfg) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kb, kh = jax.random.split(key, 3)
    p: Params = {
        "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "blocks": stack_blocks(kb, cfg, cfg.n_layers, block_init),
        "ln_f": layers.norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(kh, cfg.d_model, cfg.vocab_size, cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def block_apply(cfg: ModelConfig, p: Params, x: jax.Array, positions) -> tuple:
    h = attention.attn_apply(cfg, p["attn"],
                             layers.apply_norm(cfg, p["ln_attn"], x), positions)
    x = x + h
    inner = layers.apply_norm(cfg, p["ln_mlp"], x)
    if cfg.is_moe:
        f, aux = moe.moe_apply(cfg, p["moe"], inner)
    else:
        f, aux = layers.mlp_apply(cfg, p["mlp"], inner), jnp.float32(0)
    return x + f, aux


def _scan_blocks(cfg: ModelConfig, blocks: Params, x: jax.Array, positions):
    def body(carry, bp):
        y, aux = block_apply(cfg, bp, carry, positions)
        return y, aux

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(body, x, blocks)
        return x, auxs.sum()
    aux_total = jnp.float32(0)
    for i in range(cfg.n_layers):
        bp = jax.tree.map(lambda a: a[i], blocks)
        x, aux = body(x, bp)
        aux_total += aux
    return x, aux_total


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"].T
        logits = layers.linear(x, w)
    else:
        logits = layers.linear(x, params["lm_head"], use_kernels=cfg.use_kernels)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            positions=None, vision_embeds: jax.Array | None = None):
    """tokens (B, S) -> logits (B, S, V); returns (logits, aux_loss)."""
    x = embed_tokens(cfg, params, tokens)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = layers.positions_for(cfg, b, s)
    x, aux = _scan_blocks(cfg, params["blocks"], x, positions)
    x = layers.apply_norm(cfg, params["ln_f"], x)
    return unembed(cfg, params, x), aux


# -- serving -----------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    one = attention.init_kv_cache(cfg, batch, max_len)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
        one)


def cache_slot_axes(cfg: ModelConfig) -> Params:
    """Request-slot axis per cache leaf: (n_layers, B, hkv, L, hd) -> axis 1
    (paged layout: shared-pool leaves, marked -1 — no slot axis)."""
    return attention.kv_cache_slot_axes(cfg, axis=1)


PREFILL_CHUNK = 4096


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, max_len: int):
    """Returns (last-token logits (B, V), cache).

    Long prompts run CHUNKED (Sarathi-style): the prompt is processed in
    PREFILL_CHUNK slices, each attending to the KV cache written so far —
    activation peak becomes O(chunk) instead of O(prompt) (32k prompts cost
    20-600 GB/device otherwise; EXPERIMENTS.md §Perf it.9).  Chunk offsets
    are static (python loop), so the chunked-attention causal pruning still
    skips future KV blocks."""
    b, s = tokens.shape
    if s > PREFILL_CHUNK:
        return _prefill_chunked(cfg, params, tokens, max_len)
    x = embed_tokens(cfg, params, tokens)
    positions = layers.positions_for(cfg, b, s)
    cache = init_cache(cfg, b, max_len)

    def body(carry, inp):
        bp, layer_cache = inp
        h, new_cache = attention.attn_prefill(
            cfg, bp["attn"], layers.apply_norm(cfg, bp["ln_attn"], carry),
            positions, layer_cache)
        x2 = carry + h
        inner = layers.apply_norm(cfg, bp["ln_mlp"], x2)
        if cfg.is_moe:
            f, _ = moe.moe_apply(cfg, bp["moe"], inner)
        else:
            f = layers.mlp_apply(cfg, bp["mlp"], inner)
        return x2 + f, new_cache

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = layers.apply_norm(cfg, params["ln_f"], x[:, -1:])
    return unembed(cfg, params, x)[:, 0], cache


def _prefill_chunked(cfg: ModelConfig, params: Params, tokens: jax.Array,
                     max_len: int):
    from repro.kernels import ops

    b, s = tokens.shape
    cq = PREFILL_CHUNK
    assert s % cq == 0, (s, cq)
    swa = cfg.window is not None and cfg.window <= cq
    cache = init_cache(cfg, b, max_len)
    cache_len = cache["k"].shape[3]
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    # SWA: carry the previous chunk's K/V per layer (covers the window)
    prev_kv = None
    if swa:
        prev_kv = {
            "k": jnp.zeros((cfg.n_layers, b, hkv, cq, hd), cfg.dtype),
            "v": jnp.zeros((cfg.n_layers, b, hkv, cq, hd), cfg.dtype),
        }
    logits = None
    for o in range(0, s, cq):
        x = embed_tokens(cfg, params, tokens[:, o:o + cq])
        positions = layers.positions_for(cfg, b, cq, offset=o)

        def body(carry, inp, o=o):
            if swa:
                bp, layer_cache, pkv = inp
            else:
                bp, layer_cache = inp
                pkv = None
            xin = layers.apply_norm(cfg, bp["ln_attn"], carry)
            q, k, v = attention._project_qkv(cfg, bp["attn"], xin, positions)
            w_off = o % cache_len
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    layer_cache["k"], k.astype(layer_cache["k"].dtype),
                    (0, 0, w_off, 0)),
                "v": jax.lax.dynamic_update_slice(
                    layer_cache["v"], v.astype(layer_cache["v"].dtype),
                    (0, 0, w_off, 0)),
            }
            impl = "pallas" if cfg.use_kernels else "xla"
            if swa:
                # context = previous chunk ++ current chunk, window-masked;
                # chunk 0 has no valid previous chunk (zeros buffer) — skip it
                if o == 0:
                    h = ops.attention(q, k, v, causal=True,
                                      window=cfg.window, impl=impl)
                else:
                    k_ctx = jnp.concatenate(
                        [pkv["k"], k.astype(pkv["k"].dtype)], axis=2)
                    v_ctx = jnp.concatenate(
                        [pkv["v"], v.astype(pkv["v"].dtype)], axis=2)
                    h = ops.attention(q, k_ctx, v_ctx, causal=True,
                                      window=cfg.window, impl=impl)
                new_pkv = {"k": k.astype(pkv["k"].dtype),
                           "v": v.astype(pkv["v"].dtype)}
            else:
                # static slice of everything written so far; q sits at the
                # end of it, so causal pruning applies by construction
                hi = min(o + cq, cache_len)
                k_ctx = jax.lax.slice_in_dim(new_cache["k"], 0, hi, axis=2)
                v_ctx = jax.lax.slice_in_dim(new_cache["v"], 0, hi, axis=2)
                h = ops.attention(q, k_ctx, v_ctx, causal=True,
                                  window=cfg.window, impl=impl)
                new_pkv = None
            h = h.transpose(0, 2, 1, 3).reshape(b, cq, -1)
            h = layers.linear(h, bp["attn"]["wo"], use_kernels=cfg.use_kernels)
            x2 = carry + h
            inner = layers.apply_norm(cfg, bp["ln_mlp"], x2)
            if cfg.is_moe:
                f, _ = moe.moe_apply(cfg, bp["moe"], inner)
            else:
                f = layers.mlp_apply(cfg, bp["mlp"], inner)
            out_cache = (new_cache, new_pkv) if swa else new_cache
            return x2 + f, out_cache

        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        if swa:
            x, (cache, prev_kv) = jax.lax.scan(
                body, x, (params["blocks"], cache, prev_kv))
        else:
            x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
        if o + cq >= s:
            x = layers.apply_norm(cfg, params["ln_f"], x[:, -1:])
            logits = unembed(cfg, params, x)[:, 0]
    return logits, cache


def mixed_step(cfg: ModelConfig, params: Params, cache: Params,
               tokens: jax.Array, lengths, q_lens, *, page_table=None,
               all_logits: bool = False):
    """Mixed prefill/decode step (one dispatch for the whole tick).

    tokens (B, C); ``lengths`` (B,) = valid cache tokens BEFORE this step;
    ``q_lens`` (B,) = live new tokens per row — 1 for a decoding row, up to
    C for a row mid-prefill (its chunk is ``tokens[b, :q_lens[b]]``, the
    rest padding).  Token j of row b sits at true position ``lengths[b]+j``
    (no left-pad bucket positions).  Returns (logits (B, V) of each row's
    LAST live token, new cache).  ``page_table`` (B, pages) routes paged
    K/V placement (None = the linear default table).

    ``all_logits=True`` unembeds EVERY chunk position instead of just the
    last live one, returning (B, C, V) — the draft-verify surface: a
    speculating row's K+1 positions are scored in this one dispatch, so
    acceptance needs zero extra device round-trips.  Position j's row is
    the model's next-token distribution AFTER consuming ``tokens[b, j]``
    (positions past ``q_lens[b]-1`` are padding garbage; callers mask).
    """
    b, c = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    lengths = jnp.asarray(lengths, jnp.int32)
    q_lens = jnp.asarray(q_lens, jnp.int32)
    pos = lengths[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, b, c))

    def body(carry, inp):
        bp, layer_cache = inp
        h, new_cache = attention.attn_mixed(
            cfg, bp["attn"], layers.apply_norm(cfg, bp["ln_attn"], carry),
            pos, layer_cache, lengths, q_lens, page_table=page_table)
        x2 = carry + h
        inner = layers.apply_norm(cfg, bp["ln_mlp"], x2)
        if cfg.is_moe:
            f, _ = moe.moe_apply(cfg, bp["moe"], inner)
        else:
            f = layers.mlp_apply(cfg, bp["mlp"], inner)
        return x2 + f, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    if all_logits:
        # verify surface: every chunk position reaches the LM head
        x = layers.apply_norm(cfg, params["ln_f"], x)
        return unembed(cfg, params, x), new_cache
    # only each row's last live position reaches the LM head (C-fold cheaper
    # than unembedding the full chunk; mid-prefill rows need just this one)
    idx = jnp.clip(q_lens - 1, 0, c - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    x_last = layers.apply_norm(cfg, params["ln_f"], x_last)
    return unembed(cfg, params, x_last)[:, 0], new_cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, lengths, *, page_table=None,
                write_mask=None):
    """One decode step.  tokens (B, 1); lengths scalar or (B,) — context
    length including this token.  Returns (logits (B, V), new cache).
    Paged layout: ``page_table`` routes the K/V scatter; ``write_mask``
    (B,) bool sends masked rows' writes to the null block."""
    b = tokens.shape[0]
    x = embed_tokens(cfg, params, tokens)
    lengths = jnp.asarray(lengths)
    pos = (lengths - 1).reshape(-1, 1) * jnp.ones((b, 1), jnp.int32)
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, b, 1))

    def body(carry, inp):
        bp, layer_cache = inp
        h, new_cache = attention.attn_decode(
            cfg, bp["attn"], layers.apply_norm(cfg, bp["ln_attn"], carry),
            pos, layer_cache, lengths, page_table=page_table,
            write_mask=write_mask)
        x2 = carry + h
        inner = layers.apply_norm(cfg, bp["ln_mlp"], x2)
        if cfg.is_moe:
            f, _ = moe.moe_apply(cfg, bp["moe"], inner)
        else:
            f = layers.mlp_apply(cfg, bp["mlp"], inner)
        return x2 + f, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = layers.apply_norm(cfg, params["ln_f"], x)
    return unembed(cfg, params, x)[:, 0], new_cache
