"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, frames, d_model) — the transformer backbone
is what we build.  Simplifications vs. the released Whisper (documented in
DESIGN.md): sinusoidal positions on both sides (Whisper learns decoder
positions), pre-LN blocks.

Decode maintains per-layer self-attention KV caches plus *static* cross-
attention K/V computed once from the encoder output — the cross-KV is
exactly the paper's "pre-processable weight-like operand" (it is fixed for
the whole generation), so in quantized serving mode it could use the W4A16
path; we keep it bf16 (it is activation data, matching EdgeLLM's rule that
dynamically generated operands stay FP16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers
from repro.models.config import ModelConfig
from repro.models.layers import Params
from repro.models.transformer import stack_blocks, unembed


def _sinusoid(positions: jax.Array, d: int, dtype) -> jax.Array:
    """positions (..., s) -> (..., s, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def enc_block_init(key, cfg) -> Params:
    ka, kf = jax.random.split(key)
    return {
        "ln_attn": layers.norm_init(cfg),
        "attn": attention.attn_init(ka, cfg),
        "ln_mlp": layers.norm_init(cfg),
        "mlp": layers.mlp_init(kf, cfg),
    }


def dec_block_init(key, cfg) -> Params:
    ka, kc, kf = jax.random.split(key, 3)
    return {
        "ln_self": layers.norm_init(cfg),
        "self_attn": attention.attn_init(ka, cfg),
        "ln_cross": layers.norm_init(cfg),
        "cross_attn": attention.cross_attn_init(kc, cfg),
        "ln_mlp": layers.norm_init(cfg),
        "mlp": layers.mlp_init(kf, cfg),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kenc, kdec = jax.random.split(key, 3)
    return {
        "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "enc_blocks": stack_blocks(kenc, cfg, cfg.n_encoder_layers, enc_block_init),
        "enc_ln_f": layers.norm_init(cfg),
        "dec_blocks": stack_blocks(kdec, cfg, cfg.n_layers, dec_block_init),
        "ln_f": layers.norm_init(cfg),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames (B, F, d) stub embeddings -> encoder states (B, F, d)."""
    b, f, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(f)[None], (b, f))
    x = frames.astype(cfg.dtype) + _sinusoid(pos, cfg.d_model, cfg.dtype)
    dummy_pos = pos  # rope_type is "none"; positions unused

    def body(carry, bp):
        h = attention.attn_apply(
            cfg, bp["attn"], layers.apply_norm(cfg, bp["ln_attn"], carry),
            dummy_pos, causal=False)
        x2 = carry + h
        return x2 + layers.mlp_apply(
            cfg, bp["mlp"], layers.apply_norm(cfg, bp["ln_mlp"], x2)), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.apply_norm(cfg, params["enc_ln_f"], x)


def _dec_embed(cfg, params, tokens, offset=0):
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None] + offset, (b, s))
    return params["embed"][tokens] + _sinusoid(pos, cfg.d_model, cfg.dtype), pos


def forward(cfg: ModelConfig, params: Params, frames: jax.Array,
            tokens: jax.Array):
    """Teacher-forced training pass -> (logits (B,S,V), aux=0)."""
    enc = encode(cfg, params, frames)
    x, pos = _dec_embed(cfg, params, tokens)

    def body(carry, bp):
        h = attention.attn_apply(
            cfg, bp["self_attn"], layers.apply_norm(cfg, bp["ln_self"], carry),
            pos, causal=True)
        x2 = carry + h
        kv = attention.cross_kv(cfg, bp["cross_attn"], enc)
        h2 = attention.cross_attn_apply(
            cfg, bp["cross_attn"], layers.apply_norm(cfg, bp["ln_cross"], x2), kv)
        x3 = x2 + h2
        return x3 + layers.mlp_apply(
            cfg, bp["mlp"], layers.apply_norm(cfg, bp["ln_mlp"], x3)), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = layers.apply_norm(cfg, params["ln_f"], x)
    # Whisper ties output head to the token embedding
    return layers.linear(x, params["embed"].T), jnp.float32(0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    one = attention.init_kv_cache(cfg, batch, max_len)
    self_kv = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cross = {
        "k": jnp.zeros((cfg.n_layers, batch, hkv, cfg.encoder_frames, hd), cfg.dtype),
        "v": jnp.zeros((cfg.n_layers, batch, hkv, cfg.encoder_frames, hd), cfg.dtype),
    }
    return {"self": self_kv, "cross": cross}


def cache_slot_axes(cfg: ModelConfig) -> Params:
    """Request-slot axis per cache leaf.

    Both the self-attention KV and the per-request *cross* K/V (computed
    once from that request's encoder output) live at axis 1 of their
    (n_layers, B, ...) stacks; inserting a prefill row replaces both, so a
    reused slot never attends to a previous request's audio.
    """
    return {
        "self": attention.kv_cache_slot_axes(cfg, axis=1),
        "cross": {"k": 1, "v": 1},
    }


def request_cache(cfg: ModelConfig, params: Params, frames: jax.Array,
                  max_len: int):
    """Admission cache for chunked serving: pristine self-KV plus this
    request's cross-attention K/V (computed ONCE from its encoder output —
    the paper's "pre-processable weight-like operand").  The decoder prompt
    then streams through ``decode_step``/``mixed_step`` chunks against it.
    """
    enc = encode(cfg, params, frames)
    cache = init_cache(cfg, frames.shape[0], max_len)

    def body(carry, bp):
        k, v = attention.cross_kv(cfg, bp["cross_attn"], enc)
        return carry, {"k": k, "v": v}

    _, cross = jax.lax.scan(body, None, params["dec_blocks"])
    return {"self": cache["self"], "cross": cross}


def prefill(cfg: ModelConfig, params: Params, frames: jax.Array,
            tokens: jax.Array, max_len: int):
    """Encode audio, run the decoder prompt, build all caches."""
    enc = encode(cfg, params, frames)
    b, s = tokens.shape
    x, pos = _dec_embed(cfg, params, tokens)
    cache = init_cache(cfg, b, max_len)

    def body(carry, inp):
        bp, self_c = inp
        h, new_self = attention.attn_prefill(
            cfg, bp["self_attn"], layers.apply_norm(cfg, bp["ln_self"], carry),
            pos, self_c)
        x2 = carry + h
        kv = attention.cross_kv(cfg, bp["cross_attn"], enc)
        h2 = attention.cross_attn_apply(
            cfg, bp["cross_attn"], layers.apply_norm(cfg, bp["ln_cross"], x2), kv)
        x3 = x2 + h2
        out = x3 + layers.mlp_apply(
            cfg, bp["mlp"], layers.apply_norm(cfg, bp["ln_mlp"], x3))
        return out, (new_self, {"k": kv[0], "v": kv[1]})

    x, (self_new, cross_new) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"]))
    x = layers.apply_norm(cfg, params["ln_f"], x[:, -1:])
    logits = layers.linear(x, params["embed"].T)[:, 0]
    return logits, {"self": self_new, "cross": cross_new}


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, lengths, *, page_table=None,
                write_mask=None):
    b = tokens.shape[0]
    lengths = jnp.asarray(lengths)
    pos_scalar = (lengths - 1).reshape(-1, 1) * jnp.ones((b, 1), jnp.int32)
    x = params["embed"][tokens] + _sinusoid(pos_scalar, cfg.d_model, cfg.dtype)

    def body(carry, inp):
        bp, self_c, cross_c = inp
        h, new_self = attention.attn_decode(
            cfg, bp["self_attn"], layers.apply_norm(cfg, bp["ln_self"], carry),
            pos_scalar, self_c, lengths, page_table=page_table,
            write_mask=write_mask)
        x2 = carry + h
        h2 = attention.cross_attn_apply(
            cfg, bp["cross_attn"], layers.apply_norm(cfg, bp["ln_cross"], x2),
            (cross_c["k"], cross_c["v"]))
        x3 = x2 + h2
        out = x3 + layers.mlp_apply(
            cfg, bp["mlp"], layers.apply_norm(cfg, bp["ln_mlp"], x3))
        return out, new_self

    x, self_new = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"], cache["cross"]))
    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.linear(x, params["embed"].T)[:, 0]
    return logits, {"self": self_new, "cross": cache["cross"]}
