"""xLSTM blocks: mLSTM (matrix memory, parallelizable) + sLSTM (scalar
memory, strictly recurrent).  [arXiv:2405.04517]

xlstm-1.3b stacks 48 residual blocks; following the paper's 7:1 recipe one
block in every ``slstm_every`` is sLSTM, the rest mLSTM.

* mLSTM training uses the stabilized quadratic parallel form (an
  attention-like (L×L) score matrix gated by cumulative log-forget-gates);
  decode is the O(1) recurrent update of the (dh×dh) matrix memory C, the
  normalizer n and the stabilizer m.
* sLSTM is not parallelizable across time (hidden-state feedback inside the
  exponential gates) — training runs a ``lax.scan`` over the sequence, which
  is the honest form (the xLSTM paper says the same).

All recurrent/state math is f32; projections are model-dtype and
quantizable (W4A16) — EdgeLLM's FFN-side technique applies to every static
matmul here even though the MHA-side (FP16×FP16 KV) unit has no work in this
family (DESIGN.md §4 arch-applicability).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, linear, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg) -> Params:
    d = cfg.d_model
    di = 2 * d                       # projection factor 2
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "norm": jnp.ones((d,), cfg.dtype),
        "up_x": dense_init(ks[0], d, di, cfg.dtype),
        "up_z": dense_init(ks[1], d, di, cfg.dtype),
        "wq": dense_init(ks[2], di, di, cfg.dtype),
        "wk": dense_init(ks[3], di, di, cfg.dtype),
        "wv": dense_init(ks[4], di, di, cfg.dtype),
        "w_i": dense_init(ks[5], di, h, cfg.dtype, scale=0.01),
        "w_f": dense_init(ks[6], di, h, cfg.dtype, scale=0.01),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # open forget gates at init
        "out_norm": jnp.ones((di,), cfg.dtype),
        "down": dense_init(jax.random.fold_in(key, 9), di, d, cfg.dtype),
    }


def _mlstm_parallel(q, k, v, i_gate, f_gate):
    """Stabilized parallel mLSTM.  q/k/v (b,h,L,dh) f32; gates (b,h,L) f32."""
    b, h, L, dh = q.shape
    logf = jax.nn.log_sigmoid(f_gate)                        # (b,h,L)
    fcum = jnp.cumsum(logf, axis=-1)                         # sum_{1..t}
    # D[i,j] = sum_{k=j+1..i} logf_k + i_j  (j <= i)
    D = fcum[..., :, None] - fcum[..., None, :] + i_gate[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(mask, D, -jnp.inf)
    m = jnp.max(D, axis=-1, keepdims=True)                   # (b,h,L,1)
    m = jnp.maximum(m, -1e30)                                # rows with all -inf
    S = jnp.einsum("bhid,bhjd->bhij", q, k) / math.sqrt(dh)
    W = S * jnp.exp(D - m)
    norm = jnp.maximum(jnp.abs(W.sum(-1, keepdims=True)), jnp.exp(-m))
    return jnp.einsum("bhij,bhjd->bhid", W / norm, v)


MLSTM_CHUNK = 256


def _mlstm_chunked(q, k, v, i_gate, f_gate, chunk: int = MLSTM_CHUNK):
    """Chunkwise-parallel stabilized mLSTM — same math as the recurrence /
    quadratic parallel form (tested equal), O(L·C) memory instead of O(L²).

    Per chunk with incoming state (Ĉ, n̂, m0) and local cumulative
    log-forget b_t:

        m_t   = max(b_t + m0, max_{j≤t}(b_t − b_j + i_j))
        h_t   = [e^{b_t+m0−m_t}(q_t·Ĉ) + Σ_j S_tj e^{D_tj−m_t} v_j] / den_t
        den_t = max(|e^{b_t+m0−m_t}(q_t·n̂) + Σ_j (q_t·k_j/√d) e^{D_tj−m_t}|,
                    e^{−m_t})
        D_tj  = b_t − b_j + i_j  (j ≤ t)

    and the outgoing state takes t = C.  This is the xLSTM chunkwise form —
    the memory fix for the train_4k cell (EXPERIMENTS.md §Perf xlstm)."""
    b, h, L, dh = q.shape
    c = min(chunk, L)
    pad = (-L) % c
    if pad:
        z3 = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
        z2 = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad)))
        q, k, v = z3(q), z3(k), z3(v)
        i_gate = z2(i_gate) - 1e30 * (jnp.arange(L + pad) >= L)  # dead inputs
        f_gate = z2(f_gate)
    nc = (L + pad) // c

    def to_chunks(t, feat):
        if feat:
            return jnp.moveaxis(t.reshape(b, h, nc, c, dh), 2, 0)
        return jnp.moveaxis(t.reshape(b, h, nc, c), 2, 0)

    qs, ks, vs = to_chunks(q, True), to_chunks(k, True), to_chunks(v, True)
    igs, fgs = to_chunks(i_gate, False), to_chunks(f_gate, False)

    tri = jnp.tril(jnp.ones((c, c), bool))

    def body(carry, inp):
        C0, n0, m0 = carry                                  # (b,h,dh,dh) ...
        qc, kc, vc, ic, fc = inp
        logf = jax.nn.log_sigmoid(fc)                        # (b,h,c)
        bcum = jnp.cumsum(logf, axis=-1)
        D = bcum[..., :, None] - bcum[..., None, :] + ic[..., None, :]
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)                        # (b,h,c)
        m_t = jnp.maximum(bcum + m0[..., None], m_intra)
        m_t = jnp.maximum(m_t, -1e30)

        S = jnp.einsum("bhid,bhjd->bhij", qc, kc) / math.sqrt(dh)
        W = S * jnp.exp(D - m_t[..., None])
        carry_scale = jnp.exp(bcum + m0[..., None] - m_t)    # (b,h,c)
        num = (carry_scale[..., None] * jnp.einsum("bhid,bhde->bhie", qc, C0)
               + jnp.einsum("bhij,bhjd->bhid", W, vc))
        den = (carry_scale * jnp.einsum("bhid,bhd->bhi", qc, n0)
               + W.sum(-1))
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h_out = num / den[..., None]

        # outgoing state at t = c
        b_end = bcum[..., -1:]
        m_new = m_t[..., -1]
        decay_c = jnp.exp(b_end + m0[..., None] - m_new[..., None])  # (b,h,1)
        w_j = jnp.exp(b_end - bcum + ic - m_new[..., None])  # (b,h,c)
        k_s = kc / math.sqrt(dh)
        C_new = (C0 * decay_c[..., None] +
                 jnp.einsum("bhj,bhjd,bhje->bhde", w_j, k_s, vc))
        n_new = n0 * decay_c + jnp.einsum("bhj,bhjd->bhd", w_j, k_s)
        return (C_new, n_new, m_new), h_out

    init = (jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))
    _, hs = jax.lax.scan(body, init, (qs, ks, vs, igs, fgs))
    out = jnp.moveaxis(hs, 0, 2).reshape(b, h, L + pad, dh)
    return out[:, :, :L]


def mlstm_apply(cfg, p: Params, x: jax.Array) -> jax.Array:
    b, L, d = x.shape
    h = cfg.n_heads
    xi = rmsnorm(x, p["norm"])
    xp = linear(xi, p["up_x"], use_kernels=cfg.use_kernels)
    z = linear(xi, p["up_z"], use_kernels=cfg.use_kernels)
    di = xp.shape[-1]
    dh = di // h

    def heads(t):
        return t.reshape(b, L, h, dh).transpose(0, 2, 1, 3).astype(jnp.float32)

    q = heads(linear(xp, p["wq"], use_kernels=cfg.use_kernels))
    k = heads(linear(xp, p["wk"], use_kernels=cfg.use_kernels))
    v = heads(linear(xp, p["wv"], use_kernels=cfg.use_kernels))
    ig = (linear(xp, p["w_i"]).astype(jnp.float32) + p["b_i"]).transpose(0, 2, 1)
    fg = (linear(xp, p["w_f"]).astype(jnp.float32) + p["b_f"]).transpose(0, 2, 1)

    if L > MLSTM_CHUNK:
        y = _mlstm_chunked(q, k, v, ig, fg)                  # O(L·C) memory
    else:
        y = _mlstm_parallel(q, k, v, ig, fg)                 # (b,h,L,dh)
    y = y.transpose(0, 2, 1, 3).reshape(b, L, di).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"]) * jax.nn.silu(z)
    return x + linear(y, p["down"], use_kernels=cfg.use_kernels)


def mlstm_cache_init(cfg, batch: int) -> Params:
    h = cfg.n_heads
    dh = 2 * cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(cfg, p: Params, x: jax.Array, cache: Params):
    """One token.  x (b, 1, d)."""
    b, _, d = x.shape
    h = cfg.n_heads
    xi = rmsnorm(x, p["norm"])
    xp = linear(xi, p["up_x"], use_kernels=cfg.use_kernels)
    z = linear(xi, p["up_z"], use_kernels=cfg.use_kernels)
    di = xp.shape[-1]
    dh = di // h

    def heads(t):
        return t.reshape(b, h, dh).astype(jnp.float32)

    q = heads(linear(xp, p["wq"], use_kernels=cfg.use_kernels))
    k = heads(linear(xp, p["wk"], use_kernels=cfg.use_kernels))
    v = heads(linear(xp, p["wv"], use_kernels=cfg.use_kernels))
    ig = linear(xp, p["w_i"]).astype(jnp.float32).reshape(b, h) + p["b_i"]
    fg = linear(xp, p["w_f"]).astype(jnp.float32).reshape(b, h) + p["b_f"]

    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + cache["m"], ig)
    i_act = jnp.exp(ig - m_new)
    f_act = jnp.exp(logf + cache["m"] - m_new)
    k_s = k / math.sqrt(dh)
    C = cache["C"] * f_act[..., None, None] + i_act[..., None, None] * (
        k_s[..., :, None] * v[..., None, :])
    n = cache["n"] * f_act[..., None] + i_act[..., None] * k_s
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"]) * jax.nn.silu(z)
    out = x + linear(y, p["down"], use_kernels=cfg.use_kernels)
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg) -> Params:
    """Per the xLSTM paper, the sLSTM recurrence is BLOCK-DIAGONAL over
    heads: R is (h, dh, 4·dh), not (d, 4·d).  Besides being the faithful
    form, it streams 4x fewer recurrent-weight bytes per timestep — the
    dominant cost of the strictly-sequential scan (EXPERIMENTS.md §Perf
    xlstm it.13)."""
    d = cfg.d_model
    h = max(cfg.n_heads, 1)
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        "norm": jnp.ones((d,), cfg.dtype),
        "w_gates": dense_init(ks[0], d, 4 * d, cfg.dtype),   # z, i, f, o
        "r_gates": (jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32)
                    * 0.01).astype(cfg.dtype),
        "b_gates": jnp.zeros((h, 4 * dh), jnp.float32),
        "out_norm": jnp.ones((d,), cfg.dtype),
        "down": dense_init(ks[2], d, d, cfg.dtype),
    }


def _slstm_step(p, state, gates_x):
    """state (c, n, h, m) each (b, heads, dh) f32; gates_x (b, heads, 4dh)."""
    c, n, hid, m = state
    recur = jnp.einsum("bhd,hde->bhe", hid,
                       p["r_gates"].astype(jnp.float32))
    gates = gates_x + recur + p["b_gates"][None]
    dh = c.shape[-1]
    z_t = jnp.tanh(gates[..., :dh])
    i_t = gates[..., dh:2 * dh]
    f_t = gates[..., 2 * dh:3 * dh]
    o_t = jax.nn.sigmoid(gates[..., 3 * dh:])
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    i_act = jnp.exp(i_t - m_new)
    f_act = jnp.exp(logf + m - m_new)
    c_new = f_act * c + i_act * z_t
    n_new = jnp.maximum(f_act * n + i_act, jnp.exp(-m_new))
    h_new = o_t * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def _slstm_heads(cfg) -> tuple[int, int]:
    h = max(cfg.n_heads, 1)
    return h, cfg.d_model // h


def slstm_apply(cfg, p: Params, x: jax.Array) -> jax.Array:
    b, L, d = x.shape
    h, dh = _slstm_heads(cfg)
    xi = rmsnorm(x, p["norm"])
    gates_x = linear(xi, p["w_gates"], use_kernels=cfg.use_kernels)
    gates_x = gates_x.astype(jnp.float32).reshape(b, L, h, 4 * dh)

    if cfg.use_kernels and not isinstance(
            p["r_gates"], tuple) and hasattr(p["r_gates"], "shape"):
        # Pallas path: recurrent weights resident in VMEM for the whole
        # time loop (kernels/slstm_scan.py) — the 10^4x HBM-traffic fix
        from repro.kernels.slstm_scan import slstm_scan_pallas
        hs_blhd = slstm_scan_pallas(
            gates_x, p["r_gates"].astype(jnp.float32),
            p["b_gates"].astype(jnp.float32))
        y = hs_blhd.reshape(b, L, d).astype(x.dtype)
        y = rmsnorm(y, p["out_norm"])
        return x + linear(y, p["down"], use_kernels=cfg.use_kernels)

    def body(state, gx):
        new = _slstm_step(p, state, gx)
        return new, new[2]

    init = tuple(jnp.zeros((b, h, dh), jnp.float32) for _ in range(3)) + (
        jnp.full((b, h, dh), -1e30, jnp.float32),)
    _, hs = jax.lax.scan(body, init, jnp.moveaxis(gates_x, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, L, d).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"])
    return x + linear(y, p["down"], use_kernels=cfg.use_kernels)


def slstm_cache_init(cfg, batch: int) -> Params:
    h, dh = _slstm_heads(cfg)
    return {
        "c": jnp.zeros((batch, h, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "h": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h, dh), -1e30, jnp.float32),
    }


def slstm_decode(cfg, p: Params, x: jax.Array, cache: Params):
    b = x.shape[0]
    h, dh = _slstm_heads(cfg)
    xi = rmsnorm(x, p["norm"])
    gates_x = linear(xi, p["w_gates"], use_kernels=cfg.use_kernels)
    gx = gates_x.astype(jnp.float32)[:, 0].reshape(b, h, 4 * dh)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, hid, m = _slstm_step(p, state, gx)
    y = hid.reshape(b, 1, -1).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"])
    out = x + linear(y, p["down"], use_kernels=cfg.use_kernels)
    return out, {"c": c, "n": n, "h": hid, "m": m}
