"""xLSTM model assembly (family "ssm"): mLSTM/sLSTM residual stack + LM head.

xlstm-1.3b: 48 blocks; one sLSTM every ``slstm_every`` (paper's 7:1 recipe),
the rest mLSTM.  Segments of (slstm_every-1) mLSTM blocks are scanned, each
followed by one sLSTM block; scanning keeps the HLO compact for the
dry-run.  No attention, no KV cache — the recurrent state is O(1) in context
length, which is why this arch *runs* the long_500k cell (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, xlstm
from repro.models.config import ModelConfig
from repro.models.layers import Params
from repro.models.transformer import stack_blocks


def _segmentation(cfg: ModelConfig) -> tuple[int, int, int]:
    if cfg.slstm_every <= 0:
        return 0, 0, cfg.n_layers
    n_seg = cfg.n_layers // cfg.slstm_every
    m_per_seg = cfg.slstm_every - 1
    tail = cfg.n_layers - n_seg * cfg.slstm_every
    return n_seg, m_per_seg, tail


def init_params(cfg: ModelConfig, key) -> Params:
    ke, km, ks, kt, kh = jax.random.split(key, 5)
    n_seg, m_per_seg, tail = _segmentation(cfg)
    p: Params = {
        "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "ln_f": layers.norm_init(cfg),
        "lm_head": layers.dense_init(kh, cfg.d_model, cfg.vocab_size, cfg.dtype),
    }
    if n_seg:
        main = stack_blocks(km, cfg, n_seg * m_per_seg,
                            lambda k, c: xlstm.mlstm_init(k, c))
        p["mlstm_main"] = jax.tree.map(
            lambda a: a.reshape(n_seg, m_per_seg, *a.shape[1:]), main)
        p["slstm"] = stack_blocks(ks, cfg, n_seg,
                                  lambda k, c: xlstm.slstm_init(k, c))
    if tail:
        p["mlstm_tail"] = stack_blocks(kt, cfg, tail,
                                       lambda k, c: xlstm.mlstm_init(k, c))
    return p


def _mlstm_scan(cfg, stacked: Params, x: jax.Array) -> jax.Array:
    def body(carry, bp):
        return xlstm.mlstm_apply(cfg, bp, carry), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            positions=None, vision_embeds=None):
    x = params["embed"][tokens]
    n_seg, m_per_seg, tail = _segmentation(cfg)
    if n_seg:
        def seg_body(carry, inp):
            m_seg, s_blk = inp
            y = _mlstm_scan(cfg, m_seg, carry)
            return xlstm.slstm_apply(cfg, s_blk, y), None

        x, _ = jax.lax.scan(seg_body, x, (params["mlstm_main"], params["slstm"]))
    if tail:
        x = _mlstm_scan(cfg, params["mlstm_tail"], x)
    x = layers.apply_norm(cfg, params["ln_f"], x)
    return layers.linear(x, params["lm_head"],
                         use_kernels=cfg.use_kernels), jnp.float32(0)


# ---------------------------------------------------------------------------
# serving — recurrent state instead of KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    n_seg, m_per_seg, tail = _segmentation(cfg)
    mc = xlstm.mlstm_cache_init(cfg, batch)
    sc = xlstm.slstm_cache_init(cfg, batch)
    cache: Params = {}
    if n_seg:
        cache["mlstm_main"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None, None], (n_seg, m_per_seg) + a.shape), mc)
        cache["slstm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_seg,) + a.shape), sc)
    if tail:
        cache["mlstm_tail"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (tail,) + a.shape), mc)
    return cache


def cache_slot_axes(cfg: ModelConfig) -> Params:
    """Request-slot axis per recurrent-state leaf.

    Slot-based serving reuses rows of one resident state batch; inserting a
    freshly-initialized row through these axes is the per-row state reset
    (``m`` must return to -1e30, not 0 — plain zeroing would corrupt the
    log-sum-exp stabilizer of the next request in that slot).
    """
    n_seg, m_per_seg, tail = _segmentation(cfg)
    mc_axes = lambda ax: {"C": ax, "n": ax, "m": ax}
    axes: Params = {}
    if n_seg:
        axes["mlstm_main"] = mc_axes(2)       # (n_seg, m_per_seg, B, ...)
        axes["slstm"] = {"c": 1, "n": 1, "h": 1, "m": 1}   # (n_seg, B, ...)
    if tail:
        axes["mlstm_tail"] = mc_axes(1)       # (tail, B, ...)
    return axes


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, lengths):
    x = params["embed"][tokens]
    n_seg, m_per_seg, tail = _segmentation(cfg)
    new_cache: Params = {}
    if n_seg:
        def seg_body(carry, inp):
            m_seg, s_blk, m_c, s_c = inp

            def mbody(c2, inp2):
                bp, bc = inp2
                y, nc = xlstm.mlstm_decode(cfg, bp, c2, bc)
                return y, nc

            y, new_mc = jax.lax.scan(mbody, carry, (m_seg, m_c))
            y, new_sc = xlstm.slstm_decode(cfg, s_blk, y, s_c)
            return y, (new_mc, new_sc)

        x, (nm, ns) = jax.lax.scan(
            seg_body, x,
            (params["mlstm_main"], params["slstm"],
             cache["mlstm_main"], cache["slstm"]))
        new_cache["mlstm_main"], new_cache["slstm"] = nm, ns
    if tail:
        def mbody(c2, inp2):
            bp, bc = inp2
            return xlstm.mlstm_decode(cfg, bp, c2, bc)

        x, nt = jax.lax.scan(mbody, x, (params["mlstm_tail"], cache["mlstm_tail"]))
        new_cache["mlstm_tail"] = nt
    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.linear(x, params["lm_head"], use_kernels=cfg.use_kernels)[:, 0]
    return logits, new_cache
