"""Zamba2-style hybrid backbone: Mamba2 layers + shared attention blocks.

[arXiv:2411.15242]  81 Mamba2 layers; after every ``shared_attn_every`` (6)
of them one of ``n_shared_blocks`` (2) *weight-shared* transformer blocks
(attention + SwiGLU MLP) runs, alternating.  The shared blocks' weights are
stored once — each invocation site only owns its KV cache.

Execution shape: the 81-layer stack is split into ``n_seg`` segments of 6
(scanned) + a tail; segments run under an outer ``lax.scan`` whose per-step
shared-block parameters are index-selected (i mod 2) from the stacked shared
weights.  This keeps the HLO compact for the 512-device dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers, ssm
from repro.models.config import ModelConfig
from repro.models.layers import Params
from repro.models.transformer import stack_blocks, unembed


def shared_block_init(key, cfg) -> Params:
    ka, kf = jax.random.split(key)
    return {
        "ln_attn": layers.norm_init(cfg),
        "attn": attention.attn_init(ka, cfg),
        "ln_mlp": layers.norm_init(cfg),
        "mlp": layers.mlp_init(kf, cfg),
    }


def mamba_block_init(key, cfg) -> Params:
    return {"ln": layers.norm_init(cfg), "mamba": ssm.mamba_init(key, cfg)}


def _segmentation(cfg: ModelConfig) -> tuple[int, int, int]:
    seg = cfg.shared_attn_every
    n_seg = cfg.n_layers // seg
    tail = cfg.n_layers - n_seg * seg
    return seg, n_seg, tail


def init_params(cfg: ModelConfig, key) -> Params:
    ke, km, ks, kh = jax.random.split(key, 4)
    seg, n_seg, tail = _segmentation(cfg)
    main = stack_blocks(km, cfg, n_seg * seg, mamba_block_init)
    main = jax.tree.map(
        lambda a: a.reshape(n_seg, seg, *a.shape[1:]), main)
    p: Params = {
        "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "mamba_main": main,
        "shared": stack_blocks(ks, cfg, cfg.n_shared_blocks, shared_block_init),
        "ln_f": layers.norm_init(cfg),
        "lm_head": layers.dense_init(kh, cfg.d_model, cfg.vocab_size, cfg.dtype),
    }
    if tail:
        p["mamba_tail"] = stack_blocks(
            jax.random.fold_in(km, 1), cfg, tail, mamba_block_init)
    return p


def _mamba_scan(cfg, stacked: Params, x: jax.Array) -> jax.Array:
    def body(carry, bp):
        h = ssm.mamba_apply(cfg, bp["mamba"],
                            layers.apply_norm(cfg, bp["ln"], carry))
        return carry + h, None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _shared_apply(cfg, sp: Params, x: jax.Array, positions) -> jax.Array:
    h = attention.attn_apply(
        cfg, sp["attn"], layers.apply_norm(cfg, sp["ln_attn"], x), positions)
    x = x + h
    return x + layers.mlp_apply(
        cfg, sp["mlp"], layers.apply_norm(cfg, sp["ln_mlp"], x))


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            positions=None, vision_embeds=None):
    b, s = tokens.shape
    x = params["embed"][tokens]
    if positions is None:
        positions = layers.positions_for(cfg, b, s)
    seg, n_seg, tail = _segmentation(cfg)
    seg_ids = jnp.arange(n_seg) % cfg.n_shared_blocks

    def seg_body(carry, inp):
        mamba_seg, sid = inp
        y = _mamba_scan(cfg, mamba_seg, carry)
        sp = jax.tree.map(lambda a: a[sid], params["shared"])
        return _shared_apply(cfg, sp, y, positions), None

    x, _ = jax.lax.scan(seg_body, x, (params["mamba_main"], seg_ids))
    if tail:
        x = _mamba_scan(cfg, params["mamba_tail"], x)
    x = layers.apply_norm(cfg, params["ln_f"], x)
    return layers.linear(x, params["lm_head"],
                         use_kernels=cfg.use_kernels), jnp.float32(0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    seg, n_seg, tail = _segmentation(cfg)
    mcache = ssm.mamba_cache_init(cfg, batch)
    kv = attention.init_kv_cache(cfg, batch, max_len)
    return {
        "mamba_main": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None, None], (n_seg, seg) + a.shape), mcache),
        "mamba_tail": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (tail,) + a.shape), mcache)
        if tail else None,
        "kv": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_seg,) + a.shape), kv),
    }


def cache_slot_axes(cfg: ModelConfig) -> Params:
    """Request-slot axis per cache leaf (hybrid = Mamba state + shared KV).

    Slot reuse must reset the Mamba conv window and SSM state per row —
    inserting a fresh ``init_cache(cfg, 1, ...)`` row along these axes does
    exactly that; the KV rows are overwritten by the next prefill insert.
    """
    seg, n_seg, tail = _segmentation(cfg)
    m_axes = lambda ax: {"conv": ax, "state": ax}
    return {
        "mamba_main": m_axes(2),                        # (n_seg, seg, B, ...)
        "mamba_tail": m_axes(1) if tail else None,      # (tail, B, ...)
        "kv": attention.kv_cache_slot_axes(cfg, axis=1),  # (n_seg, B, ...)
    }


def _mamba_decode_scan(cfg, stacked: Params, x: jax.Array, caches: Params):
    def body(carry, inp):
        bp, c = inp
        h, new_c = ssm.mamba_decode(
            cfg, bp["mamba"], layers.apply_norm(cfg, bp["ln"], carry), c)
        return carry + h, new_c

    return jax.lax.scan(body, x, (stacked, caches))


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, lengths, *, page_table=None,
                write_mask=None):
    b = tokens.shape[0]
    lengths = jnp.asarray(lengths)
    x = params["embed"][tokens]
    pos = (lengths - 1).reshape(-1, 1) * jnp.ones((b, 1), jnp.int32)
    seg, n_seg, tail = _segmentation(cfg)
    seg_ids = jnp.arange(n_seg) % cfg.n_shared_blocks

    def seg_body(carry, inp):
        mamba_seg, mamba_c, kv_c, sid = inp
        y, new_mc = _mamba_decode_scan(cfg, mamba_seg, carry, mamba_c)
        sp = jax.tree.map(lambda a: a[sid], params["shared"])
        h, new_kv = attention.attn_decode(
            cfg, sp["attn"], layers.apply_norm(cfg, sp["ln_attn"], y),
            pos, kv_c, lengths, page_table=page_table, write_mask=write_mask)
        y = y + h
        y = y + layers.mlp_apply(
            cfg, sp["mlp"], layers.apply_norm(cfg, sp["ln_mlp"], y))
        return y, (new_mc, new_kv)

    x, (new_main, new_kv) = jax.lax.scan(
        seg_body, x,
        (params["mamba_main"], cache["mamba_main"], cache["kv"], seg_ids))
    new_tail = cache.get("mamba_tail")
    if tail:
        x, new_tail = _mamba_decode_scan(
            cfg, params["mamba_tail"], x, cache["mamba_tail"])
    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.linear(x, params["lm_head"], use_kernels=cfg.use_kernels)[:, 0]
    return logits, {"mamba_main": new_main, "mamba_tail": new_tail, "kv": new_kv}
