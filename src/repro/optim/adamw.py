"""AdamW with ZeRO-sharded state (pure functional, optax-free).

Optimizer state lives in f32 and inherits the parameter sharding (FSDP
partition over the ``data`` axis + TP over ``model``), which is ZeRO-3
semantics under pjit: states are *stored* sharded and never gathered.
Training params are f32 masters; the train step casts to the compute dtype
(bf16) before the forward pass.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float | None = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, metrics)."""
        step = state.step + 1
        gnorm = global_norm(grads)
        if self.grad_clip is not None:
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v), {
            "grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac) * 0.5 *
                      (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr
