"""JAX version-compatibility shims for the parallel layer.

``shard_map`` graduated from ``jax.experimental`` to the ``jax`` namespace
in newer releases; the call sites here use keyword arguments
(``mesh=/in_specs=/out_specs=``) that both versions accept.  The
replication-check flag also renamed (``check_rep`` -> ``check_vma``), so
the wrapper translates whichever spelling the installed jax understands —
call sites always pass ``check_rep``.
"""

import inspect

import jax

try:
    _shard_map = jax.shard_map  # jax >= 0.5
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    if "check_rep" in kwargs and "check_rep" not in _PARAMS:
        val = kwargs.pop("check_rep")
        if "check_vma" in _PARAMS:
            kwargs["check_vma"] = val
    return _shard_map(f, **kwargs)
