"""JAX version-compatibility shims for the parallel layer.

``shard_map`` graduated from ``jax.experimental`` to the ``jax`` namespace
in newer releases; the call sites here use keyword arguments
(``mesh=/in_specs=/out_specs=``) that both versions accept.
"""

import jax

try:
    shard_map = jax.shard_map  # jax >= 0.5
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401
