"""shard_map flash-decoding: one-token attention over a sequence-sharded KV
cache (EXPERIMENTS.md §Perf qwen3-decode iterations).

Under plain pjit, the decode step's cache update + attention trigger
"involuntary full rematerialization" resharding copies between the
seq-sharded cache and the head-sharded attention compute — measured ~200×
the int4-floor memory traffic on qwen3-8b decode_32k.  This module makes
the intended dataflow explicit:

* the cache NEVER moves: each model shard holds a contiguous sequence slice;
* the new token's K/V is written by whichever shard owns slot
  ``(length-1) mod cache_len`` (a ``lax.cond`` guarded local update);
* each shard computes partial attention over its slice with a local max /
  sum, then the shards merge with the flash-decoding log-sum-exp rule
  (one pmax + two psums of (b, h, d)-sized partials — KBs, not GBs);
* q is replicated across the sequence axes (it is one token).

Numerically identical to ``ref.decode_attention_ref`` (tested).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map


def seq_axes_for(mesh: Mesh, batch: int) -> tuple[str, ...]:
    """Mirror kv_cache_specs: seq shards over 'model', plus the data axes
    when the batch can't occupy them (batch == 1 / indivisible)."""
    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in da])) if da else 1
    if batch > 1 and batch % dsize == 0:
        return ("model",)
    return da + ("model",)


def decode_attention_sharded(
    q: jax.Array,            # (b, hq, 1, hd)
    k_new: jax.Array,        # (b, hkv, 1, hd)
    v_new: jax.Array,
    k_cache: jax.Array,      # (b, hkv, S, hd) — seq sharded
    v_cache: jax.Array,
    lengths: jax.Array,      # scalar: context length incl. new token
    mesh: Mesh,
    *,
    rolling: bool,
    scale: float | None = None,
    scales: tuple | None = None,   # (k_scale, v_scale) for int8-quantized KV
):
    """Returns (out (b, hq, 1, hd), new_cache dict)."""
    b, hq, _, hd = q.shape
    hkv, S = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hkv
    scale_v = scale if scale is not None else float(1.0 / (hd ** 0.5))
    sa = seq_axes_for(mesh, b)
    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_ax = da if (b > 1 and sa == ("model",)) else None
    quant = scales is not None

    def local(q_l, kn, vn, ck, cv, ksc, vsc, length):
        s_loc = ck.shape[2]
        shard = sum(jax.lax.axis_index(a) * int(np.prod(
            [mesh.shape[x] for x in sa[i + 1:]]))
            for i, a in enumerate(sa))
        off = shard * s_loc
        write_idx = ((length - 1) % S) if rolling else (length - 1)
        local_idx = write_idx - off
        in_range = (local_idx >= 0) & (local_idx < s_loc)

        def upd(c, new):
            safe = jnp.clip(local_idx, 0, s_loc - 1)
            updated = jax.lax.dynamic_update_slice(
                c, new.astype(c.dtype), (0, 0, safe, 0))
            return jax.lax.cond(in_range, lambda: updated, lambda: c)

        if quant:
            from repro.models.attention import quantize_kv
            knq, kns = quantize_kv(kn)
            vnq, vns = quantize_kv(vn)
            ck2, cv2 = upd(ck, knq), upd(cv, vnq)
            ksc2, vsc2 = upd(ksc, kns), upd(vsc, vns)
        else:
            ck2, cv2 = upd(ck, kn), upd(cv, vn)
            ksc2 = vsc2 = None

        # partial attention over the local slice, via the SAME blocked
        # walker as the single-host path (kernels/xla_attention).  Its
        # traffic rules (measured on qwen3 decode, §Perf): (1) the cache
        # stays in its storage dtype — an explicit .astype(f32) materializes
        # a full f32 cache copy per layer; (2) GQA via grouped einsum, NOT
        # jnp.repeat — repeating K/V to 32 heads materializes rep x the
        # cache bytes; (3) per-shard block skipping — each shard clamps the
        # walk to ITS live positions (`length - off`), so a shard whose
        # slice sits past the valid context streams zero KV blocks instead
        # of its whole slice (the length-clamp trick from decode_flash.py,
        # restated for shard_map).  int8 KV: scale-after-dot (the paper's
        # Stage-3 trick applied to the dynamic operand):
        # logits_s = (q·k_q_s)·kscale_s.
        from repro.kernels.xla_attention import decode_blocked_partials
        bl = q_l.shape[0]                                    # local batch
        q5 = q_l.reshape(bl, hkv, rep, 1, hd)
        valid_len = jnp.minimum(length, S) if rolling else length
        local_live = jnp.clip(valid_len - off, 0, s_loc)
        m_loc, l_loc, acc = decode_blocked_partials(
            q5, ck2, cv2, jnp.broadcast_to(local_live, (bl,)),
            scale=scale_v,
            k_scale=ksc2[..., 0] if quant else None,
            v_scale=vsc2[..., 0] if quant else None)

        # flash-decoding merge across sequence shards
        m_g = jax.lax.pmax(m_loc, sa)
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, sa)
        acc_g = jax.lax.psum(acc * corr[..., None], sa)
        out = (acc_g / jnp.maximum(l_g, 1e-30)[..., None])
        out = out.reshape(bl, hq, 1, hd).astype(q_l.dtype)
        if quant:
            return out, ck2, cv2, ksc2, vsc2
        return out, ck2, cv2

    cache_spec = P(batch_ax, None, sa if len(sa) > 1 else sa[0], None)
    rep_spec = P(batch_ax, None, None, None)
    # check_rep=False: the blocked partials walk is a lax.while_loop (trip
    # count = this shard's live blocks), which shard_map's replication
    # checker cannot type yet; the explicit pmax/psum merge below is what
    # establishes replication of the output
    if quant:
        ksc, vsc = scales
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(rep_spec, rep_spec, rep_spec, cache_spec, cache_spec,
                      cache_spec, cache_spec, P()),
            out_specs=(rep_spec, cache_spec, cache_spec, cache_spec,
                       cache_spec),
            check_rep=False,
        )
        out, k2, v2, ks2, vs2 = fn(q, k_new, v_new, k_cache, v_cache,
                                   ksc, vsc, lengths)
        return out, {"k": k2, "v": v2, "k_scale": ks2, "v_scale": vs2}

    def local_noq(q_l, kn, vn, ck, cv, length):
        return local(q_l, kn, vn, ck, cv, None, None, length)

    fn = shard_map(
        local_noq, mesh=mesh,
        in_specs=(rep_spec, rep_spec, rep_spec, cache_spec, cache_spec, P()),
        out_specs=(rep_spec, cache_spec, cache_spec),
        check_rep=False,
    )
    out, k2, v2 = fn(q, k_new, v_new, k_cache, v_cache, lengths)
    return out, {"k": k2, "v": v2}


def usable(mesh: Mesh | None, batch: int, hq: int, hkv: int, S: int,
           lengths, *, paged: bool = False) -> bool:
    """Whether the sequence-sharded decode path applies.

    ``paged`` caches stay on the single-program path: the blocked walker
    this module shares (``decode_blocked_partials``) already takes a
    ``page_table``, but sequence-sharding a SHARED block pool needs a
    block-home assignment (which shard owns which physical block) that the
    engine's host allocator doesn't emit yet — see ROADMAP open items.
    """
    if paged:
        return False
    if mesh is None or "model" not in mesh.axis_names:
        return False
    if jnp.asarray(lengths).ndim != 0:
        return False
    sa = seq_axes_for(mesh, batch)
    n = int(np.prod([mesh.shape[a] for a in sa]))
    return S % n == 0 and S >= n
