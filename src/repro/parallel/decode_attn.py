"""shard_map flash-decoding: one-token attention over a sequence-sharded KV
cache (EXPERIMENTS.md §Perf qwen3-decode iterations).

Under plain pjit, the decode step's cache update + attention trigger
"involuntary full rematerialization" resharding copies between the
seq-sharded cache and the head-sharded attention compute — measured ~200×
the int4-floor memory traffic on qwen3-8b decode_32k.  This module makes
the intended dataflow explicit:

* the cache NEVER moves: each model shard holds a contiguous sequence slice
  (slot layout) or a contiguous run of pool rows — its block HOMES (paged
  layout);
* the new token's K/V is written by whichever shard owns its slot / home
  block (a masked local scatter — rows homed elsewhere keep their values);
* each shard computes partial attention over its slice with a local max /
  sum, then the shards merge with the flash-decoding log-sum-exp rule
  (one pmax + two psums of (b, h, d)-sized partials — KBs, not GBs);
* q is replicated across the sequence axes (it is one token).

``lengths`` may be a scalar or per-row ``(B,)`` — the serving engine always
passes the vector, so both the write scatter and the live-length clamp are
per-row.  Numerically identical to ``ref.decode_attention_ref`` (tested);
batched token streams match the single-device walk bitwise at the argmax.

Paged layout (``decode_attention_sharded_paged``): the shared pool's rows
are partitioned into ``n_shards`` contiguous "block homes"; the engine's
allocator leases each row's blocks round-robin across homes, page-table
entries stay GLOBAL block ids, and each shard's walker translates them to
home-local rows (non-home blocks masked to exact zeros — see
``decode_blocked_partials``).  Every logical block is counted by exactly
one shard, so the same pmax/psum merge combines the partials.  Resident
batch then scales with total mesh memory instead of one device's.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map


def seq_axes_for(mesh: Mesh, batch: int) -> tuple[str, ...]:
    """Mirror kv_cache_specs: seq shards over 'model', plus the data axes
    when the batch can't occupy them (batch == 1 / indivisible)."""
    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in da])) if da else 1
    if batch > 1 and batch % dsize == 0:
        return ("model",)
    return da + ("model",)


def _shard_index(mesh: Mesh, sa: tuple[str, ...]) -> jax.Array:
    """Linear index of this program among the ``sa`` shards (row-major)."""
    return sum(jax.lax.axis_index(a) * int(np.prod(
        [mesh.shape[x] for x in sa[i + 1:]]))
        for i, a in enumerate(sa))


def decode_attention_sharded(
    q: jax.Array,            # (b, hq, 1, hd)
    k_new: jax.Array,        # (b, hkv, 1, hd)
    v_new: jax.Array,
    k_cache: jax.Array,      # (b, hkv, S, hd) — seq sharded
    v_cache: jax.Array,
    lengths: jax.Array,      # scalar or (b,): context length incl. new token
    mesh: Mesh,
    *,
    rolling: bool,
    scale: float | None = None,
    scales: tuple | None = None,   # (k_scale, v_scale) for int8-quantized KV
):
    """Returns (out (b, hq, 1, hd), new_cache dict)."""
    b, hq, _, hd = q.shape
    hkv, S = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hkv
    scale_v = scale if scale is not None else float(1.0 / (hd ** 0.5))
    sa = seq_axes_for(mesh, b)
    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_ax = da if (b > 1 and sa == ("model",)) else None
    quant = scales is not None
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))

    def local(q_l, kn, vn, ck, cv, ksc, vsc, length):
        s_loc = ck.shape[2]
        bl = q_l.shape[0]                                    # local batch
        off = _shard_index(mesh, sa) * s_loc
        write_idx = ((length - 1) % S) if rolling else (length - 1)  # (bl,)
        local_idx = write_idx - off
        in_range = (local_idx >= 0) & (local_idx < s_loc)
        rows = jnp.arange(bl)
        safe = jnp.clip(local_idx, 0, s_loc - 1)

        def upd(c, new):
            # per-row scatter: a row whose write slot lives on another
            # shard keeps its current value (each slot written exactly once
            # across the mesh)
            cur = c[rows, :, safe]
            vals = jnp.where(in_range[:, None, None],
                             new[:, :, 0].astype(c.dtype), cur)
            return c.at[rows, :, safe].set(vals)

        if quant:
            from repro.models.attention import quantize_kv
            knq, kns = quantize_kv(kn)
            vnq, vns = quantize_kv(vn)
            ck2, cv2 = upd(ck, knq), upd(cv, vnq)
            ksc2, vsc2 = upd(ksc, kns), upd(vsc, vns)
        else:
            ck2, cv2 = upd(ck, kn), upd(cv, vn)
            ksc2 = vsc2 = None

        # partial attention over the local slice, via the SAME blocked
        # walker as the single-host path (kernels/xla_attention).  Its
        # traffic rules (measured on qwen3 decode, §Perf): (1) the cache
        # stays in its storage dtype — an explicit .astype(f32) materializes
        # a full f32 cache copy per layer; (2) GQA via grouped einsum, NOT
        # jnp.repeat — repeating K/V to 32 heads materializes rep x the
        # cache bytes; (3) per-shard block skipping — each shard clamps the
        # walk to ITS live positions (`length - off`), so a shard whose
        # slice sits past the valid context streams zero KV blocks instead
        # of its whole slice (the length-clamp trick from decode_flash.py,
        # restated for shard_map).  int8 KV: scale-after-dot (the paper's
        # Stage-3 trick applied to the dynamic operand):
        # logits_s = (q·k_q_s)·kscale_s.
        from repro.kernels.xla_attention import decode_blocked_partials
        q5 = q_l.reshape(bl, hkv, rep, 1, hd)
        valid_len = jnp.minimum(length, S) if rolling else length
        local_live = jnp.clip(valid_len - off, 0, s_loc)     # (bl,)
        m_loc, l_loc, acc = decode_blocked_partials(
            q5, ck2, cv2, local_live,
            scale=scale_v,
            k_scale=ksc2[..., 0] if quant else None,
            v_scale=vsc2[..., 0] if quant else None)

        # flash-decoding merge across sequence shards
        m_g = jax.lax.pmax(m_loc, sa)
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, sa)
        acc_g = jax.lax.psum(acc * corr[..., None], sa)
        out = (acc_g / jnp.maximum(l_g, 1e-30)[..., None])
        out = out.reshape(bl, hq, 1, hd).astype(q_l.dtype)
        if quant:
            return out, ck2, cv2, ksc2, vsc2
        return out, ck2, cv2

    cache_spec = P(batch_ax, None, sa if len(sa) > 1 else sa[0], None)
    rep_spec = P(batch_ax, None, None, None)
    len_spec = P(batch_ax)          # per-row lengths ride with the batch
    # check_rep=False: the blocked partials walk is a lax.while_loop (trip
    # count = this shard's live blocks), which shard_map's replication
    # checker cannot type yet; the explicit pmax/psum merge below is what
    # establishes replication of the output
    if quant:
        ksc, vsc = scales
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(rep_spec, rep_spec, rep_spec, cache_spec, cache_spec,
                      cache_spec, cache_spec, len_spec),
            out_specs=(rep_spec, cache_spec, cache_spec, cache_spec,
                       cache_spec),
            check_rep=False,
        )
        out, k2, v2, ks2, vs2 = fn(q, k_new, v_new, k_cache, v_cache,
                                   ksc, vsc, lengths)
        return out, {"k": k2, "v": v2, "k_scale": ks2, "v_scale": vs2}

    def local_noq(q_l, kn, vn, ck, cv, length):
        return local(q_l, kn, vn, ck, cv, None, None, length)

    fn = shard_map(
        local_noq, mesh=mesh,
        in_specs=(rep_spec, rep_spec, rep_spec, cache_spec, cache_spec,
                  len_spec),
        out_specs=(rep_spec, cache_spec, cache_spec),
        check_rep=False,
    )
    out, k2, v2 = fn(q, k_new, v_new, k_cache, v_cache, lengths)
    return out, {"k": k2, "v": v2}


def decode_attention_sharded_paged(
    q: jax.Array,            # (b, hq, 1, hd)
    k_new: jax.Array,        # (b, hkv, 1, hd)
    v_new: jax.Array,
    k_pool: jax.Array,       # (N, hkv, bs, hd) — pool rows home-sharded
    v_pool: jax.Array,
    lengths: jax.Array,      # (b,) context length incl. new token
    page_table: jax.Array,   # (b, n_pages) GLOBAL physical block ids
    write_mask: jax.Array | None,   # (b,) bool; False rows never land
    mesh: Mesh,
    *,
    scale: float | None = None,
    scales: tuple | None = None,
):
    """Sequence-sharded PAGED decode: one engine across a device mesh.

    The pool's ``N`` rows (null block included, last) are partitioned into
    ``n_shards`` contiguous block homes of ``N // n_shards`` rows; shard
    ``s`` holds rows ``[s*R, (s+1)*R)``.  The engine's allocator leases a
    row's blocks round-robin across homes, so each shard's walker — the
    shared ``decode_blocked_partials`` with ``block_home`` — visits only
    the blocks it is home to (non-home blocks mask to exact zeros) and the
    flash-decoding pmax/psum merge combines the partials.  The new token's
    K/V is written by the shard homing its block (masked rows and
    other-home rows drop).  No rolling-SWA variant: the dispatch gates this
    path on ``cfg.window is None``.

    Returns (out (b, hq, 1, hd), new_cache dict).
    """
    b, hq, _, hd = q.shape
    hkv, bs = k_pool.shape[1], k_pool.shape[2]
    rep = hq // hkv
    scale_v = scale if scale is not None else float(1.0 / (hd ** 0.5))
    sa = seq_axes_for(mesh, b)
    n_shards = 1
    for a in sa:
        n_shards *= mesh.shape[a]
    quant = scales is not None
    n_pos = page_table.shape[1] * bs
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    mask = (jnp.ones((b,), bool) if write_mask is None
            else jnp.asarray(write_mask, bool))

    def local(q_l, kn, vn, kp, vp, ksp, vsp, length, table, wmask):
        r_loc = kp.shape[0]                   # home rows on this shard
        base = _shard_index(mesh, sa) * r_loc

        # -- write the new token: the row's physical block translates to a
        # home-local row; rows homed on other shards drop, masked rows route
        # to the GLOBAL null row (last pool row) so the null-homing shard
        # absorbs them exactly like the single-device write path — pools
        # stay bitwise identical across the two dispatches
        pos = jnp.clip(length - 1, 0, n_pos - 1)
        blk = jnp.take_along_axis(table, (pos // bs)[:, None], axis=1)[:, 0]
        blk = jnp.where(wmask, blk, r_loc * n_shards - 1)
        loc = blk - base
        ok = (loc >= 0) & (loc < r_loc)
        blk_eff = jnp.where(ok, loc, r_loc)   # r_loc is out of bounds

        def upd(pool_l, new):
            return pool_l.at[blk_eff, :, pos % bs].set(
                new.astype(pool_l.dtype), mode="drop")

        if quant:
            from repro.models.attention import quantize_kv
            knq, kns = quantize_kv(kn)
            vnq, vns = quantize_kv(vn)
            kp2, vp2 = upd(kp, knq[:, :, 0]), upd(vp, vnq[:, :, 0])
            ksp2, vsp2 = upd(ksp, kns[:, :, 0]), upd(vsp, vns[:, :, 0])
        else:
            kp2, vp2 = upd(kp, kn[:, :, 0]), upd(vp, vn[:, :, 0])
            ksp2 = vsp2 = None

        # -- partial attention over home blocks only, then the LSE merge
        from repro.kernels.xla_attention import decode_blocked_partials
        q5 = q_l.reshape(b, hkv, rep, 1, hd)
        m_loc, l_loc, acc = decode_blocked_partials(
            q5, kp2, vp2, jnp.clip(length, 0, n_pos),
            scale=scale_v,
            k_scale=ksp2[..., 0] if quant else None,
            v_scale=vsp2[..., 0] if quant else None,
            page_table=table, block_home=base)
        m_g = jax.lax.pmax(m_loc, sa)
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, sa)
        acc_g = jax.lax.psum(acc * corr[..., None], sa)
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        out = out.reshape(b, hq, 1, hd).astype(q_l.dtype)
        if quant:
            return out, kp2, vp2, ksp2, vsp2
        return out, kp2, vp2

    pool_spec = P(sa if len(sa) > 1 else sa[0], None, None, None)
    rep4 = P(None, None, None, None)
    # batch stays replicated: the pool has no batch axis, and replicated
    # writes by the full batch keep every data-replica identical
    if quant:
        ksc, vsc = scales
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(rep4, rep4, rep4, pool_spec, pool_spec, pool_spec,
                      pool_spec, P(None), P(None, None), P(None)),
            out_specs=(rep4, pool_spec, pool_spec, pool_spec, pool_spec),
            check_rep=False,
        )
        out, k2, v2, ks2, vs2 = fn(q, k_new, v_new, k_pool, v_pool,
                                   ksc, vsc, lengths, page_table, mask)
        return out, {"k": k2, "v": v2, "k_scale": ks2, "v_scale": vs2}

    def local_noq(q_l, kn, vn, kp, vp, length, table, wmask):
        return local(q_l, kn, vn, kp, vp, None, None, length, table, wmask)

    fn = shard_map(
        local_noq, mesh=mesh,
        in_specs=(rep4, rep4, rep4, pool_spec, pool_spec, P(None),
                  P(None, None), P(None)),
        out_specs=(rep4, pool_spec, pool_spec),
        check_rep=False,
    )
    out, k2, v2 = fn(q, k_new, v_new, k_pool, v_pool, lengths,
                     page_table, mask)
    return out, {"k": k2, "v": v2}


def paged_homes(mesh: Mesh | None, batch: int, pool_rows: int, *,
                window: int | None = None) -> int:
    """Number of block homes the sharded paged path partitions the pool
    into (1 = unsharded).  The engine's allocator MUST agree with the
    dispatch gate, so both derive from this one function: homes > 1 exactly
    when ``usable(..., paged=True)`` will route decode through
    ``decode_attention_sharded_paged``.  ``pool_rows`` counts the null row.
    """
    if window is not None or mesh is None or "model" not in mesh.axis_names:
        return 1
    sa = seq_axes_for(mesh, batch)
    n = int(np.prod([mesh.shape[a] for a in sa]))
    if pool_rows % n == 0 and pool_rows >= n:
        return n
    return 1


def usable(mesh: Mesh | None, batch: int, hq: int, hkv: int, S: int,
           lengths, *, paged: bool = False) -> bool:
    """Whether the sequence-sharded decode path applies.

    ``S`` is the cache's sharded extent: sequence slots for the slot
    layout, pool ROWS (null block included) for ``paged=True``.  Either
    way the requirement is the same — the extent divides evenly across the
    sequence shards (contiguous slice per shard for slots, equal block
    homes for pages).  ``lengths`` may be a scalar or a per-row ``(B,)``
    vector — the serving engine always passes the vector.
    """
    if mesh is None or "model" not in mesh.axis_names:
        return False
    sa = seq_axes_for(mesh, batch)
    n = int(np.prod([mesh.shape[a] for a in sa]))
    return S % n == 0 and S >= n
