"""Activation sharding hints.

Model code calls ``hint(x, "batch", "heads", None, None)`` at layout-critical
points (attention operands, logits, SSD tensors).  When a mesh is active
(set by the launch layer via :func:`use_mesh`), logical names resolve to mesh
axes and a ``with_sharding_constraint`` is emitted; otherwise the call is a
no-op, so single-device tests never see mesh machinery.

Why this exists: XLA's sharding propagation gives up at a few model points —
notably the GQA ``jnp.repeat`` of K/V heads, after which the whole attention
computation silently replicates across the ``model`` axis (measured: 16×
excess attention FLOPs on mixtral train before these hints — EXPERIMENTS.md
§Perf iteration 1).

Logical names:
    batch  -> ("pod", "data")      heads  -> "model"
    ffn    -> "model"              seq_mp -> "model" (decode KV seq)
    none / None -> unsharded
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

_ACTIVE_MESH: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar(
    "repro_active_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    token = _ACTIVE_MESH.set(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _ACTIVE_MESH.reset(token)


def active_mesh() -> Mesh | None:
    return _ACTIVE_MESH.get()


def _resolve(name: str | None, mesh: Mesh):
    if name is None or name == "none":
        return None
    if name == "batch":
        return tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    if name in ("heads", "ffn", "seq_mp"):
        return "model" if "model" in mesh.axis_names else None
    if name in mesh.axis_names:
        return name
    return None


def hint(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain x's sharding if a mesh is active; no-op otherwise."""
    mesh = active_mesh()
    if mesh is None or not hasattr(x, "shape") or len(logical) != x.ndim:
        return x
    spec = []
    for dim, name in zip(x.shape, logical):
        axes = _resolve(name, mesh)
        if axes is None:
            spec.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = int(np.prod([mesh.shape[a] for a in ax_tuple]))
        spec.append(axes if dim % size == 0 and dim >= size else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
