"""Sharding rules: parameter/activation/cache PartitionSpecs per mode.

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod.  The ``pod`` axis always composes with ``data`` (batch /
FSDP dimension) — gradients reduce hierarchically (reduce-scatter in-pod,
all-reduce across pods, both emitted by XLA from the same spec).

Two rule sets:

* TRAIN — Megatron TP over ``model`` (column-parallel in-projections,
  row-parallel out-projections) × FSDP/ZeRO over ``data`` (every matrix's
  other dimension).  Optimizer state inherits these specs = ZeRO-3.
* SERVE — TP over ``model`` only; weights replicated across ``data`` (each
  data shard decodes its own batch rows; no FSDP gathers on the decode
  critical path).  KV caches shard batch over ``data`` and sequence over
  ``model`` (decode attention partial-softmax reductions become ``model``
  collectives — flash-decoding, SPMD-style).  When batch == 1 (long_500k)
  the cache sequence axis shards over BOTH axes.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# parameter-name classification --------------------------------------------

_COL_PARALLEL = {
    "wq", "wk", "wv", "gate", "up", "up_x", "up_z", "w_gates", "in_proj",
    "lm_head", "w_i", "w_f",
}
_ROW_PARALLEL = {"wo", "down", "out_proj", "r_gates"}
_EMBED = {"embed"}
_REPLICATED = {
    "gamma", "beta", "norm", "out_norm", "ln", "A_log", "D", "dt_bias",
    "b_gates", "b_i", "b_f", "conv_b", "bq", "bk", "bv", "up_bias",
    "down_bias", "router", "scales", "block_idx",
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def _path_names(path) -> list[str]:
    out = []
    for entry in path:
        if isinstance(entry, jax.tree_util.DictKey):
            out.append(str(entry.key))
        elif isinstance(entry, jax.tree_util.GetAttrKey):
            out.append(str(entry.name))
    return out


def data_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _spec_for(name: str, names: list[str], ndim: int, shape,
              mesh: Mesh, mode: str) -> P:
    """Spec over the TRAILING 2 dims; leading dims (layer stack, expert,
    segment) stay unsharded unless noted."""
    da = data_axes(mesh)
    fsdp = da if mode == "train" else None
    is_packed = name == "packed"                     # quantized weight bytes

    if name in _REPLICATED and not is_packed:
        return P()
    if "slstm" in names:
        # sLSTM is strictly sequential: sharding its (small) weights over
        # 'model' puts an all-reduce inside every timestep of the scan —
        # measured 7.6M collective ops on xlstm train (§Perf it.6).
        # Replicate the whole block; the recurrence stays device-local.
        return P()
    if "moe" in names and name in ("gate", "up", "down") and not is_packed:
        # expert weights: hidden axis sharded over (data…, model) jointly —
        # must match the shard_map in_specs in models/moe.py exactly, or
        # every scan step reshards the whole expert stack
        wstack = (da + ("model",)) if mode == "train" else ("model",)
        wstack = wstack if len(wstack) > 1 else wstack[0]
        if name == "down":
            return P(*([None] * (ndim - 2)), wstack, None)
        return P(*([None] * (ndim - 1)), wstack)
    if name in _EMBED:
        # vocab over model; replicate d (lookups gather rows)
        return P(*([None] * (ndim - 2)), "model", None)
    if name == "conv_w":
        return P(*([None] * (ndim - 1)), "model")
    if name in _COL_PARALLEL or (is_packed and _col_quant(names)):
        return P(*([None] * (ndim - 2)), fsdp, "model")
    if name in _ROW_PARALLEL or (is_packed and not _col_quant(names)):
        return P(*([None] * (ndim - 2)), "model", fsdp)
    # default: replicate
    return P()


def _col_quant(names: list[str]) -> bool:
    """Is a QuantizedTensor leaf (``.../<wname>/packed``) column-parallel?"""
    for n in reversed(names[:-1]):
        if n in _COL_PARALLEL:
            return True
        if n in _ROW_PARALLEL:
            return False
    return True


def _quant_scale_spec(names: list[str], ndim: int, mesh: Mesh, mode: str) -> P:
    # col-parallel: scales (..., groups, out) shard the out dim;
    # row-parallel: shard the groups dim (follows the contraction TP split)
    if _col_quant(names):
        return P(*([None] * (ndim - 1)), "model")
    return P(*([None] * (ndim - 2)), "model", None)


def param_specs(params_shape: Any, mesh: Mesh, mode: str = "train") -> Any:
    """Map a params shape-pytree to PartitionSpecs."""

    def f(path, leaf):
        names = _path_names(path)
        name = _leaf_name(path)
        ndim = len(leaf.shape)
        if ndim == 0:
            return P()
        if name == "scales":
            return _quant_scale_spec(names, ndim, mesh, mode)
        if name in ("block_idx",):
            return P()
        spec = _spec_for(name, names, ndim, leaf.shape, mesh, mode)
        return _legalize(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(f, params_shape)


def _legalize(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on axes that don't divide evenly; strip trailing Nones."""
    out = []
    for dim, s in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if s is None:
            out.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(s if dim % size == 0 else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shardings_for(tree_shape: Any, mesh: Mesh, mode: str = "train") -> Any:
    specs = param_specs(tree_shape, mesh, mode)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# -- activations / batches / caches -----------------------------------------

def batch_spec(mesh: Mesh) -> P:
    return P(data_axes(mesh))


def batch_shardings(batch_shape: Any, mesh: Mesh) -> Any:
    da = data_axes(mesh)

    def f(leaf):
        spec = _legalize(P(da), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(f, batch_shape)


def kv_cache_specs(cache_shape: Any, mesh: Mesh, batch: int) -> Any:
    """KV/state caches: batch over data, sequence over model (flash-decoding
    partials); batch==1 shards sequence over every axis."""
    da = data_axes(mesh)
    data_size = int(np.prod([mesh.shape[a] for a in da]))

    def f(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        # find the batch dim: first dim equal to `batch` after leading stack dims
        spec: list = [None] * len(shape)
        bdim = None
        for i, d in enumerate(shape):
            if d == batch:
                bdim = i
                break
        if batch > 1 and bdim is not None and batch % data_size == 0:
            spec[bdim] = da
        if name in ("k", "v", "k_scale", "v_scale") and len(shape) >= 2:
            # sequence dim is -2 in (..., B, hkv, max_len, hd|1)
            seq_dim = len(shape) - 2
            if bdim != seq_dim:
                if batch == 1 or bdim is None:
                    spec[seq_dim] = da + ("model",)
                else:
                    spec[seq_dim] = "model"
        elif name in ("state",):
            # mamba state (..., B, H, N, P): heads over model
            hdim = (bdim + 1) if bdim is not None else len(shape) - 3
            spec[hdim] = "model"
        elif name in ("conv",):
            spec[-1] = "model"
        elif name in ("C", "n"):
            hdim = (bdim + 1) if bdim is not None else 1
            spec[hdim] = "model"
        return NamedSharding(mesh, _legalize(P(*spec), shape, mesh))

    return jax.tree_util.tree_map_with_path(f, cache_shape)
