"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs          / (peak_FLOP/s per chip)
    memory     = HLO_bytes          / (HBM_bw per chip)
    collective = collective_bytes   / (link_bw per chip)

``cost_analysis()`` of the partitioned executable reports **per-device**
FLOPs/bytes, so no further division by chip count is needed.  Collective
bytes are not in cost_analysis — they are parsed from the optimized HLO
(every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op), with ring-model wire factors applied per op kind.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-given).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

# -- hardware constants (TPU v5e) -------------------------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, per direction)

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ring-model wire traffic per device, as a multiple of the op's payload
# bytes (N = ring size; for N=16: (N-1)/N ≈ 0.94, 2(N-1)/N ≈ 1.9)
_WIRE_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,          # payload = full output, each dev sends 1/N·out×(N-1)
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    """Sum byte sizes of every dtype[shape] occurrence in a type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict[str, Any]:
    """Parse optimized HLO; per collective kind: op count + payload bytes +
    ring-model wire bytes (per device)."""
    stats = {k: {"count": 0, "payload_bytes": 0, "wire_bytes": 0.0}
             for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        m = re.match(r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([a-z0-9-]+)",
                     rhs)
        if not m:
            continue
        type_str, op = m.groups()
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        payload = _type_bytes(type_str)
        stats[base]["count"] += 1
        stats[base]["payload_bytes"] += payload
        stats[base]["wire_bytes"] += payload * _WIRE_FACTOR[base]
    stats["total_payload_bytes"] = sum(
        v["payload_bytes"] for k, v in stats.items() if k in _COLLECTIVES)
    stats["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in stats.items() if k in _COLLECTIVES)
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    hbm_bytes: float             # per device
    collective_bytes: float      # per device (wire model)
    steps_per_call: int = 1      # grad-accum microbatches etc.

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "t_bound_s": self.t_bound,
        }


def model_flops(cfg, cell, n_devices: int) -> dict[str, float]:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for training;
    2·N·D for inference forward (per step: D = tokens processed)."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        total = 6.0 * n_active * tokens
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = cell.global_batch
        total = 2.0 * n_active * tokens
    return {
        "model_flops_total": total,
        "model_flops_per_dev": total / n_devices,
        "active_params": float(n_active),
        "params": float(cfg.param_count()),
    }
