"""Loop-aware HLO cost model.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count (verified on this backend), which silently zeroes out scan-over-layers
models.  This module re-derives FLOPs / HBM bytes / collective bytes from the
optimized HLO text with loop multipliers:

* while ops carry ``backend_config={"known_trip_count":{"n":"L"}}`` — the
  body's cost is multiplied by L (nested loops compose);
* ``fusion`` call sites contribute operand+output bytes (the fusion boundary
  is the HBM boundary) and the fused computation is recursed for FLOPs only;
* ``call``/``conditional`` recurse fully;
* collective ops (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute) accumulate payload + ring-model wire bytes by kind;
  ``-start``/``-done`` pairs are counted once;
* dot FLOPs = 2 · prod(out) · prod(contracting dims); elementwise /
  reduce / rng ops contribute ~1 FLOP per output element, reported
  separately (``ew_flops``) since they bind to the VPU, not the MXU.

The result is the per-device cost of one step of the *partitioned* program —
exactly the quantity the three-term roofline needs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SKIP_MEM = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "while", "call", "conditional", "custom-call",
}
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "logistic", "log", "log-plus-one", "rsqrt",
    "sqrt", "negate", "abs", "compare", "select", "and", "or", "xor", "not",
    "exponential-minus-one", "cosine", "sine", "floor", "ceil", "round",
    "clamp", "remainder", "sign", "atan2", "reduce", "reduce-window", "map",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z0-9\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*([0-9]+)')
_ATTR_COMP_RE = re.compile(r"(?:body|to_apply|calls)=(%?[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%?[\w.\-]+)")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return elems, bytes_


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


def _parse_computations(text: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    cur: list[Op] | None = None
    cur_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _HDR_RE.match(line.strip())
            if m:
                cur_name = m.group(2).lstrip("%")
                cur = []
            continue
        if line.strip() == "}":
            comps[cur_name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, type_str, opcode = m.groups()
            cur.append(Op(name.lstrip("%"), type_str, opcode, line))
        elif "(" in line and line.strip().startswith("%") and "= " not in line:
            # parameter declarations inside header already consumed; ignore
            pass
    return comps


@dataclasses.dataclass
class Cost:
    dot_flops: float = 0.0
    ew_flops: float = 0.0
    mem_bytes: float = 0.0
    coll: dict[str, dict[str, float]] = dataclasses.field(
        default_factory=lambda: {k: {"count": 0.0, "payload_bytes": 0.0,
                                     "wire_bytes": 0.0} for k in _COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.dot_flops += other.dot_flops * mult
        self.ew_flops += other.ew_flops * mult
        self.mem_bytes += other.mem_bytes * mult
        for k in _COLLECTIVES:
            for f in ("count", "payload_bytes", "wire_bytes"):
                self.coll[k][f] += other.coll[k][f] * mult

    def as_dict(self) -> dict[str, Any]:
        out = {
            "dot_flops": self.dot_flops,
            "ew_flops": self.ew_flops,
            "flops": self.dot_flops + self.ew_flops,
            "mem_bytes": self.mem_bytes,
            "collectives": self.coll,
            "collective_payload_bytes": sum(
                v["payload_bytes"] for v in self.coll.values()),
            "collective_wire_bytes": sum(
                v["wire_bytes"] for v in self.coll.values()),
        }
        return out


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = _parse_computations(text)
        # symbol tables: computation -> {op_name: type_str}
        self.symbols = {
            cname: {op.name: op.type_str for op in ops}
            for cname, ops in self.comps.items()
        }
        self.ops_by_name = {
            cname: {op.name: op for op in ops}
            for cname, ops in self.comps.items()
        }
        # parameters appear as ops with opcode 'parameter'
        self._memo: dict[str, Cost] = {}
        self.entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _HDR_RE.match(line.strip())
                if m:
                    self.entry = m.group(2).lstrip("%")
        if self.entry is None:
            # fall back: the last computation
            self.entry = list(self.comps)[-1] if self.comps else None

    # -- CPU-backend bf16 legalization correction ----------------------------

    def _operand_names(self, op: Op) -> list[str]:
        # locate "<opcode>(" AFTER the "=" (the op name may contain the
        # opcode as a substring, e.g. "%dot = f32[...] dot(...)")
        eq = op.line.find(" = ")
        m = re.search(re.escape(op.opcode) + r"\(([^)]*)\)", op.line[eq + 3:])
        if not m:
            return []
        return [o.strip().lstrip("%") for o in m.group(1).split(",") if o.strip()]

    def _derived_from_bf16(self, cname: str, name: str, depth: int = 5) -> bool:
        """Does this value's producer chain round-trip through bf16?

        The CPU backend's float-normalization pass upcasts bf16 dots to f32
        BEFORE collectives are placed, so the partitioned HLO shows f32
        all-reduces that would be bf16 on TPU (verified on a trivial
        row-parallel matmul).  We walk the producer chain through
        convert / dot / fusion-root / elementwise ops looking for a
        convert-from-bf16, and count such collectives at 2 bytes/element.
        """
        if depth <= 0:
            return False
        op = self.ops_by_name.get(cname, {}).get(name)
        if op is None:
            return False
        if "bf16[" in op.type_str:
            return True
        if op.opcode == "convert":
            src = self._operand_names(op)
            if src:
                t = self.symbols.get(cname, {}).get(src[0], "")
                if "bf16[" in t:
                    return True
                return self._derived_from_bf16(cname, src[0], depth - 1)
        if op.opcode == "fusion":
            sub = _ATTR_COMP_RE.search(op.line)
            if sub:
                sub_name = sub.group(1).lstrip("%")
                ops = self.comps.get(sub_name, [])
                for o in ops:
                    if "ROOT" in o.line:
                        return self._derived_from_bf16(sub_name, o.name, depth - 1)
        if op.opcode in ("dot", "add", "multiply", "subtract", "select",
                         "maximum", "get-tuple-element", "copy", "transpose",
                         "reshape", "bitcast", "dynamic-slice", "broadcast"):
            for src in self._operand_names(op):
                t = self.symbols.get(cname, {}).get(src, "")
                if "bf16[" in t:
                    return True
                if self._derived_from_bf16(cname, src, depth - 1):
                    return True
        return False

    def _fusion_root_opcode(self, sub_name: str | None) -> str | None:
        if not sub_name:
            return None
        root = None
        has_dus = has_ds = False
        for o in self.comps.get(sub_name, []):
            if o.opcode == "dynamic-update-slice":
                has_dus = True
            if o.opcode in ("dynamic-slice", "slice"):
                has_ds = True
            if "ROOT" in o.line:
                root = o.opcode
        wrappers = ("dynamic-update-slice", "dynamic-slice", "slice",
                    "convert", "copy", "bitcast", "reshape", "broadcast")
        # convert/copy-wrapped in-place updates count as the update itself
        if has_dus and root in wrappers:
            return "dynamic-update-slice"
        # slice-reading fusions touch the sliced region, not the whole
        # operand (a scan reading one layer's KV from the stacked cache)
        if has_ds and root in wrappers:
            return "dynamic-slice"
        return root

    def _coll_payload(self, op: Op, cname: str) -> float:
        """Collective payload bytes with effective-dtype correction."""
        elems, bytes_ = _shape_elems_bytes(op.type_str)
        if "f32[" in op.type_str:
            for src in self._operand_names(op):
                if self._derived_from_bf16(cname, src):
                    return bytes_ / 2.0
        return float(bytes_)

    # -- per-op costs --------------------------------------------------------

    def _dot_flops(self, op: Op, cname: str) -> float:
        out_elems, _ = _shape_elems_bytes(op.type_str)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        dims = [int(d) for d in m.group(1).split(",")] if m and m.group(1) else []
        # lhs operand type
        operands = self._operand_names(op)
        lhs_type = self.symbols.get(cname, {}).get(operands[0]) if operands else None
        contract = 1
        if lhs_type and dims:
            shapes = _SHAPE_RE.findall(lhs_type)
            if shapes:
                dim_list = ([int(d) for d in shapes[0][1].split(",")]
                            if shapes[0][1] else [])
                for d in dims:
                    if d < len(dim_list):
                        contract *= dim_list[d]
        return 2.0 * out_elems * max(contract, 1)

    def _operand_bytes(self, op: Op, cname: str) -> float:
        total = 0.0
        table = self.symbols.get(cname, {})
        for nm in self._operand_names(op):
            t = table.get(nm)
            if not t:
                continue
            b = _shape_elems_bytes(t)[1]
            if "f32[" in t and self._derived_from_bf16(cname, nm, depth=3):
                b /= 2.0  # CPU bf16->f32 legalization; bf16 on TPU
            total += b
        return total

    def _output_bytes(self, op: Op, cname: str) -> float:
        _, b = _shape_elems_bytes(op.type_str)
        if "f32[" in op.type_str and self._derived_from_bf16(
                cname, op.name, depth=3):
            return b / 2.0
        return float(b)

    # -- computation cost ----------------------------------------------------

    def comp_cost(self, cname: str, flops_only: bool = False) -> Cost:
        key = f"{cname}|{flops_only}"
        if key in self._memo:
            return self._memo[key]
        cost = Cost()
        for op in self.comps.get(cname, []):
            oc = op.opcode
            base = oc
            for suf in ("-start", "-done"):
                if base.endswith(suf):
                    base = base[: -len(suf)]
            if base in _COLLECTIVES:
                if oc.endswith("-done"):
                    continue
                payload = self._coll_payload(op, cname)
                cost.coll[base]["count"] += 1
                cost.coll[base]["payload_bytes"] += payload
                cost.coll[base]["wire_bytes"] += payload * _WIRE_FACTOR[base]
                continue
            if oc == "while":
                m = _TRIP_RE.search(op.line)
                trips = float(m.group(1)) if m else 1.0
                body = _ATTR_COMP_RE.search(op.line)
                if body:
                    cost.add(self.comp_cost(body.group(1).lstrip("%"),
                                            flops_only), trips)
                cond = _COND_RE.search(op.line)
                if cond:
                    cost.add(self.comp_cost(cond.group(1).lstrip("%"),
                                            flops_only), trips)
                continue
            if oc in ("call", "conditional"):
                for sub in _ATTR_COMP_RE.findall(op.line):
                    cost.add(self.comp_cost(sub.lstrip("%"), flops_only))
                continue
            if oc == "fusion":
                sub = _ATTR_COMP_RE.search(op.line)
                sub_name = sub.group(1).lstrip("%") if sub else None
                if sub_name:
                    cost.add(self.comp_cost(sub_name, flops_only=True))
                if not flops_only:
                    root_oc = self._fusion_root_opcode(sub_name)
                    if root_oc == "dynamic-update-slice":
                        # in-place slice write: traffic ~ 2x the update
                        # payload, NOT the whole buffer (a scan writing per-
                        # layer KV back into the stacked cache would
                        # otherwise count the full cache x trip count)
                        ops_b = [
                            _shape_elems_bytes(
                                self.symbols.get(cname, {}).get(nm, ""))[1]
                            for nm in self._operand_names(op)]
                        ops_b = [x for x in ops_b if x > 0]
                        upd = min(ops_b) if ops_b else 0
                        cost.mem_bytes += 2.0 * upd
                    elif root_oc == "dynamic-slice":
                        # slice-reading fusion: touched region ~ 2x output
                        cost.mem_bytes += 2.0 * self._output_bytes(op, cname)
                    else:
                        cost.mem_bytes += (self._output_bytes(op, cname)
                                           + self._operand_bytes(op, cname))
                continue
            if oc == "dot":
                cost.dot_flops += self._dot_flops(op, cname)
                if not flops_only:
                    cost.mem_bytes += (self._output_bytes(op, cname)
                                       + self._operand_bytes(op, cname))
                continue
            if oc in _EW_OPS:
                elems, out_b = _shape_elems_bytes(op.type_str)
                cost.ew_flops += elems
                if not flops_only:
                    # output bytes only: on TPU, XLA fuses elementwise chains
                    # into producers/consumers — counting operand re-reads at
                    # every unfused CPU-HLO op would overstate HBM traffic
                    cost.mem_bytes += out_b
                continue
            if oc in ("dynamic-slice", "slice", "gather", "take"):
                # reads only the sliced region, NOT the full operand — a
                # scan-over-layers slices the whole stacked weights every
                # iteration and counting operands would multiply total weight
                # bytes by the trip count (measured 200x inflation)
                if not flops_only:
                    cost.mem_bytes += 2.0 * self._output_bytes(op, cname)
                continue
            if oc in ("dynamic-update-slice", "scatter", "scatter-add"):
                # in-place update: traffic ~ 2x the update payload (read +
                # write of the touched region), not the whole buffer
                if not flops_only:
                    names_ops = self._operand_names(op)
                    upd_b = 0.0
                    if len(names_ops) >= 2:
                        t = self.symbols.get(cname, {}).get(names_ops[1], "")
                        upd_b = _shape_elems_bytes(t)[1]
                    cost.mem_bytes += (2.0 * upd_b if upd_b
                                       else 2.0 * self._output_bytes(op, cname))
                continue
            if oc in _SKIP_MEM:
                if oc == "custom-call" and not flops_only:
                    cost.mem_bytes += (self._output_bytes(op, cname)
                                       + self._operand_bytes(op, cname))
                continue
            if not flops_only:
                cost.mem_bytes += (self._output_bytes(op, cname)
                                   + self._operand_bytes(op, cname))
        self._memo[key] = cost
        return cost

    def module_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)


def analyze_hlo(text: str) -> dict[str, Any]:
    return HloAnalyzer(text).module_cost().as_dict()
