"""Roofline report: artifacts/dryrun/*.json -> markdown tables.

Usage:  PYTHONPATH=src python -m repro.roofline.report [--dir artifacts/dryrun]

Emits the §Dry-run and §Roofline tables EXPERIMENTS.md embeds: per
(arch × shape × mesh) the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs usefulness ratio, and the per-device memory footprint.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.roofline.analysis import PEAK_FLOPS

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "qwen1.5-4b", "gemma-2b", "starcoder2-7b", "qwen3-8b", "xlstm-1.3b",
    "granite-moe-3b-a800m", "mixtral-8x22b", "qwen2-vl-7b", "whisper-small",
    "zamba2-7b",
]


def load(dirname: str) -> list[dict]:
    out = []
    for f in glob.glob(os.path.join(dirname, "*.json")):
        if os.path.basename(f).startswith(("baseline", "hillclimb")):
            continue
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def _fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def _sortkey(r):
    a = ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99
    s = SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 99
    return (a, s)


def dryrun_table(records: list[dict], mesh: str) -> str:
    rows = ["| arch | shape | status | compile | peak GB/dev | args GB | "
            "temp GB | collective ops |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted([r for r in records if r["mesh"] == mesh], key=_sortkey):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP — {r['reason'][:42]} "
                        "| — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | **ERROR** | — | — | — | — | — |")
            continue
        m = r["memory_analysis"]
        ncoll = sum(int(v["count"]) for v in r["collectives"].values()
                    if isinstance(v, dict))
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f}s "
            f"| {m['peak_bytes_est']/1e9:.2f} | {m['argument_bytes']/1e9:.2f} "
            f"| {m['temp_bytes']/1e9:.2f} | {ncoll} |")
    return "\n".join(rows)


def roofline_table(records: list[dict], mesh: str = "pod16x16") -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | bound | "
            "MODEL/HLO flops | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted([r for r in records if r["mesh"] == mesh], key=_sortkey):
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        mf = r["model_flops"]
        useful = mf["model_flops_per_dev"] / max(rf["flops_per_dev"], 1)
        # roofline fraction: useful-FLOPs time at peak / bound time
        frac = (mf["model_flops_per_dev"] / PEAK_FLOPS) / max(rf["t_bound_s"], 1e-12)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_t(rf['t_compute_s'])} "
            f"| {_fmt_t(rf['t_memory_s'])} | {_fmt_t(rf['t_collective_s'])} "
            f"| {rf['bottleneck']} | {useful:.2f} | {frac*100:.1f}% |")
    return "\n".join(rows)


def summary(records: list[dict]) -> str:
    lines = []
    for mesh in ("pod16x16", "pod2x16x16"):
        rs = [r for r in records if r["mesh"] == mesh]
        ok = sum(r["status"] == "ok" for r in rs)
        skip = sum(r["status"] == "skipped" for r in rs)
        err = len(rs) - ok - skip
        lines.append(f"- mesh {mesh}: {ok} ok / {skip} skipped / {err} error "
                     f"(of {len(rs)} cells)")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    records = load(args.dir)
    print("## Summary\n")
    print(summary(records))
    print("\n## Dry-run, single pod (16x16)\n")
    print(dryrun_table(records, "pod16x16"))
    print("\n## Dry-run, multi-pod (2x16x16)\n")
    print(dryrun_table(records, "pod2x16x16"))
    print("\n## Roofline (single pod)\n")
    print(roofline_table(records, "pod16x16"))


if __name__ == "__main__":
    main()
