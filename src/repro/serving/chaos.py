"""Fault-injection harness for the serving engine (ISSUE 8).

The engine's resilience claims — preemption is lossless and bounded, a bad
row quarantines without touching its batch-mates, ``sum(reserve) <= free``
and the allocator's refcount partition survive anything — are only worth
stating if they hold under ADVERSARIAL schedules, not just the happy path.
``ChaosMonkey`` injects deterministic, rate-configurable faults at exactly
the host seams the engine defends:

* **reservation denials** (``deny_rate``) — ``_admit_head`` treats a denial
  as a shortfall-with-no-victim: the head stalls a tick.  Exercises the
  stall/retry path and the admission-order bookkeeping under flapping.
* **forced preemptions** (``preempt_rate``) — a random running (and still
  preemptable) slot is evicted-and-requeued at the front.  Exercises the
  donate/fold/re-admit cycle far more often than organic pool pressure
  would.
* **NaN logit rows** (``nan_rate``) — a random advancing row's logits are
  overwritten with NaN host-side, exactly as a device fault would surface.
  The engine must quarantine that row (``status="error"``) and NOT donate
  its blocks.
* **garbage drafts** (``garbage_draft_rate``) — a verify row's draft tokens
  are replaced with random vocab ids of the same length.  Greedy
  verification must reject them and stay bitwise lossless.

Every fault stream is driven by one seeded ``np.random.default_rng`` so a
soak run is REPRODUCIBLE: same seed, same faults, same final state.  The
injection counters (``stats()``) ride along in
``Engine.resilience_stats()``.

``run_soak`` is the acceptance harness: for every family mixture (slot vs
paged, int8-KV, speculation, prefix sharing) it runs a faulted engine with
``audit_every=1`` (allocator/reservation/page-table invariants checked
EVERY tick) and asserts each surviving request's token stream is bitwise
equal to the ``reference_decode`` oracle on its ORIGINAL prompt — faults
may kill a row, they may never corrupt a neighbour.  Runnable directly:

    PYTHONPATH=src python -m repro.serving.chaos --seed 0 --out stats.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache
from repro.models import api
from repro.models.config import ModelConfig
from repro.serving.engine import Engine, Request, reference_decode


@dataclasses.dataclass
class ChaosConfig:
    """Per-seam injection rates (probability per opportunity, in [0, 1])."""
    seed: int = 0
    deny_rate: float = 0.0           # P(reservation denied) per admit try
    preempt_rate: float = 0.0        # P(forced preemption) per tick
    nan_rate: float = 0.0            # P(row -> NaN) per advancing row
    garbage_draft_rate: float = 0.0  # P(draft garbled) per verify row
    kill_rate: float = 0.0           # P(process death) per tick top
    kill_after: int | None = None    # deterministic death at the Nth tick


class EngineKilled(RuntimeError):
    """Simulated process death, raised by ``ChaosMonkey.maybe_kill`` at the
    top of a tick.  Everything the engine had not journaled or snapshotted
    dies with the process; ``Engine.restore`` must recover the rest — the
    kill/restore soak asserts the recovered streams are bitwise the
    never-killed oracle's."""


class ChaosMonkey:
    """Deterministic fault injector the engine consults at its host seams.

    Construct from a ``ChaosConfig`` or keyword rates; attach via
    ``Engine(..., chaos=monkey)``.  All randomness flows from one seeded
    generator, so identical (seed, workload) pairs inject identical faults.
    """

    def __init__(self, config: ChaosConfig | None = None, **rates: Any):
        self.config = config if config is not None else ChaosConfig(**rates)
        self._rng = np.random.default_rng(self.config.seed)
        self.injected = {"denials": 0, "preemptions": 0,
                         "nan_rows": 0, "garbled_drafts": 0, "kills": 0}
        self._ticks_to_kill = self.config.kill_after

    # -- seams (called by Engine.run / Engine._admit_head) -----------------

    def maybe_kill(self) -> None:
        """Once per tick, at the TOP (after the previous tick's journal
        fsync): simulated process death.  Draws from the rng only when
        enabled, so a kill-free monkey's other fault streams are unchanged
        from pre-kill seeds."""
        if self._ticks_to_kill is not None:
            self._ticks_to_kill -= 1
            if self._ticks_to_kill <= 0:
                self._ticks_to_kill = None
                self.injected["kills"] += 1
                raise EngineKilled("chaos: process killed at tick "
                                   f"{self.config.kill_after} (scheduled)")
        if (self.config.kill_rate and
                self._rng.random() < self.config.kill_rate):
            self.injected["kills"] += 1
            raise EngineKilled("chaos: process killed at tick top")

    def deny_reservation(self) -> bool:
        """One admission attempt: True = pretend the pool cannot reserve."""
        if self._rng.random() < self.config.deny_rate:
            self.injected["denials"] += 1
            return True
        return False

    def forced_preempt(self, eligible: list[int]) -> int | None:
        """Once per tick: pick a running slot to evict, or None.  Only
        slots still under their preemption bound are offered."""
        if eligible and self._rng.random() < self.config.preempt_rate:
            self.injected["preemptions"] += 1
            return int(self._rng.choice(eligible))
        return None

    def corrupt_rows(self, advancing: list[int]) -> list[int]:
        """Once per tick: the subset of advancing rows whose logits turn
        NaN this dispatch (independent draw per row)."""
        hit = [i for i in advancing
               if self._rng.random() < self.config.nan_rate]
        self.injected["nan_rows"] += len(hit)
        return hit

    def garble_draft(self, draft: list[int], vocab: int) -> list[int]:
        """Maybe replace one verify row's draft with same-length junk
        (length is load-bearing: the engine sized its leases by it)."""
        if self._rng.random() < self.config.garbage_draft_rate:
            self.injected["garbled_drafts"] += 1
            return self._rng.integers(0, vocab, len(draft)).tolist()
        return draft

    def stats(self) -> dict[str, Any]:
        return {**dataclasses.asdict(self.config), **self.injected}


# -- soak harness ----------------------------------------------------------

# every engine mixture the resilience contract must survive: (label,
# kv_layout, kv_quant, spec_k, prefix_cache, mesh).  The mesh cell forces
# the engine under a device mesh so decode routes through the sequence-
# sharded paged path (block homes, per-home reservations) — on a 1-device
# host it degenerates to a 1-shard shard_map, which still exercises the
# sharded dispatch end to end; CI runs it with 8 forced host devices.
SOAK_CELLS = [
    ("slot",            "slot",  "none", 0, False, False),
    ("paged",           "paged", "none", 0, False, False),
    ("paged-int8",      "paged", "int8", 0, False, False),
    ("paged-spec",      "paged", "none", 3, False, False),
    ("paged-prefix",    "paged", "none", 0, True,  False),
    ("paged-all",       "paged", "int8", 3, True,  False),
    ("paged-mesh",      "paged", "none", 0, True,  True),
]


def _tiny_cfg(kv_layout: str, kv_quant: str,
              mesh: bool = False) -> ModelConfig:
    over = {}
    if kv_layout == "paged":
        # the mesh cell needs pool ROWS (blocks + null) divisible by the
        # shard count, so block homes actually activate: 39 + 1 = 40 rows
        over = {"kv_block_size": 8,
                "kv_pool_blocks": 39 if mesh else 40}
    return get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                            kv_layout=kv_layout, kv_quant=kv_quant, **over)


def _mesh_ctx(mesh: bool):
    """The forced-mesh cell's engine context: a (1, n_devices) mesh (the
    oracle always runs OUTSIDE it — parity must be vs the single-device
    reference).  Pool rows not divisible by the device count just means
    ``paged_homes`` returns 1 and the cell degrades to the unsharded path
    — still green, by the balance-not-correctness contract."""
    import contextlib

    from repro.parallel.hints import use_mesh
    if not mesh:
        return contextlib.nullcontext()
    return use_mesh(jax.make_mesh((1, jax.device_count()),
                                  ("data", "model")))


# oracle executables close over their cfg, so compile caches are shared
# ONLY within an identical (layout, quant) cell key — same idiom as the
# paged/prefix test suites
_ORACLE_CC: dict[tuple, CompileCache] = {}


def _oracle_cc(key: tuple) -> CompileCache:
    return _ORACLE_CC.setdefault(key, CompileCache())


def run_soak_cell(label: str, kv_layout: str, kv_quant: str,
                  spec_k: int, prefix_cache: bool, mesh: bool = False,
                  *, seed: int = 0,
                  n_requests: int = 10, compile_cache: CompileCache
                  | None = None) -> dict[str, Any]:
    """One soak cell: a faulted engine vs the unfaulted oracle.

    Asserts (1) every request reached a terminal state, (2) every
    ``done`` request's output is bitwise ``reference_decode`` on its
    ORIGINAL prompt, (3) every faulted/expired request's partial output is
    a strict prefix of its oracle stream (the fault cut it short, never
    corrupted it), and (4) the per-tick ``audit_every=1`` invariant checks
    stayed green (they raise otherwise).  Returns the cell's stats.
    """
    rng = np.random.default_rng(seed)
    cfg = _tiny_cfg(kv_layout, kv_quant, mesh)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    cc = (compile_cache if compile_cache is not None
          else _oracle_cc((kv_layout, kv_quant, spec_k, mesh)))
    monkey = ChaosMonkey(ChaosConfig(
        seed=seed + 1, deny_rate=0.10, preempt_rate=0.15, nan_rate=0.02,
        garbage_draft_rate=0.5 if spec_k else 0.0))
    max_len = 96

    # oracles run OUTSIDE the mesh context: parity is vs the single-device
    # reference, and the snapshot is taken BEFORE submit (preemption folds
    # output into the prompt)
    shared = rng.integers(0, cfg.vocab_size, 24)   # hot prefix for sharing
    reqs, oracle = [], {}
    for rid in range(n_requests):
        if rid % 3 == 0 and prefix_cache:
            prompt = np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, rng.integers(2, 9))])
        else:
            prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 33))
        r = Request(rid=rid, prompt=prompt.astype(np.int64),
                    max_new_tokens=int(rng.integers(4, 13)))
        oracle[rid] = reference_decode(cfg, params, prompt,
                                       r.max_new_tokens, max_len=max_len,
                                       compile_cache=cc)
        reqs.append(r)

    with _mesh_ctx(mesh):
        engine = Engine(cfg, params, batch_size=4, max_len=max_len,
                        chunk_size=16, prefill_token_budget=32,
                        spec_k=spec_k, prefix_cache=prefix_cache,
                        max_preemptions=2, audit_every=1, chaos=monkey,
                        compile_cache=cc)
        for r in reqs:
            engine.submit(r)
        done = engine.run(max_steps=4000)
    assert done.drained, (
        f"{label}: soak did not drain (truncated={done.truncated} "
        f"stalled={done.stalled} in_flight={done.in_flight})")
    engine.audit()                       # one final full audit
    outcomes: dict[str, int] = {}
    for r in reqs:
        assert r.done and r.status in ("done", "error"), (
            f"{label}: rid {r.rid} not terminal: {r.status}")
        outcomes[r.status] = outcomes.get(r.status, 0) + 1
        ref = oracle[r.rid]
        if r.status == "done":
            assert r.output == ref, (
                f"{label}: rid {r.rid} (preempted {r.preemptions}x) "
                f"diverged from oracle:\n  got {r.output}\n  ref {ref}")
        else:   # faulted: output up to the fault must still be the oracle's
            assert r.output == ref[:len(r.output)], (
                f"{label}: faulted rid {r.rid} corrupted before its fault")
        assert r.preemptions <= 2, f"{label}: preemption bound violated"
    if kv_layout == "paged":
        assert engine.alloc.n_free == engine.pool_blocks - (
            len(engine.prefix.blocks()) if engine.prefix is not None else 0), (
            f"{label}: leaked blocks after drain")
    return {"cell": label, "outcomes": outcomes,
            "n_homes": getattr(engine, "n_homes", 1),
            **engine.resilience_stats()}


def run_soak(seed: int = 0, n_requests: int = 10) -> list[dict[str, Any]]:
    """All cells; compile caches are shared per (layout, quant, spec) key —
    executables bake their cfg in, so cross-cfg sharing would be wrong."""
    return [run_soak_cell(*cell, seed=seed, n_requests=n_requests)
            for cell in SOAK_CELLS]


# -- kill/restore soak (ISSUE 9) --------------------------------------------

def run_restart_cell(label: str, kv_layout: str, kv_quant: str,
                     spec_k: int, prefix_cache: bool, mesh: bool = False,
                     *, seed: int = 0,
                     n_requests: int = 10,
                     max_lives: int = 12) -> dict[str, Any]:
    """One kill/restore cell: the full fault mix PLUS seeded process kills.

    The engine runs with snapshots + write-ahead journal; every
    ``EngineKilled`` abandons the live engine (the in-process stand-in for
    a dead process) and a fresh ``Engine.restore`` picks up from disk.
    Asserts the DURABLE record (``snapshot.journaled_streams`` across every
    journal epoch): each request reaches a terminal state exactly once, a
    ``done`` stream is bitwise ``reference_decode`` on the ORIGINAL prompt,
    a faulted stream is a strict prefix of it, ``audit()`` is green on the
    final engine, and no pool block leaked across any restart boundary.
    After ``max_lives`` deaths the monkey stops killing so the soak always
    drains."""
    import shutil
    import tempfile

    from repro.serving import snapshot as snaplib

    rng = np.random.default_rng(seed)
    cfg = _tiny_cfg(kv_layout, kv_quant, mesh)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    cc = _oracle_cc((kv_layout, kv_quant, spec_k, mesh))

    def monkey(life: int) -> ChaosMonkey:
        # Life 0 dies DETERMINISTICALLY at tick 7 — one tick past the first
        # periodic snapshot (every 6), so recovery always exercises snapshot
        # + journal-tail replay regardless of seed.  Later lives die
        # probabilistically until ``max_lives`` caps the soak.
        return ChaosMonkey(ChaosConfig(
            seed=seed + 100 + life, deny_rate=0.05, preempt_rate=0.10,
            nan_rate=0.02, garbage_draft_rate=0.5 if spec_k else 0.0,
            kill_after=7 if life == 0 else None,
            kill_rate=0.08 if 0 < life < max_lives else 0.0))

    max_len = 96
    workdir = tempfile.mkdtemp(prefix=f"restart_{label}_")

    shared = rng.integers(0, cfg.vocab_size, 24)   # hot prefix for sharing
    reqs, oracle = [], {}
    for rid in range(n_requests):
        if rid % 3 == 0 and prefix_cache:
            prompt = np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, rng.integers(2, 9))])
        else:
            prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 33))
        r = Request(rid=rid, prompt=prompt.astype(np.int64),
                    max_new_tokens=int(rng.integers(4, 13)))
        oracle[rid] = reference_decode(cfg, params, prompt,
                                       r.max_new_tokens, max_len=max_len,
                                       compile_cache=cc)
        reqs.append(r)

    # restores happen INSIDE the mesh context too: a snapshot taken under a
    # mesh records its home count, and the restoring engine must derive the
    # same one (snapshot._load_host enforces it)
    with _mesh_ctx(mesh):
        engine = Engine(cfg, params, batch_size=4, max_len=max_len,
                        chunk_size=16, prefill_token_budget=32,
                        spec_k=spec_k, prefix_cache=prefix_cache,
                        max_preemptions=2, audit_every=1, chaos=monkey(0),
                        compile_cache=cc,
                        snapshot_dir=workdir, snapshot_every=6)
        for r in reqs:
            engine.submit(r)

        lives = 1
        while True:
            try:
                res = engine.run(max_steps=4000)
                break
            except EngineKilled:
                # the killed engine object is abandoned wholesale — the
                # restore may only consult what reached disk
                engine = Engine.restore(workdir, params,
                                        chaos=monkey(lives),
                                        compile_cache=cc)
                lives += 1
    assert res.drained, (
        f"{label}: restart soak did not drain (truncated={res.truncated} "
        f"stalled={res.stalled} in_flight={res.in_flight})")
    engine.audit()
    kills = lives - 1
    assert kills >= 1, (
        f"{label}: no kill fired — raise kill_rate or max_steps")

    streams, status = snaplib.journaled_streams(workdir)
    outcomes: dict[str, int] = {}
    for rid in range(n_requests):
        st = status.get(rid)
        assert st in ("done", "error"), (
            f"{label}: rid {rid} durable status {st!r} not terminal")
        outcomes[st] = outcomes.get(st, 0) + 1
        ref = oracle[rid]
        got = streams.get(rid, [])
        if st == "done":
            assert got == ref, (
                f"{label}: rid {rid} durable stream diverged across "
                f"{kills} restart(s):\n  got {got}\n  ref {ref}")
        else:   # faulted: the stream up to the fault is still the oracle's
            assert got == ref[:len(got)], (
                f"{label}: faulted rid {rid} corrupted before its fault")
    if kv_layout == "paged":
        assert engine.alloc.n_free == engine.pool_blocks - (
            len(engine.prefix.blocks()) if engine.prefix is not None else 0), (
            f"{label}: leaked blocks across the restart boundary")
    stats = {"cell": label, "lives": lives, "kills": kills,
             "snapshots_taken": engine.snapshots_taken,
             "outcomes": outcomes, "n_homes": getattr(engine, "n_homes", 1),
             **engine.resilience_stats()}
    shutil.rmtree(workdir, ignore_errors=True)
    return stats


def run_restart_soak(seed: int = 0,
                     n_requests: int = 10) -> list[dict[str, Any]]:
    """Kill/restore chaos across all six engine mixtures."""
    return [run_restart_cell(*cell, seed=seed, n_requests=n_requests)
            for cell in SOAK_CELLS]


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--n-requests", type=int, default=10)
    p.add_argument("--restart", action="store_true",
                   help="run the kill/restore soak (snapshots + journal + "
                        "seeded process kills) instead of the in-process one")
    p.add_argument("--out", default=None,
                   help="write per-cell stats JSON here (CI artifact)")
    args = p.parse_args()
    soak = run_restart_soak if args.restart else run_soak
    stats = soak(seed=args.seed, n_requests=args.n_requests)
    for s in stats:
        print(json.dumps(s))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"seed": args.seed, "cells": stats}, f, indent=2)
        print(f"wrote {args.out}")
    kind = "kill/restore" if args.restart else "chaos"
    print(f"{kind} soak OK: {len(stats)} cells green")


if __name__ == "__main__":
    main()
