"""Model-free draft-token proposers for speculative decoding.

Decode is bandwidth-bound: every tick streams the full weight set (and the
live KV) to advance each row by ONE token.  ``api.mixed_step`` already
scores ``q_lens[b]`` tokens per row in one dispatch, so if something cheap
can GUESS the next K tokens, the engine verifies all K+1 positions for one
weight stream — accepted tokens are free bandwidth-wise.  The guesser here
is prompt-lookup / n-gram drafting (no second model, no new params, no new
executables): LLM output is locally repetitive — copied spans, code
boilerplate, format scaffolding, greedy loops — so the continuation of the
row's CURRENT suffix n-gram has usually been seen before in the row's own
token history.

``PromptLookupDrafter`` keeps, per engine slot, the request's token history
(prompt + everything emitted) and an incremental suffix index: a hash map
from each n-gram (``ngram_min <= n <= ngram_max``) to the position where it
last occurred — the O(1)-per-token collapsed form of a suffix automaton's
last-occurrence endpoints, which is the only query drafting needs (match
the longest indexed suffix of the history, propose the tokens that followed
its previous occurrence).  Rejected drafts are never observed, so the
history always equals the accepted stream and rollback needs no drafter
bookkeeping.

Acceptance is decided by the target model (longest agreeing greedy prefix),
so draft quality affects THROUGHPUT only, never outputs — a drafter may
return garbage, fewer than ``k`` tokens, or nothing at all (the engine then
decodes that row plainly).
"""

from __future__ import annotations


class PromptLookupDrafter:
    """Per-slot n-gram / prompt-lookup draft proposer.

    ``observe(slot, tokens)`` appends accepted tokens to the slot's history
    and indexes the new suffix n-grams; ``draft(slot, k)`` proposes up to
    ``k`` continuation tokens by matching the longest current suffix n-gram
    against its LAST earlier occurrence; ``reset(slot)`` clears the slot for
    its next lease.  All host-side, O(ngram_max) per token.
    """

    def __init__(self, *, ngram_max: int = 3, ngram_min: int = 1):
        if not 1 <= ngram_min <= ngram_max:
            raise ValueError(f"need 1 <= ngram_min <= ngram_max, got "
                             f"{ngram_min}..{ngram_max}")
        self.ngram_max = ngram_max
        self.ngram_min = ngram_min
        self._history: dict[int, list[int]] = {}
        # per slot, per n: n-gram tuple -> index AFTER its last occurrence
        self._index: dict[int, dict[int, dict[tuple, int]]] = {}

    def reset(self, slot: int) -> None:
        self._history.pop(slot, None)
        self._index.pop(slot, None)

    def observe(self, slot: int, tokens) -> None:
        """Append accepted tokens to ``slot``'s history (prompt at admission,
        then each emitted token) and index their suffix n-grams.  Each
        n-gram keeps its last TWO occurrence endpoints: the history's
        current suffix is always its own last occurrence, so drafting needs
        the one before it (a cycle like ``a b a b`` must still match)."""
        hist = self._history.setdefault(slot, [])
        idx = self._index.setdefault(
            slot, {n: {} for n in range(self.ngram_min, self.ngram_max + 1)})
        for t in tokens:
            hist.append(int(t))
            end = len(hist)
            for n in range(self.ngram_min, min(self.ngram_max, end) + 1):
                g = tuple(hist[end - n:end])
                cur = idx[n].get(g)
                idx[n][g] = (end, cur[0] if cur is not None else None)

    def draft(self, slot: int, k: int) -> list[int]:
        """Propose up to ``k`` tokens continuing ``slot``'s history.

        Matches the longest suffix n-gram with an earlier occurrence and
        copies the run that followed it.  When the match lies within ``k``
        of the end, the copy overlaps the current position — the tail IS a
        cycle of period ``end - pos`` (a constant run is the period-1 case)
        and the draft continues it periodically instead of truncating at
        the end of history.  Returns [] when nothing matches (the engine
        decodes the row plainly that tick).
        """
        hist = self._history.get(slot)
        if not hist or k <= 0:
            return []
        end = len(hist)
        for n in range(min(self.ngram_max, end - 1), self.ngram_min - 1, -1):
            rec = self._index[slot][n].get(tuple(hist[end - n:end]))
            if rec is None:
                continue
            # the suffix IS its own last occurrence — take the one before
            pos = rec[0] if rec[0] < end else rec[1]
            if pos is None:
                continue
            if pos + k <= end:
                return hist[pos:pos + k]
            period = end - pos
            return [hist[pos + (j % period)] for j in range(k)]
        return []

    def history_len(self, slot: int) -> int:
        return len(self._history.get(slot, ()))

    def dump(self) -> dict:
        """JSON-safe capture: histories only — the suffix index is a pure
        function of the history and is rebuilt on ``load``."""
        return {"ngram_max": self.ngram_max, "ngram_min": self.ngram_min,
                "history": {str(s): list(h) for s, h in self._history.items()}}

    def load(self, state: dict) -> None:
        """Rebuild per-slot histories (and their indexes) from ``dump()``."""
        self._history = {}
        self._index = {}
        for s, hist in state.get("history", {}).items():
            self.observe(int(s), hist)


DRAFTERS = {
    "plookup": PromptLookupDrafter,
}


def make_drafter(name: str, **kwargs) -> PromptLookupDrafter:
    """Build a drafter by registry name (the ``--drafter`` serving knob)."""
    if name not in DRAFTERS:
        raise ValueError(f"unknown drafter {name!r}; have {sorted(DRAFTERS)}")
    return DRAFTERS[name](**kwargs)
