"""Serving engine: slot-based continuous batching (EdgeLLM §IV-B, Fig. 9).

The paper's deployment keeps the accelerator saturated by pre-compiling a
fixed executable set and pipelining host work behind device compute.  The
JAX restatement of that contract, end to end:

* **One resident cache.**  ``api.init_cache(cfg, B, max_len)`` allocates a
  single slot-based cache (KV: ``(layers, B, heads, L, hd)``; recurrent
  families: per-row state) that lives on device for the engine's lifetime.
  Requests do not own cache pytrees — they *lease a slot*.

* **Batch-1 bucketed prefill, scattered into a slot.**  A prompt prefills
  at its ``TokenBuckets`` length bucket (the paper's per-token-length
  instruction streams) and the resulting row cache is written into a free
  slot with ``api.insert_request`` — a ``dynamic_update_slice`` scatter
  whose slot index is a traced operand, so one executable covers all slots.

* **One jitted decode per step, per-row lengths.**  ``api.decode_step``
  advances ALL ``B`` slots in a single device call against the shared cache
  with ``lengths: (B,)`` masking each row to its own context — decode cost
  is one dispatch per step regardless of how many requests are live, not
  O(live) Python-dispatched batch-1 calls.

* **Continuous batching.**  Finished rows are retired mid-flight
  (``api.evict_slot`` resets recurrent state) and immediately refilled from
  the queue; the batch never drains to restart.  This is the scheduler half
  of Fig. 9 — the host admits/retires while JAX's async dispatch overlaps
  the next step's input prep with device compute (``core/pipeline.py``
  measures that overlap).

* **Bounded compilation.**  Executables are memoized in ``CompileCache``
  under ``("prefill", bucket)`` / ``("decode", B)`` / ``("insert", B)`` —
  misses are bounded by ``n_buckets + 2`` no matter the traffic.
"""

from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import CompileCache, TokenBuckets
from repro.models import api
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int = 32
    frames: np.ndarray | None = None  # (F, d) audio family only
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float | None = None
    finished_at: float | None = None


@dataclasses.dataclass
class _Slot:
    """Host-side mirror of one row of the resident cache."""
    req: Request | None = None
    length: int = 1                  # valid context length of this row
    last_token: int = 0              # input token for the next decode step


def _bucketed_prompt_batch(prompt: np.ndarray, bucket: int,
                           frames: np.ndarray | None = None) -> dict:
    """Left-pad a prompt into its token bucket; shared by the engine and
    the batch-1 oracle so their prefill inputs can never drift apart."""
    padded = np.zeros((1, bucket), np.int32)
    padded[0, -len(prompt):] = prompt
    batch = {"tokens": jnp.asarray(padded)}
    if frames is not None:
        f = np.asarray(frames)
        batch["frames"] = jnp.asarray(f[None] if f.ndim == 2 else f)
    return batch


def _prefill_executable(cfg: ModelConfig, max_len: int):
    def fn(p, batch):
        return api.prefill(cfg, p, batch, max_len)
    return jax.jit(fn)


def _insert_executable(cfg: ModelConfig):
    def fn(c, row, slot):
        return api.insert_request(cfg, c, row, slot)
    # donate the resident cache: the engine rebinds it on every call, so XLA
    # may update the slot in place instead of copying the whole cache
    return jax.jit(fn, donate_argnums=(0,))


def _decode_executable(cfg: ModelConfig):
    def fn(p, c, tokens, lengths):
        logits, new_c = api.decode_step(cfg, p, c, tokens, lengths)
        return jnp.argmax(logits, axis=-1), logits, new_c
    return jax.jit(fn, donate_argnums=(1,))


class Engine:
    """Continuous-batching decode engine over one slot-based cache."""

    def __init__(self, cfg: ModelConfig, params: Any, *, batch_size: int = 4,
                 max_len: int = 512, eos_id: int | None = None,
                 compile_cache: CompileCache | None = None):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.buckets = TokenBuckets(max_tokens=max_len)
        # a shared compile cache must come from an engine with the same
        # (cfg, max_len): executables bake both in
        self.cache_compiles = compile_cache or CompileCache()
        self._queue: "queue.Queue[Request]" = queue.Queue()
        # the resident slot cache (slots are reset lazily: admission
        # overwrites every leaf of the leased row)
        self.cache = api.init_cache(cfg, batch_size, max_len)
        self._slots = [_Slot() for _ in range(batch_size)]
        self.steps = 0
        self.decode_calls = 0        # must equal steps: one dispatch per step
        self._occupancy_sum = 0.0

    # -- client API ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} exceeds "
                f"engine max_len {self.max_len} — raise max_len or truncate")
        req.submitted_at = time.monotonic()
        self._queue.put(req)

    # -- executables (all memoized: misses bounded by n_buckets + 2) ---------

    def _build_prefill(self):
        return _prefill_executable(self.cfg, self.max_len)

    def _build_insert(self):
        return _insert_executable(self.cfg)

    def _build_decode(self):
        return _decode_executable(self.cfg)

    # -- internals -----------------------------------------------------------

    def _prefill_one(self, req: Request):
        """Batch-1 prefill at the request's length bucket."""
        bucket = self.buckets.bucket(len(req.prompt))
        fn = self.cache_compiles.get("prefill", bucket, self._build_prefill)
        batch = _bucketed_prompt_batch(req.prompt, bucket, req.frames)
        logits, row_cache = fn(self.params, batch)
        return logits, row_cache, bucket

    def _finish(self, req: Request, completed: list[Request]) -> None:
        req.done = True
        req.finished_at = time.monotonic()
        completed.append(req)

    def _free_slot(self, idx: int) -> None:
        """Retire a row: release the host lease.

        Device eviction is lazy — the next ``_admit`` overwrites every leaf
        of the row (``api.evict_slot`` exists for callers that need an
        eager reset), so retirement costs no device dispatch.  The dead row
        rides along in decode at its parked length; its output is ignored.
        """
        self._slots[idx] = _Slot()

    def _admit(self, req: Request, idx: int, sample, completed) -> None:
        """Prefill ``req`` and lease slot ``idx`` to it (continuous refill)."""
        logits, row_cache, bucket = self._prefill_one(req)
        row = np.asarray(logits[0])        # blocks until the device is done
        req.first_token_at = time.monotonic()
        tok = int(np.argmax(row)) if sample is None else int(sample(row))
        req.output.append(tok)
        if (len(req.output) >= req.max_new_tokens or
                bucket >= self.max_len or   # no cache room left to decode into
                (self.eos_id is not None and tok == self.eos_id)):
            self._finish(req, completed)   # done at prefill; slot stays free
            return
        insert = self.cache_compiles.get("insert", self.batch,
                                         self._build_insert)
        self.cache = insert(self.cache, row_cache, np.int32(idx))
        self._slots[idx] = _Slot(req=req, length=bucket, last_token=tok)

    def run(self, *, max_steps: int = 10_000,
            sample: Callable | None = None) -> list[Request]:
        """Drain the queue; returns completed requests.

        Each loop iteration: (1) retire rows out of cache room, (2) refill
        every free slot from the queue (prefill + slot insert), (3) advance
        ALL slots with exactly one jitted decode call.  ``sample`` maps a
        logits row (V,) to a token id; greedy argmax (computed on device)
        when None.
        """
        completed: list[Request] = []
        start_steps = self.steps       # max_steps bounds THIS call, not the
        while self.steps - start_steps < max_steps:  # engine's lifetime
            # 1. retire rows whose context hit the cache bound
            for i, slot in enumerate(self._slots):
                if slot.req is not None and slot.length >= self.max_len:
                    self._finish(slot.req, completed)
                    self._free_slot(i)
            # 2. continuous refill: admit queued requests into free slots
            for i in range(self.batch):
                while self._slots[i].req is None and not self._queue.empty():
                    self._admit(self._queue.get(), i, sample, completed)
            live = [i for i, s in enumerate(self._slots) if s.req is not None]
            if not live:
                break  # queue drained and no row in flight
            # 3. one batched decode step for all B rows (dead rows ride along
            #    at their parked length; their output is ignored)
            tokens = np.fromiter((s.last_token for s in self._slots),
                                 np.int32, self.batch).reshape(self.batch, 1)
            lengths = np.fromiter(
                (s.length + (1 if s.req is not None else 0)
                 for s in self._slots), np.int32, self.batch)
            decode = self.cache_compiles.get("decode", self.batch,
                                             self._build_decode)
            next_tok, logits, self.cache = decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(lengths))
            self.steps += 1
            self.decode_calls += 1
            self._occupancy_sum += len(live) / self.batch
            next_np = np.asarray(next_tok)
            logits_np = None if sample is None else np.asarray(logits)
            for i in live:
                slot = self._slots[i]
                req = slot.req
                slot.length += 1
                tok = (int(next_np[i]) if sample is None
                       else int(sample(logits_np[i])))
                req.output.append(tok)
                slot.last_token = tok
                if (len(req.output) >= req.max_new_tokens or
                        (self.eos_id is not None and tok == self.eos_id)):
                    self._finish(req, completed)
                    self._free_slot(i)
        return completed

    # -- metrics ---------------------------------------------------------------

    @property
    def slot_occupancy(self) -> float:
        """Mean fraction of slots live per decode step (1.0 = saturated)."""
        return self._occupancy_sum / self.steps if self.steps else 0.0

    @staticmethod
    def summarize(reqs: list[Request]) -> dict[str, float]:
        if not reqs:
            return {}
        ttft = [r.first_token_at - r.submitted_at for r in reqs
                if r.first_token_at]
        # decode throughput: measured from the first token so queue-wait
        # does not pollute the device tokens/s number
        tps = [(len(r.output) - 1) /
               max(r.finished_at - r.first_token_at, 1e-9)
               for r in reqs
               if r.finished_at and r.first_token_at and len(r.output) > 1]
        return {
            "n": len(reqs),
            "total_tokens": float(sum(len(r.output) for r in reqs)),
            "mean_ttft_s": float(np.mean(ttft)) if ttft else float("nan"),
            "mean_tokens_per_s": float(np.mean(tps)) if tps else float("nan"),
        }


def reference_decode(cfg: ModelConfig, params: Any, prompt: np.ndarray,
                     max_new_tokens: int, *, max_len: int = 512,
                     eos_id: int | None = None,
                     frames: np.ndarray | None = None,
                     compile_cache: CompileCache | None = None) -> list[int]:
    """Per-request batch-1 greedy decode — the seed engine's inner loop.

    Kept as (a) the numerics oracle the batched slot engine must match and
    (b) the baseline ``benchmarks/serving_bench.py`` compares against.
    Uses the same bucketed left-padded prefill and the same per-row-lengths
    decode path (``lengths: (1,)``), so outputs are directly comparable.
    """
    cc = compile_cache if compile_cache is not None else CompileCache()
    buckets = TokenBuckets(max_tokens=max_len)
    bucket = buckets.bucket(len(prompt))
    pf = cc.get("ref_prefill", bucket, lambda: jax.jit(
        lambda p, b: api.prefill(cfg, p, b, max_len)))
    logits, cache = pf(params, _bucketed_prompt_batch(prompt, bucket, frames))
    out = [int(np.argmax(np.asarray(logits[0])))]
    dec = cc.get("ref_decode", 1, lambda: jax.jit(
        lambda p, c, t, l: api.decode_step(cfg, p, c, t, l)))
    length = bucket
    while (len(out) < max_new_tokens and length < max_len and
           (eos_id is None or out[-1] != eos_id)):
        length += 1
        logits, cache = dec(params, cache,
                            jnp.asarray([[out[-1]]], jnp.int32),
                            jnp.asarray([length], jnp.int32))
        out.append(int(np.argmax(np.asarray(logits[0]))))
    return out
