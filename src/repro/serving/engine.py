"""Serving engine: chunked-prefill continuous batching over one slot cache
(EdgeLLM §IV-B, Fig. 9 — plus the §IV "one data shape for every operator"
contract applied to admission).

The paper keeps the FPGA saturated by giving every operator the same data
shape so one fixed executable processes any token stream.  The seed engine
broke that contract at admission time: each new prompt ran a *separate*
batch-1 bucketed prefill that head-of-line-blocked every live decode slot
for the whole prompt.  This engine fuses admission into the per-step decode
dispatch instead:

* **One resident cache.**  ``api.init_cache(cfg, B, max_len)`` allocates a
  single slot-based cache (KV: ``(layers, B, heads, L, hd)``; recurrent
  families: per-row state) that lives on device for the engine's lifetime.
  Requests do not own cache pytrees — they *lease a slot*.

* **One mixed-batch dispatch per tick.**  ``api.mixed_step`` advances ALL
  ``B`` slots in a single jitted call; row ``b`` advances by ``q_lens[b]``
  tokens — 1 for a decoding row, up to C (the chunk bucket) for a row
  mid-prefill.  Prompts are split into chunk-bucket pieces (Sarathi-style
  token budget) and co-scheduled with decode rows, so admission costs ZERO
  extra dispatches and decode rows never stall behind a long prompt.  Ticks
  with no prefill work degrade to the classic ``api.decode_step``
  executable — bit-identical to the batch-1 oracle.

* **True-length accounting.**  Slots track the request's TRUE token count
  (not a padded bucket): K/V land at the row's real positions
  (``dynamic_update_slice`` at its current length — no left-pad writes),
  decode never attends over pad tokens, and cache room is measured exactly
  — a prompt is admissible whenever ``len(prompt) <= max_len``.

* **True recurrent prefill.**  Chunks run the prompt *through the
  cache-updating step path*, so ssm/hybrid slots hold the REAL post-prompt
  recurrent state (the old forward-as-prefill gap is closed); admission
  first resets the leased slot via ``api.request_cache`` +
  ``insert_request`` for the families that need it (recurrent state, audio
  cross-KV).

* **Continuous batching.**  Finished rows are retired mid-flight and
  immediately refilled from the queue; the batch never drains to restart.

* **Bounded compilation.**  Executables memoize in ``CompileCache`` under
  ``("mixed", W)`` (one per chunk-width bucket W), ``("decode", B)`` and
  ``("insert", B)`` — misses are bounded by ``n_chunk_buckets + 2``
  regardless of traffic (audio adds one ``("admit", F)`` encoder
  executable), the XLA analogue of the paper's per-token-length instruction
  streams with a MAX-token address space.

* **Speculative decoding (``spec_k > 0``).**  Decode is bandwidth-bound:
  every tick streams the whole weight set to advance each row by one token.
  A model-free prompt-lookup drafter (``serving/draft.py``) proposes up to
  K continuation tokens per decode row from the row's own token history;
  the engine packs ``[last_token, d_1..d_K]`` as a ``q_lens[b] = K+1``
  chunk into the SAME ``("mixed", W)`` dispatch (verify rows co-scheduled
  with decode rows and mid-prefill chunks under one token budget — zero
  new executable shapes), and ``mixed_step(all_logits=True)`` returns
  every position's greedy token so acceptance — the longest draft prefix
  agreeing with the model's own greedy choices — costs zero extra device
  round-trips.  Accepted tokens emit ``a + 1`` per dispatch (the ``+1`` is
  the model's token at the first disagreement, so every verify tick
  emits at least what plain decode would); the rejected tail is rolled
  back host-side by ``_rewind_slot`` — ``lengths[b]`` shrinks (stale K/V
  past it hides behind true-length masking) and, under paging, wholly
  dead tail blocks are re-nulled in the table and returned to the free
  list.  Greedy acceptance is LOSSLESS: outputs are token-for-token the
  ``reference_decode`` oracle's, speculation only changes how many
  dispatches they take.  Families without a rewindable sequence dimension
  (ssm/hybrid recurrent state) fail ``api.supports_speculation`` and fall
  back to plain decode; a ``sample`` hook disables speculation for the
  call (acceptance is defined against greedy).  A verify row that fully
  rejects still costs a W-wide tick, so drafting is ADAPTIVE: a slot whose
  drafts keep missing backs off exponentially (skipping drafting for 1, 2,
  4, ... up to ``_DRAFT_BACKOFF_MAX`` ticks) and any accepted token resets
  it — cold rows decode plainly, repetitive rows speculate at full depth.

* **Paged KV (``cfg.kv_layout == "paged"``).**  KV leaves become ONE shared
  block pool; each slot addresses it through a row of the HOST-side page
  table, which rides into every dispatch as a plain operand (the dispatch
  shapes — and so the executable set — are unchanged: the paper's
  one-data-shape contract survives paging).  Allocation is on-demand: a row
  leases a block when its length crosses a block boundary, and retirement
  returns the row's blocks to the free list.  Admission reserves each
  request's WORST-CASE block count (``ceil(min(len + max_new, max_len) /
  bs)``) up front — a request is only admitted when the unreserved free
  blocks cover it, so a live row can always lease its next block and the
  pool can never deadlock; requests held back by reservation count as
  ``admission_stalls``.  Because slots no longer pin ``max_len`` rows each,
  ``batch_size`` may exceed ``pool_tokens / max_len`` — short requests stop
  paying for long ones, which is the capacity lever
  ``benchmarks/serving_bench.py --paged-capacity`` measures.

* **Prefix sharing (``prefix_cache=True``, paged transformer families).**
  The free list becomes a refcounted ``BlockAllocator`` and a host-side
  ``RadixPrefixCache`` maps block-aligned prompt prefixes to the physical
  blocks that already hold their K/V (``serving/prefix.py``).  Admission of
  a request whose prompt walks a cached path is a PAGE-TABLE COPY: the
  shared blocks are increfed into the slot's table rows and only the
  uncovered suffix streams through chunked prefill — no new executables and
  no kernel changes, because the page table already rides in as a plain
  operand and kernels only ever READ through it.  Serving writes are
  append-only, so copy-on-write fires at most once per admission: when the
  suffix starts mid-block, the engine leases a fresh block, duplicates the
  shared one on device (the single ``("cow", 0)`` executable) and overwrites
  its tail through the normal chunk writer.  Worst-case reservation shrinks
  by the shared block count (the CoW page leases normally, so the "+1 CoW
  block" stays inside the reservation) and ``sum(reserve) <= free`` stays
  the deadlock-free invariant.  A finished prompt donates its fully-written
  blocks back to the cache (one cache-held reference each), so hot system
  prompts stay resident after their first author retires; under pool
  pressure, admission evicts cold cache leaves LRU-first — but only blocks
  the cache is the SOLE holder of, so shared residents are evicted last.
  Sharing is exact: ``mixed_step`` is bitwise equal to sequential decode,
  so cached K/V is bit-identical to a recompute and token streams match the
  cache-OFF engine and ``reference_decode`` token for token.

* **Resilience: lifecycle, preemption, fault quarantine.**  Every request
  walks an explicit state machine — ``queued -> running -> {done, error,
  cancelled, deadline_missed}`` (preemption loops a running request back to
  ``queued``) — and pool pressure has a second answer beyond admission
  stalls: with ``max_preemptions > 0``, a FIFO head that cannot reserve
  (after LRU prefix eviction already ran) PREEMPTS the youngest /
  lowest-priority running slot.  Preemption is lossless and cheap: the
  victim's fully-written blocks are donated to the radix prefix cache
  (prompt AND accepted output — so re-admission is mostly a page-table
  copy), its accepted output is folded into its prompt, and it requeues
  just behind the head; the slot layout falls back to plain
  evict-and-recompute.  Each request is preempted at most
  ``max_preemptions`` times, then becomes immune — so admission-triggered
  eviction can never starve anyone.  ``deadline_s`` requests are swept
  every tick (queued or running) once ``enforce_deadlines`` is on, and
  ``cancel(rid)`` retires a request at any point in the lifecycle.  Faults
  stay inside their row: non-finite logits (``check_finite``) and a
  throwing ``sample`` hook quarantine ONLY the offending slot (terminal
  ``status="error"``, blocks freed, allocator invariants intact) instead of
  propagating out of the tick — and a poisoned row's blocks are never
  donated to the prefix cache.  ``audit_every=N`` self-checks the
  allocator partition, reservation invariant and page-table/ownership
  coherence every N ticks; ``serving/chaos.py`` injects deterministic
  faults (reservation denials, forced preemptions, NaN rows, garbage
  drafts) against exactly these seams.  ``run()`` returns a ``RunResult``
  (a list) whose ``truncated``/``in_flight``/``queued`` fields make a
  ``max_steps`` budget hit explicit instead of silently dropping work.

* **Durability (``snapshot_dir=``, ``serving/snapshot.py``).**  Process
  death is a routine edge operating condition, so serving state is
  persistable: atomic point-in-time snapshots (device KV pool + the full
  host control plane — slots, page tables, allocator refcounts, radix
  cache, request lifecycle fields with deadlines as REMAINING budget,
  drafter history, compile keys for warm re-jit) plus an append-only
  write-ahead journal of submit/emit/terminal events, fsync'd once per
  tick.  ``Engine.restore(dir, params)`` loads the latest complete
  snapshot, replays the journal — post-snapshot output re-folds into
  prompts via the ``_fold_slot`` preemption primitive, so re-admission is
  mostly prefix-cache page-table copies — and resumes with token streams
  BITWISE equal to the never-killed engine's.  A snapshot interrupted
  mid-write is never observed (the previous complete one wins), and the
  injectable ``clock`` keeps restored deadlines counting down from what
  was left, not from a dead process's monotonic base.
"""

from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import CompileCache, TokenBuckets
from repro.models import api
from repro.models.config import ModelConfig
from repro.serving.prefix import BlockAllocator, RadixPrefixCache


@dataclasses.dataclass
class _PrefixPlan:
    """Host-side admission plan from a radix-cache hit (see module doc).

    ``shared`` blocks map read-only into the slot's page table (one incref
    each); ``cow`` is the one cached block whose matched HEAD is reused via
    copy-on-write (None when the suffix starts block-aligned); ``consumed``
    prompt tokens are covered without recompute — always < len(prompt), so
    at least one prompt token runs and produces the first-token logits."""
    shared: list[int]
    cow: int | None
    consumed: int


# request lifecycle: queued -> running -> one terminal state (preemption
# loops running back to queued; ``done`` stays True exactly on terminals)
TERMINAL_STATES = ("done", "error", "cancelled", "deadline_missed")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int = 32
    frames: np.ndarray | None = None  # (F, d) audio family only
    priority: int = 0                # higher = admitted/kept first
    deadline_s: float | None = None  # seconds after submit; None = no deadline
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "queued"           # queued|running|done|error|cancelled|
    #                                  deadline_missed
    error: str | None = None         # quarantine reason when status=="error"
    preemptions: int = 0             # times evicted-and-requeued (bounded)
    folded: int = 0                  # output tokens already folded into
    #                                  prompt by earlier preemptions
    submitted_at: float = 0.0
    first_token_at: float | None = None
    finished_at: float | None = None
    token_times: list = dataclasses.field(default_factory=list)


class RunResult(list):
    """``Engine.run``'s return value: the requests that reached a terminal
    state during the call (a plain list, for compatibility), plus the drain
    state — ``truncated`` is True when ``max_steps`` ran out with work still
    queued or in flight (the budget hit is explicit, never silent),
    ``stalled`` when the queue is non-empty but nothing could be admitted
    and no row is live (permanent starvation signature: call again after
    freeing resources)."""

    def __init__(self, reqs=(), *, truncated: bool = False,
                 in_flight: int = 0, queued: int = 0, stalled: bool = False):
        super().__init__(reqs)
        self.truncated = truncated
        self.in_flight = in_flight
        self.queued = queued
        self.stalled = stalled

    @property
    def drained(self) -> bool:
        """True when no work remains anywhere in the engine."""
        return not (self.truncated or self.stalled or
                    self.in_flight or self.queued)


@dataclasses.dataclass
class _Slot:
    """Host-side mirror of one row of the resident cache."""
    req: Request | None = None
    length: int = 0                  # TRUE tokens resident in this row
    pos: int = 0                     # prompt tokens consumed (chunk cursor)
    last_token: int = 0              # input token for the next decode step
    seq: int = 0                     # admission order (preemption picks the
    #                                  youngest = largest seq first)

    @property
    def prefilling(self) -> bool:
        return self.req is not None and self.pos < len(self.req.prompt)


def _mixed_executable(cfg: ModelConfig):
    def fn(p, c, tokens, lengths, q_lens):
        logits, new_c = api.mixed_step(cfg, p, c, tokens, lengths, q_lens)
        return jnp.argmax(logits, axis=-1), logits, new_c
    return jax.jit(fn, donate_argnums=(1,))


def _mixed_executable_paged(cfg: ModelConfig):
    def fn(p, c, tokens, lengths, q_lens, page_table):
        logits, new_c = api.mixed_step(cfg, p, c, tokens, lengths, q_lens,
                                       page_table=page_table)
        return jnp.argmax(logits, axis=-1), logits, new_c
    return jax.jit(fn, donate_argnums=(1,))


# adaptive-speculation cap: a slot whose drafts keep fully rejecting sits
# out 1, 2, 4, ... up to this many ticks before drafting again
_DRAFT_BACKOFF_MAX = 8


def _mixed_executable_spec(cfg: ModelConfig, paged: bool):
    """Verify-capable mixed tick: ``all_logits=True`` scores every chunk
    position, the per-position greedy tokens (B, C) come back for host-side
    draft acceptance, and the last-live-position logits keep the ``sample``
    hook's contract.  A speculating engine uses this variant for ALL its
    mixed ticks, so keys stay exactly ``("mixed", W)`` — the price is
    unembedding W positions instead of 1 on chunked-prefill ticks, which is
    what buys verify ticks their K-fold weight-stream amortization."""
    def fn(p, c, tokens, lengths, q_lens, page_table=None):
        kw = {"page_table": page_table} if paged else {}
        logits, new_c = api.mixed_step(cfg, p, c, tokens, lengths, q_lens,
                                       all_logits=True, **kw)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, C)
        idx = jnp.clip(q_lens - 1, 0, tokens.shape[1] - 1)
        next_tok = jnp.take_along_axis(greedy, idx[:, None], axis=1)[:, 0]
        last_logits = jnp.take_along_axis(
            logits, idx[:, None, None], axis=1)[:, 0]
        return next_tok, last_logits, new_c, greedy
    return jax.jit(fn, donate_argnums=(1,))


def _decode_executable(cfg: ModelConfig):
    def fn(p, c, tokens, lengths):
        logits, new_c = api.decode_step(cfg, p, c, tokens, lengths)
        return jnp.argmax(logits, axis=-1), logits, new_c
    return jax.jit(fn, donate_argnums=(1,))


def _decode_executable_paged(cfg: ModelConfig):
    # write_mask keeps non-advancing rows (retired slots riding along, rows
    # between ticks) from writing through a stale/parked page table entry —
    # the paged replacement for "stale rows hide behind true-length masking"
    def fn(p, c, tokens, lengths, page_table, write_mask):
        logits, new_c = api.decode_step(cfg, p, c, tokens, lengths,
                                        page_table=page_table,
                                        write_mask=write_mask)
        return jnp.argmax(logits, axis=-1), logits, new_c
    return jax.jit(fn, donate_argnums=(1,))


def _insert_executable(cfg: ModelConfig):
    def fn(c, row, slot):
        return api.insert_request(cfg, c, row, slot)
    # donate the resident cache: the engine rebinds it on every call, so XLA
    # may update the slot in place instead of copying the whole cache
    return jax.jit(fn, donate_argnums=(0,))


def _admit_executable(cfg: ModelConfig, max_len: int):
    def fn(p, frames):
        return api.request_cache(cfg, p, {"frames": frames}, max_len)
    return jax.jit(fn)


class Engine:
    """Continuous-batching engine: one mixed-batch dispatch per tick."""

    def __init__(self, cfg: ModelConfig, params: Any, *, batch_size: int = 4,
                 max_len: int = 512, eos_id: int | None = None,
                 chunk_size: int = 64,
                 prefill_token_budget: int | None = None,
                 prefill_policy: str = "mixed",
                 spec_k: int = 0, drafter: Any = "plookup",
                 prefix_cache: bool = False,
                 max_preemptions: int = 0,
                 enforce_deadlines: bool = True,
                 check_finite: bool = True,
                 audit_every: int = 0,
                 chaos: Any = None,
                 compile_cache: CompileCache | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 snapshot_dir: str | None = None,
                 snapshot_every: int = 0,
                 snapshot_keep: int = 2,
                 journal: bool = True):
        if prefill_policy not in ("mixed", "stall"):
            raise ValueError(f"unknown prefill_policy {prefill_policy!r}")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if max_preemptions < 0:
            raise ValueError(
                f"max_preemptions must be >= 0, got {max_preemptions}")
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        # >= 2 so a mixed tick never takes mixed_step's C == 1 decode
        # delegation (that path assumes every row advances by one token)
        self.chunk_size = max(2, min(chunk_size, max_len))
        self.prefill_token_budget = prefill_token_budget
        self.prefill_policy = prefill_policy
        # speculative decoding: drafts ride the mixed dispatch as K+1-token
        # chunks, so K is capped by the chunk width (and one slot of cache
        # room for the mandatory real token).  Families without a rewindable
        # sequence dimension cleanly fall back to plain decode (spec_k -> 0;
        # the request is recorded so callers can see the gate fired).
        self.spec_requested = spec_k
        self.spec_supported = api.supports_speculation(cfg)
        self.spec_k = (min(spec_k, self.chunk_size - 1)
                       if spec_k and self.spec_supported else 0)
        # chunk widths are bucketed so executables stay bounded: a tick's
        # dispatch width W is the smallest bucket covering its largest chunk.
        # A speculating engine keeps FINER buckets: verify ticks are only
        # K+1 wide, and padding a 3-wide verify tick to the full chunk width
        # costs more than the dispatch it saves — same ("mixed", W) key
        # family either way, and compile_budget counts all_buckets().
        self.chunk_buckets = TokenBuckets(
            max_tokens=self.chunk_size,
            min_bucket=min(4 if self.spec_k else 16, self.chunk_size))
        if self.spec_k:
            from repro.serving.draft import make_drafter
            self.drafter = (make_drafter(drafter)
                            if isinstance(drafter, str) else drafter)
        else:
            self.drafter = None
        self.spec_ticks = 0        # dispatches carrying >= 1 verify row
        self.spec_rows = 0         # verify rows dispatched
        self.spec_drafted = 0      # draft tokens scored
        self.spec_accepted = 0     # draft tokens accepted
        self.spec_rewinds = 0      # partial/full rejections rolled back
        # adaptive speculation: per-slot exponential backoff after fully
        # rejected drafts (a miss still costs a W-wide verify tick)
        self._draft_wait = [0] * batch_size      # ticks left to sit out
        self._draft_penalty = [0] * batch_size   # current backoff length
        # a shared compile cache must come from an engine with the same
        # (cfg, max_len, batch, chunk_size, spec on/off): executables bake
        # these in — a speculating engine's mixed executables return the
        # per-position greedy tokens, a plain engine's do not.  (`is not
        # None`, not `or`: an EMPTY CompileCache is falsy via __len__, and
        # silently replacing a caller's fresh cache means every engine
        # recompiles privately and the shared cache never warms.)
        self.cache_compiles = (compile_cache if compile_cache is not None
                               else CompileCache())
        self._queue: "collections.deque[Request]" = collections.deque()
        # the resident slot cache (pure-KV slots are reset lazily — stale
        # rows hide behind true-length masking; stateful families are reset
        # at admission via insert_request)
        self.cache = api.init_cache(cfg, batch_size, max_len)
        self._slots = [_Slot() for _ in range(batch_size)]
        # paged-KV bookkeeping: host free list + page table (see module doc)
        self.paged = api.has_paged_kv(cfg)
        # batch-1 admission rows: their paged pool leaves are SKIPPED by
        # insert_request (axis -1), so build them from a minimal-pool cfg —
        # otherwise a stateful paged engine would hold a dead duplicate of
        # the whole serving pool for its lifetime
        self._row_cfg = (dataclasses.replace(cfg, kv_pool_blocks=1)
                         if self.paged else cfg)
        # pristine batch-1 row for stateful-family admission resets
        self._fresh_row = (api.init_cache(self._row_cfg, 1, max_len)
                           if api.needs_admission_insert(cfg) and
                           cfg.family != "audio" else None)
        if self.paged:
            from repro.models.attention import (paged_geometry,
                                                paged_pool_blocks)
            from repro.parallel import decode_attn
            from repro.parallel.hints import active_mesh
            self.block_size, self.n_pages = paged_geometry(cfg, max_len)
            self.pool_blocks = paged_pool_blocks(cfg, batch_size, max_len)
            self._null_block = self.pool_blocks      # last pool row
            # topology-aware allocation: when decode will run through the
            # sharded paged path (a mesh is active at construction), the
            # pool rows split into per-shard block homes and the allocator
            # leases round-robin across them — paged_homes is the ONE
            # function both this ctor and the dispatch gate derive from,
            # so host accounting and device routing cannot disagree
            self.n_homes = decode_attn.paged_homes(
                active_mesh(), batch_size, self.pool_blocks + 1,
                window=cfg.window)
            self.alloc = BlockAllocator(self.pool_blocks, self.n_homes)
            self._page_table = np.full((batch_size, self.n_pages),
                                       self._null_block, np.int32)
            self._slot_blocks: list[list[int]] = [[] for _ in
                                                  range(batch_size)]
            self._slot_reserve = [0] * batch_size    # worst-case not-yet-leased
            # per-home split of each slot's reservation (row sums equal
            # _slot_reserve): the deadlock-freedom invariant holds PER
            # home — sum over slots of _reserve_home[:, h] <= free blocks
            # in home h — so a row can always lease its next block from a
            # home it reserved in, whatever the other homes' pressure
            self._reserve_home = [[0] * self.n_homes
                                  for _ in range(batch_size)]
        # prefix sharing: radix cache over prompt tokens -> physical blocks.
        # Gated to paged transformer families: recurrent state (ssm/hybrid)
        # has no per-token block chain, and audio decoder K/V depends on the
        # request's encoder output through cross-attention, so token-prefix
        # equality does not imply K/V equality there.
        self.prefix_requested = prefix_cache
        self.prefix_sharing = bool(prefix_cache and self.paged and
                                   api.supports_prefix_cache(cfg))
        self.prefix = (RadixPrefixCache(self.block_size)
                       if self.prefix_sharing else None)
        self.prefix_hits = 0         # admissions that reused >= 1 block
        self.prefix_hit_tokens = 0   # prompt tokens covered without recompute
        self.cow_copies = 0          # copy-on-write block duplications
        self.prefix_evictions = 0    # cache leaves dropped under pool pressure
        self.peak_pool_blocks = 0    # high-water physical blocks in use
        self.admission_stalls = 0    # admissions held back by the block pool
        self.peak_resident_tokens = 0
        self.steps = 0
        self.dispatches = 0          # must equal steps: one dispatch per tick
        self.mixed_ticks = 0
        self._occupancy_sum = 0.0
        # -- resilience layer (lifecycle, preemption, fault isolation) -------
        # max_preemptions bounds how many times ONE request may be evicted
        # and requeued (0 disables preemption — the seed's stall-only
        # behavior); a request at the bound is immune, so progress is
        # guaranteed.  enforce_deadlines sweeps deadline_s requests (queued
        # or running) every tick; check_finite quarantines rows whose logits
        # go non-finite; audit_every=N self-checks allocator/page-table
        # invariants every N ticks; chaos is a serving.chaos.ChaosMonkey
        # injecting deterministic faults at exactly these seams.
        self.max_preemptions = max_preemptions
        self.enforce_deadlines = enforce_deadlines
        self.check_finite = check_finite
        self.audit_every = audit_every
        self.chaos = chaos
        self.preemptions = 0         # total preempt-and-requeue events
        self.deadline_misses = 0     # requests retired past their deadline
        self.row_faults = 0          # rows quarantined (NaN logits / hook)
        self.cancels = 0             # cancel() calls that found their target
        self.audits = 0              # audit() passes run (all green)
        self._admit_seq = 0          # monotonic admission counter (slot age)
        self._live_rids: set = set() # queued + running rids (duplicate gate)
        # -- durability layer (snapshots + write-ahead journal) --------------
        # clock is injectable so lifecycle tests exercise nonzero deadlines
        # deterministically and snapshots serialize deadlines as REMAINING
        # budget (a restored engine's clock has a different monotonic base)
        self.clock = clock
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        self.snapshot_keep = snapshot_keep
        self.journal_enabled = journal
        self.snapshots_taken = 0
        # terminal events replayed from the journal at restore (requests
        # that finished after the last snapshot in the killed process; the
        # caller's objects are gone, so restore surfaces them here)
        self.restored_terminal: list[Request] = []
        self._journal: Any = None
        self._snap_epoch = -1
        if snapshot_dir is not None:
            # baseline snapshot: restore ALWAYS has a complete snapshot to
            # start from, and the epoch's journal captures everything after
            from repro.serving import snapshot as _snaplib
            _snaplib.attach(self, snapshot_dir)

    # -- client API ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens} — a request always emits at least "
                "its first token")
        if len(req.prompt) > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} exceeds "
                f"engine max_len {self.max_len} — raise max_len or truncate")
        if self.paged and self._worst_case_blocks(req) > self.pool_blocks:
            raise ValueError(
                f"request {req.rid}: worst case needs "
                f"{self._worst_case_blocks(req)} KV blocks but the pool has "
                f"{self.pool_blocks} — raise kv_pool_blocks")
        if req.rid in self._live_rids:
            raise ValueError(
                f"request {req.rid}: rid already queued or in flight — "
                "rids must be unique among live requests")
        req.status = "queued"
        self._live_rids.add(req.rid)
        req.submitted_at = self.clock()
        self._queue.append(req)
        if self._journal is not None:
            self._journal.append({
                "ev": "submit", "rid": req.rid,
                "prompt": np.asarray(req.prompt).tolist(),
                "max_new": req.max_new_tokens, "priority": req.priority,
                "deadline": req.deadline_s,
                "frames": (None if req.frames is None
                           else np.asarray(req.frames).tolist())})
            # durable immediately: a submit outside run() must survive a
            # kill before the next tick-batch fsync
            self._journal.commit()

    def cancel(self, rid: int) -> bool:
        """Retire request ``rid`` wherever it is in the lifecycle: dequeued
        if still waiting, or freed mid-flight (slot + blocks released, the
        partial output stays on the request).  Terminal ``status`` becomes
        ``"cancelled"``.  Returns False when no live request has that rid
        (already finished, or never submitted) — cancel is idempotent.
        The request is retired HERE, not echoed through a later ``run()``
        result: the caller already holds the object."""
        for r in self._queue:
            if r.rid == rid:
                self._queue.remove(r)
                self.cancels += 1
                self._terminal(r, "cancelled")
                if self._journal is not None:
                    self._journal.commit()
                return True
        for i, s in enumerate(self._slots):
            if s.req is not None and s.req.rid == rid:
                self.cancels += 1
                self._terminal(s.req, "cancelled")
                self._free_slot(i)
                if self._journal is not None:
                    self._journal.commit()
                return True
        return False

    def snapshot(self) -> str:
        """Write a point-in-time snapshot to ``snapshot_dir`` (atomic: temp
        dir + ``os.replace``) and rotate the write-ahead journal to a fresh
        epoch.  Returns the snapshot directory.  See ``serving/snapshot.py``
        for the durability contract."""
        if self.snapshot_dir is None:
            raise RuntimeError("engine has no snapshot_dir")
        from repro.serving import snapshot as _snaplib
        return _snaplib.save(self)

    @classmethod
    def restore(cls, snapshot_dir: str, params: Any,
                **overrides) -> "Engine":
        """Rebuild a process-equivalent engine from the latest complete
        snapshot under ``snapshot_dir``, replaying the journal of everything
        that happened after it.  Restored token streams are bitwise equal to
        the never-killed engine's (and so to ``reference_decode``).
        ``overrides`` replace constructor kwargs (e.g. a fresh ``chaos``
        monkey or a shared ``compile_cache``)."""
        from repro.serving import snapshot as _snaplib
        return _snaplib.restore_engine(snapshot_dir, params, **overrides)

    def durability_stats(self) -> dict[str, Any]:
        """Snapshot/journal counters for launch stats lines."""
        return {
            "snapshot_dir": self.snapshot_dir,
            "snapshot_every": self.snapshot_every,
            "snapshots_taken": self.snapshots_taken,
            "epoch": self._snap_epoch,
            "journal": self._journal is not None,
            "restored_terminal": len(self.restored_terminal),
        }

    @property
    def compile_budget(self) -> int:
        """Upper bound on compile-cache misses this engine can cause:
        n_chunk_buckets (mixed widths) + decode + insert.  Audio adds one
        ``("admit", F)`` encoder executable per DISTINCT frame count seen —
        traffic-dependent, so it is counted from the cache, keeping
        ``misses <= compile_budget`` an invariant for any workload.  Prefix
        sharing adds exactly one executable — the ``("cow", 0)`` block
        copy — regardless of traffic."""
        extra = sum(1 for name, _ in self.cache_compiles.keys()
                    if name == "admit")
        return (len(self.chunk_buckets.all_buckets()) + 2 + extra +
                (1 if self.prefix_sharing else 0))

    # -- executables (all memoized: misses bounded by compile_budget) --------

    def _build_mixed(self):
        if self.spec_k:
            return _mixed_executable_spec(self.cfg, self.paged)
        return (_mixed_executable_paged(self.cfg) if self.paged
                else _mixed_executable(self.cfg))

    def _build_decode(self):
        return (_decode_executable_paged(self.cfg) if self.paged
                else _decode_executable(self.cfg))

    def _build_insert(self):
        return _insert_executable(self.cfg)

    def _build_cow(self):
        # one shape for every copy-on-write: (cache, src, dst) with traced
        # scalar block ids, donated cache — memoized under ("cow", 0)
        cfg = self.cfg
        return jax.jit(
            lambda c, s, d: api.copy_pool_block(cfg, c, s, d),
            donate_argnums=(0,))

    # -- paged-KV block accounting -------------------------------------------

    @property
    def _free_blocks(self) -> list[int]:
        """The allocator's free list (kept as the PR 5 attribute name: tests
        and tools introspect it for leak checks)."""
        return self.alloc.free

    def _worst_case_blocks(self, req: Request) -> int:
        """Blocks the request can ever hold: its prompt plus its REMAINING
        generation (a preempted request's accepted output is folded into the
        prompt, so only ``max_new_tokens - len(output)`` tokens are still
        owed — but at least one: re-prefill always emits a token), capped by
        the cache's addressable span (the ``_emit`` stop rules)."""
        owed = max(req.max_new_tokens - len(req.output), 1)
        toks = min(len(req.prompt) + owed, self.max_len)
        return -(-toks // self.block_size)

    def _prefix_plan(self, req: Request) -> _PrefixPlan | None:
        """Match the prompt against the radix cache and plan the admission.

        ``consumed`` is capped at ``len(prompt) - 1``: the final prompt token
        must always run through a chunk to produce the first-token logits.
        When the cache covers the WHOLE prompt, the last matched block is
        demoted from shared to CoW source so that token has a writable page.
        """
        if self.prefix is None or len(req.prompt) < 2:
            return None
        full, partial = self.prefix.match(req.prompt)
        consumed = len(full) * self.block_size
        cow = None
        if partial is not None:
            blk, n = partial
            n = min(n, len(req.prompt) - 1 - consumed)
            if n > 0:
                cow = blk
                consumed += n
        elif consumed >= len(req.prompt):
            cow = full.pop()
            consumed = len(req.prompt) - 1
        if not full and cow is None:
            return None
        return _PrefixPlan(shared=full, cow=cow, consumed=consumed)

    def _evict_for(self, n: int, plan: _PrefixPlan | None) -> int:
        """Free up to ``n`` blocks by dropping cold radix-cache leaves
        (LRU-first).  Only blocks the cache SOLELY holds actually free —
        shared residents (refcount >= 2) and the current plan's blocks are
        skipped, so cache pressure can never invalidate a live mapping or
        the admission plan just computed.  Returns the blocks freed."""
        protect = set()
        if plan is not None:
            protect.update(plan.shared)
            if plan.cow is not None:
                protect.add(plan.cow)
        freed = 0
        while freed < n:
            blk = self.prefix.evict_lru(
                keep=lambda b: b in protect or self.alloc.ref(b) > 1)
            if blk is None:
                break                       # nothing evictable left
            self.prefix_evictions += 1
            if not self.alloc.decref(blk):  # cache was sole holder: frees
                raise RuntimeError(
                    f"evicted cache block {blk} still live — keep() gate "
                    "is wrong")
            freed += 1
        return freed

    def _reserved_by_home(self) -> list[int]:
        """Outstanding reservations per block home, summed over slots."""
        totals = [0] * self.n_homes
        for vec in self._reserve_home:
            for h, v in enumerate(vec):
                totals[h] += v
        return totals

    def _plan_reserve(self, need: int) -> list[int] | None:
        """Distribute a worst-case reservation of ``need`` blocks across
        block homes by remaining headroom (free minus already reserved,
        per home), so leases spread round-robin over the mesh and the
        deadlock-freedom invariant holds home by home.  Returns the
        per-home vector, or None when the pool cannot cover it.  With one
        home this degenerates to the PR 5 total check."""
        free_h = self.alloc.free_by_home()
        res_h = self._reserved_by_home()
        head = [f - r for f, r in zip(free_h, res_h)]
        if sum(h for h in head if h > 0) < need:
            return None
        vec = [0] * self.n_homes
        for _ in range(need):
            h = max(range(self.n_homes), key=lambda j: (head[j], -j))
            vec[h] += 1
            head[h] -= 1
        return vec

    def _can_reserve(self, req: Request,
                     plan: _PrefixPlan | None = None) -> bool:
        """Admission gate: unreserved free blocks must cover the request's
        worst case — per block HOME, not just in total.  Every admitted row
        can then ALWAYS lease its next block from a home it reserved in
        (``sum(reserve) <= free`` holds per home), so decode never stalls
        and the pool never deadlocks — pressure shows up as admission
        stalls, never as a stuck batch.  A prefix-cache hit shrinks the need
        by its shared blocks (the CoW page leases normally, inside the
        reservation); on a shortfall, cold cache leaves are evicted one at
        a time until the per-home plan closes (or nothing is evictable)."""
        need = self._worst_case_blocks(req)
        if plan is not None:
            need -= len(plan.shared)
        vec = self._plan_reserve(need)
        while vec is None and self.prefix is not None:
            if self._evict_for(1, plan) == 0:
                break                       # nothing evictable left
            vec = self._plan_reserve(need)
        return vec is not None

    def _lease_for_slot(self, idx: int) -> int:
        """Lease one block against slot ``idx``'s reservation, consuming
        the home with the most remaining reserved blocks (ties to the
        lowest home) — the per-home invariant guarantees that home has a
        free block, so the lease cannot fail."""
        vec = self._reserve_home[idx]
        homes = [h for h in range(self.n_homes) if vec[h] > 0]
        if not homes:
            raise RuntimeError(
                f"slot {idx} leased past its reservation — worst-case "
                "accounting is wrong")
        h = max(homes, key=lambda j: (vec[j], -j))
        try:
            blk = self.alloc.lease(home=h)
        except RuntimeError as e:
            raise RuntimeError(
                f"{e} despite a reservation there — per-home accounting "
                "is wrong") from None
        vec[h] -= 1
        self._slot_reserve[idx] -= 1
        return blk

    def _lease_to(self, idx: int, new_len: int) -> None:
        """Grow slot ``idx`` to cover ``new_len`` tokens, leasing blocks as
        the length crosses page boundaries (on-demand allocation)."""
        need = -(-new_len // self.block_size)
        owned = self._slot_blocks[idx]
        while len(owned) < need:
            blk = self._lease_for_slot(idx)
            self._page_table[idx, len(owned)] = blk
            owned.append(blk)

    def pool_stats(self) -> dict[str, int]:
        """Free-list invariants, exposed for leak/double-free checks.

        ``leased`` counts LIVE physical blocks (refcount >= 1), so ``free +
        leased == total`` stays the partition invariant under sharing —
        a block mapped by three slots and the cache is still ONE block."""
        return {
            "total": self.pool_blocks,
            "free": self.alloc.n_free,
            "leased": self.alloc.n_live,
            "n_homes": self.n_homes,
            "reserved_outstanding": sum(self._slot_reserve),
            "shared_blocks": self.alloc.n_shared(),
            "cached_blocks": (len(self.prefix)
                              if self.prefix is not None else 0),
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "cow_copies": self.cow_copies,
            "prefix_evictions": self.prefix_evictions,
        }

    def prefix_stats(self) -> dict[str, int]:
        """Prefix-cache counters (subset of ``pool_stats`` plus the gate)."""
        return {
            "enabled": self.prefix_sharing,
            "requested": self.prefix_requested,
            "hits": self.prefix_hits,
            "hit_tokens": self.prefix_hit_tokens,
            "cow_copies": self.cow_copies,
            "evictions": self.prefix_evictions,
            "cached_blocks": (len(self.prefix)
                              if self.prefix is not None else 0),
            "shared_blocks": self.alloc.n_shared() if self.paged else 0,
        }

    def drop_prefix_cache(self) -> int:
        """Flush the radix cache, releasing every cache-held block reference
        (cold-workload reset; also how leak checks prove the cache holds
        exactly one reference per node).  Returns the nodes dropped."""
        if self.prefix is None:
            return 0
        blocks = self.prefix.clear()
        for blk in blocks:
            self.alloc.decref(blk)
        return len(blocks)

    # -- internals -----------------------------------------------------------

    def _terminal(self, req: Request, status: str,
                  completed: list[Request] | None = None) -> None:
        """Move ``req`` into terminal state ``status`` — the single exit
        point of the lifecycle state machine, so every path (done, error,
        cancelled, deadline_missed) stamps ``finished_at`` and releases the
        rid for reuse exactly once."""
        assert status in TERMINAL_STATES, status
        req.status = status
        req.done = True
        req.finished_at = self.clock()
        self._live_rids.discard(req.rid)
        if self._journal is not None:
            self._journal.append({"ev": "terminal", "rid": req.rid,
                                  "status": status, "error": req.error})
        if completed is not None:
            completed.append(req)

    def _finish(self, req: Request, completed: list[Request]) -> None:
        self._terminal(req, "done", completed)

    def _free_slot(self, idx: int) -> None:
        """Retire a row: release the host lease.  Device eviction is lazy —
        pure-KV rows hide behind true-length masking and stateful rows are
        reset by the next admission's ``insert_request`` — so retirement
        costs no device dispatch.  The dead row rides along in later ticks
        at q_len 0 / its parked length; its output is ignored.  Paged: the
        row's block references are DROPPED — a block returns to the free
        list only when no other slot (and not the radix cache) still maps
        it — and its page-table row is pointed at the null block, so a
        stale lease can never alias a block the next occupant is handed."""
        if self.paged:
            for blk in self._slot_blocks[idx]:
                try:
                    self.alloc.decref(blk)
                except RuntimeError as e:
                    raise RuntimeError(f"{e} (slot {idx})") from None
            self._slot_blocks[idx] = []
            self._slot_reserve[idx] = 0
            self._reserve_home[idx] = [0] * self.n_homes
            self._page_table[idx, :] = self._null_block
        if self.drafter is not None:
            self.drafter.reset(idx)
        self._slots[idx] = _Slot()

    def _rewind_slot(self, idx: int, new_len: int) -> None:
        """Rollback primitive: shrink slot ``idx``'s valid length to
        ``new_len`` (rejected speculative tokens).  Host-side only — stale
        K/V past ``new_len`` hides behind true-length masking and the next
        writes land over it.  Paged: tail blocks wholly past the new length
        are re-nulled in the page table and returned to the free list, and
        the blocks go BACK into the slot's worst-case reservation (it may
        legitimately lease them again), so ``sum(reserve) <= free`` and
        free+leased accounting stay invariant.  Under prefix sharing a
        rewound tail block is always PRIVATE (shared blocks cover at most
        ``len(prompt) - 1`` tokens and speculation only rewinds past the
        prompt), so the decref here really frees — but refcounts make even
        an artificial shared rewind safe."""
        slot = self._slots[idx]
        if new_len > self.max_len:
            raise ValueError(f"rewind to {new_len} exceeds max_len")
        slot.length = new_len
        if self.paged:
            from repro.models.attention import paged_blocks_for
            keep = paged_blocks_for(new_len, self.block_size)
            owned = self._slot_blocks[idx]
            while len(owned) > keep:
                blk = owned.pop()
                self._page_table[idx, len(owned)] = self._null_block
                try:
                    self.alloc.decref(blk)
                except RuntimeError as e:
                    raise RuntimeError(f"{e} (rewind slot {idx})") from None
                # the freed block physically returns to ITS home's free
                # list, so crediting the reservation to that same home
                # preserves the per-home invariant exactly
                self._slot_reserve[idx] += 1
                self._reserve_home[idx][self.alloc.home(blk)] += 1

    # -- resilience: quarantine, deadlines, preemption ----------------------

    def _fault_row(self, idx: int, msg: str,
                   completed: list[Request]) -> None:
        """Quarantine exactly one row: the request finishes with
        ``status="error"`` (partial output kept, ``error`` says why), its
        slot and blocks are released through the normal ``_free_slot``
        path, and every other row's tick proceeds untouched — a bad row
        never propagates out of the batch."""
        req = self._slots[idx].req
        req.error = msg
        self.row_faults += 1
        self._terminal(req, "error", completed)
        self._free_slot(idx)

    def _safe_sample(self, idx: int, sample: Callable,
                     logits_np: np.ndarray,
                     completed: list[Request]) -> int | None:
        """Run the user's ``sample`` hook for one row, quarantining the row
        (not the tick) if the hook throws.  Returns None when faulted."""
        try:
            return int(sample(logits_np[idx]))
        except Exception as e:  # noqa: BLE001 — hook code is untrusted
            self._fault_row(idx, f"sample hook raised: {e!r}", completed)
            return None

    def _sweep_deadlines(self, completed: list[Request]) -> None:
        """Retire every live request whose deadline has passed — queued
        (never admitted) or mid-flight (slot freed, partial output kept).
        ``deadline_s`` is measured from ``submitted_at``; ``>=`` makes
        ``deadline_s=0.0`` miss deterministically at the first sweep."""
        if not self.enforce_deadlines:
            return
        now = self.clock()

        def missed(r: Request) -> bool:
            return (r.deadline_s is not None and
                    now - r.submitted_at >= r.deadline_s)

        for i, s in enumerate(self._slots):
            if s.req is not None and missed(s.req):
                self.deadline_misses += 1
                self._terminal(s.req, "deadline_missed", completed)
                self._free_slot(i)
        if any(missed(r) for r in self._queue):
            keep: collections.deque = collections.deque()
            for r in self._queue:
                if missed(r):
                    self.deadline_misses += 1
                    self._terminal(r, "deadline_missed", completed)
                else:
                    keep.append(r)
            self._queue = keep

    def _pick_victim(self, max_priority: int | None = None, *,
                     strict: bool = False) -> int | None:
        """Choose the slot to preempt: lowest priority first, youngest
        (largest admission ``seq``) within a priority.  Requests at their
        preemption bound are immune (progress guarantee).  ``strict``
        requires the victim's priority be LOWER than ``max_priority``
        (priority preemption for a full batch); non-strict allows equal
        (shortfall preemption — the FIFO head outranks a peer that has
        already had its turn)."""
        best: int | None = None
        best_key: tuple[int, int] | None = None
        for i, s in enumerate(self._slots):
            r = s.req
            if r is None or r.preemptions >= self.max_preemptions:
                continue
            if max_priority is not None:
                if strict and r.priority >= max_priority:
                    continue
                if not strict and r.priority > max_priority:
                    continue
            key = (r.priority, -s.seq)
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    def _fold_slot(self, idx: int) -> None:
        """The lossless fold primitive shared by preemption and snapshot
        restore: donate the slot's fully written resident blocks to the
        radix cache (prompt AND accepted output — so re-admission is mostly
        a page-table copy via ``_prefix_plan``), then fold the accepted
        output into the prompt.  The folded run's token stream is bitwise
        the never-folded one, since emit-time lengths realign exactly."""
        slot = self._slots[idx]
        req = slot.req
        if self.prefix is not None and slot.length >= self.block_size:
            # prompt already holds output[:folded] from earlier folds —
            # resident tokens are prompt + the output emitted SINCE
            resident = np.concatenate([
                np.asarray(req.prompt, np.int64),
                np.asarray(req.output[req.folded:], np.int64)])[:slot.length]
            nfull = slot.length // self.block_size
            fresh = self.prefix.insert(resident[:nfull * self.block_size],
                                       self._slot_blocks[idx][:nfull])
            for blk in fresh:
                self.alloc.incref(blk)
        if len(req.output) > req.folded:
            # fold only the output NOT already folded by an earlier
            # preemption — re-folding would duplicate tokens in the prompt
            req.prompt = np.concatenate([
                np.asarray(req.prompt, np.int64),
                np.asarray(req.output[req.folded:], np.int64)])
            req.folded = len(req.output)

    def _preempt(self, idx: int, *, requeue_front: bool = False) -> None:
        """Evict a running request, keeping its work: ``_fold_slot`` donates
        its blocks and folds accepted output into the prompt (re-admission
        recomputes nothing semantically).  Requeued behind the current head
        by default — the head caused the preemption and must win the freed
        space — or at the front for a forced (chaos) preemption with no
        waiting head."""
        req = self._slots[idx].req
        self._fold_slot(idx)
        req.preemptions += 1
        req.status = "queued"
        self.preemptions += 1
        self._free_slot(idx)
        if requeue_front or not self._queue:
            self._queue.appendleft(req)
        else:
            # behind the head that evicted it AND behind every waiter that
            # outranks it — a preempted hog must not become the new head
            # and block the higher-priority queue it was evicted for
            pos = 1
            while (pos < len(self._queue) and
                   self._queue[pos].priority > req.priority):
                pos += 1
            self._queue.insert(pos, req)

    def _admit_head(self, idx: int) -> bool:
        """Try to admit the queue head into free slot ``idx``.  On a paged
        reservation shortfall (after ``_can_reserve`` already ran LRU
        prefix eviction), preempt victims one at a time — youngest/lowest
        priority, never outranking the head — re-planning after each, until
        the head fits or no victim remains (admission stall)."""
        head = self._queue[0]
        plan = self._prefix_plan(head)
        if self.paged:
            if self.chaos is not None and self.chaos.deny_reservation():
                self.admission_stalls += 1
                return False
            while not self._can_reserve(head, plan):
                v = self._pick_victim(head.priority, strict=False)
                if v is None:
                    self.admission_stalls += 1
                    return False
                self._preempt(v)
                plan = self._prefix_plan(head)
        self._admit(self._queue.popleft(), idx, plan)
        return True

    def audit(self) -> None:
        """One-shot invariant audit (the ``audit_every`` knob runs it each
        N ticks).  Raises AssertionError on the first violation: allocator
        refcount/partition (``BlockAllocator.check``), deadlock-freedom
        (``sum(reserve) <= free``), page-table rows exactly mirror the
        slots' owned live blocks with a null tail, dead slots own nothing,
        cache-held blocks are live, and every running rid is tracked."""
        self.audits += 1
        if self.paged:
            self.alloc.check()
            reserved = sum(self._slot_reserve)
            assert reserved <= self.alloc.n_free, (
                f"reservation invariant broken: {reserved} reserved > "
                f"{self.alloc.n_free} free")
            # per-home deadlock freedom + reservation-vector coherence
            assert self.alloc.n_homes == self.n_homes, (
                "allocator homes diverged from the engine topology")
            free_h = self.alloc.free_by_home()
            for h, r in enumerate(self._reserved_by_home()):
                assert r <= free_h[h], (
                    f"home {h}: {r} reserved > {free_h[h]} free — per-home "
                    "deadlock-freedom broken")
            for i, vec in enumerate(self._reserve_home):
                assert all(v >= 0 for v in vec) and \
                    sum(vec) == self._slot_reserve[i], (
                    f"slot {i} home-reservation vector {vec} != total "
                    f"{self._slot_reserve[i]}")
            for i, s in enumerate(self._slots):
                owned = self._slot_blocks[i]
                if s.req is None:
                    assert not owned and not self._slot_reserve[i], (
                        f"dead slot {i} owns blocks/reservation")
                row = self._page_table[i]
                assert list(row[:len(owned)]) == owned, (
                    f"slot {i} page table != owned blocks")
                assert all(b == self._null_block
                           for b in row[len(owned):]), (
                    f"slot {i} page table has stale tail entries")
                for blk in owned:
                    assert self.alloc.ref(blk) >= 1, (
                        f"slot {i} maps freed block {blk}")
                    # every leased block resolves to (shard, local block)
                    # consistently with its page-table entry: the id is a
                    # real pool block (never the null row) and its home's
                    # local translation stays inside the home's rows
                    assert 0 <= blk < self.pool_blocks, (
                        f"slot {i} maps out-of-pool block {blk}")
                    home = self.alloc.home(blk)
                    local = blk - home * self.alloc.rows_per_home
                    assert (0 <= home < self.n_homes and
                            0 <= local < self.alloc.rows_per_home), (
                        f"block {blk} resolves outside home partition")
            if self.prefix is not None:
                for blk in self.prefix.blocks():
                    assert self.alloc.ref(blk) >= 1, (
                        f"radix cache holds freed block {blk}")
        for i, s in enumerate(self._slots):
            if s.req is not None:
                assert s.length <= self.max_len, f"slot {i} overran max_len"
                assert s.req.rid in self._live_rids, (
                    f"running rid {s.req.rid} untracked")

    def resilience_stats(self) -> dict[str, Any]:
        """Lifecycle/fault counters (chaos injection stats ride along when
        a monkey is attached)."""
        out: dict[str, Any] = {
            "preemptions": self.preemptions,
            "max_preemptions": self.max_preemptions,
            "deadline_misses": self.deadline_misses,
            "row_faults": self.row_faults,
            "cancels": self.cancels,
            "audits": self.audits,
            "enforce_deadlines": self.enforce_deadlines,
            "check_finite": self.check_finite,
        }
        if self.chaos is not None:
            out["chaos"] = self.chaos.stats()
        return out

    def _cow_block(self, idx: int, src: int) -> None:
        """Copy-on-write: lease a private block for slot ``idx``'s next page
        and duplicate shared block ``src`` into it on device.  The matched
        head of the copy is live (bit-identical K/V); its stale tail sits
        past the slot's length until the normal chunk writer overwrites it.
        The lease consumes the slot's reservation like any other, so the
        "+1 CoW block" is already inside the admission accounting."""
        page = len(self._slot_blocks[idx])
        dst = self._lease_for_slot(idx)
        self._page_table[idx, page] = dst
        self._slot_blocks[idx].append(dst)
        fn = self.cache_compiles.get("cow", 0, self._build_cow)
        self.cache = fn(self.cache, np.int32(src), np.int32(dst))
        self.cow_copies += 1

    def _cache_prompt(self, idx: int) -> None:
        """Prefill just finished for slot ``idx``: donate the prompt's fully
        written blocks to the radix cache.  The cache holds ONE reference
        per node it newly created; dedup (a concurrent identical prompt)
        keeps the first author's block and the duplicate stays private."""
        prompt = self._slots[idx].req.prompt
        nfull = len(prompt) // self.block_size
        if nfull == 0:
            return
        fresh = self.prefix.insert(np.asarray(prompt)[:nfull *
                                                      self.block_size],
                                   self._slot_blocks[idx][:nfull])
        for blk in fresh:
            self.alloc.incref(blk)

    def _admit(self, req: Request, idx: int,
               plan: _PrefixPlan | None = None) -> None:
        """Lease slot ``idx`` to ``req``.  No prefill dispatch happens here:
        the prompt streams through subsequent mixed ticks.  Stateful
        families scatter a fresh ``request_cache`` row into the slot first
        (recurrent-state reset; audio also carries the request's cross-KV).
        A prefix-cache ``plan`` maps the shared blocks into the page table
        (incref each), optionally CoW-copies one partial block, and starts
        the chunk cursor at the first uncovered prompt token."""
        if self.paged:
            shared = list(plan.shared) if plan is not None else []
            need = self._worst_case_blocks(req) - len(shared)
            vec = self._plan_reserve(need)
            if vec is None:   # _can_reserve just planned this very need
                raise RuntimeError(
                    "admission without a coverable reservation — "
                    "_can_reserve gate bypassed")
            self._slot_reserve[idx] = need
            self._reserve_home[idx] = vec
            for page, blk in enumerate(shared):
                self.alloc.incref(blk)
                self._page_table[idx, page] = blk
            if shared:
                self._slot_blocks[idx] = shared
        if api.needs_admission_insert(self.cfg):
            if self.cfg.family == "audio":
                f = np.asarray(req.frames)
                frames = jnp.asarray(f[None] if f.ndim == 2 else f)
                admit = self.cache_compiles.get(
                    "admit", frames.shape[1],
                    lambda: _admit_executable(self._row_cfg, self.max_len))
                row = admit(self.params, frames)
            else:
                row = self._fresh_row
            insert = self.cache_compiles.get("insert", self.batch,
                                             self._build_insert)
            self.cache = insert(self.cache, row, np.int32(idx))
        self._admit_seq += 1
        req.status = "running"
        self._slots[idx] = _Slot(req=req, seq=self._admit_seq)
        if plan is not None:
            if plan.cow is not None:
                self._cow_block(idx, plan.cow)
            s = self._slots[idx]
            s.length = s.pos = plan.consumed
            self.prefix_hits += 1
            self.prefix_hit_tokens += plan.consumed
        self._draft_wait[idx] = self._draft_penalty[idx] = 0
        if self.drafter is not None:
            # seed the drafter with the full prompt (prompt-lookup proper):
            # drafts may copy prompt spans before the prompt finishes
            # streaming through the cache — acceptance keeps it lossless
            self.drafter.reset(idx)
            self.drafter.observe(idx, req.prompt)

    def _schedule_drafts(self, chunks: list[int], decoding: list[int],
                         sample) -> dict[int, list[int]]:
        """Pick this tick's verify rows: up to ``spec_k`` draft tokens per
        decode row from the drafter, each capped by (1) cache room past the
        row's mandatory real token, (2) the request's remaining token need
        minus one — which also keeps the tick's writes inside the paged
        worst-case reservation (``len(prompt) + max_new_tokens`` total) —
        and (3) the shared prefill token budget: chunks are scheduled
        first, verify tokens consume what remains.  A ``sample`` hook
        disables drafting for the tick (acceptance is defined against the
        model's greedy tokens)."""
        if not self.spec_k or sample is not None:
            return {}
        left = None
        if self.prefill_token_budget is not None:
            left = max(self.prefill_token_budget - sum(chunks), 0)
        drafts: dict[int, list[int]] = {}
        for i in decoding:
            if self._draft_wait[i] > 0:          # backing off after misses
                self._draft_wait[i] -= 1
                continue
            s = self._slots[i]
            k = min(self.spec_k,
                    self.max_len - s.length - 1,
                    s.req.max_new_tokens - len(s.req.output) - 1)
            if left is not None:
                k = min(k, left)
            if k <= 0:
                continue
            d = self.drafter.draft(i, k)
            if d:
                drafts[i] = d
                if left is not None:
                    left -= len(d)
        return drafts

    def _schedule_chunks(self) -> list[int]:
        """Pick this tick's per-slot prompt-chunk sizes (Sarathi-style).

        Returns q_lens for mid-prefill rows only (0 elsewhere).  The
        "mixed" policy advances every mid-prefill row, subject to the
        token budget (FIFO by slot, at least one row always advances);
        the "stall" policy advances only the oldest mid-prefill row —
        the seed's head-of-line-blocking admission, kept as the
        serving_bench baseline.
        """
        chunks = [0] * self.batch
        budget = self.prefill_token_budget
        picked = 0
        for i, s in enumerate(self._slots):
            if not s.prefilling:
                continue
            want = min(self.chunk_size, len(s.req.prompt) - s.pos)
            if picked and budget is not None:
                want = min(want, max(budget, 0))
            if picked and self.prefill_policy == "stall":
                want = 0
            if want <= 0:
                continue
            chunks[i] = want
            picked += 1
            if budget is not None:
                budget -= want
        return chunks

    def _emit(self, idx: int, token: int, completed: list[Request],
              first: bool) -> None:
        """Record one generated token; finish/free the slot when done."""
        slot = self._slots[idx]
        req = slot.req
        now = self.clock()
        if first and req.first_token_at is None:
            # a preempted request keeps its ORIGINAL first-token time: the
            # re-prefill's "first" token is really a later output token
            req.first_token_at = now
        req.output.append(token)
        req.token_times.append(now)
        if self._journal is not None:
            self._journal.append({"ev": "emit", "rid": req.rid,
                                  "tok": int(token)})
        slot.last_token = token
        if self.drafter is not None:
            self.drafter.observe(idx, (token,))
        if (len(req.output) >= req.max_new_tokens or
                slot.length >= self.max_len or  # no cache room to decode into
                (self.eos_id is not None and token == self.eos_id)):
            self._finish(req, completed)
            self._free_slot(idx)

    def run(self, *, max_steps: int = 10_000,
            sample: Callable | None = None) -> "RunResult":
        """Drain the queue; returns a ``RunResult`` (a list of the requests
        that reached a terminal state this call — done, error, cancelled,
        deadline_missed — plus truncation/stall flags).

        Each tick: (0) sweep deadlines and apply chaos, (1) refill free
        slots from the queue (a host-side lease — no prefill dispatch),
        preempting bounded victims on a reservation shortfall or for a
        higher-priority head, (2) co-schedule prompt chunks with decode
        rows, (3) advance ALL slots with exactly one jitted call —
        ``mixed_step`` when any prompt chunk is in flight, the classic
        ``decode_step`` otherwise — then quarantine any faulted row and
        consume the rest.  ``sample`` maps a logits row (V,) to a token id;
        greedy argmax (computed on device) when None.
        """
        completed: list[Request] = []
        start_steps = self.steps       # max_steps bounds THIS call, not the
        stalled = False                # engine's lifetime
        idle = 0                       # consecutive no-row no-admission ticks
        while self.steps - start_steps < max_steps:
            # 0. chaos process death fires at the TOP of the tick, after the
            # previous tick's journal batch was fsync'd — so a kill can lose
            # at most un-dispatched work, never an emitted token (getattr:
            # older monkeys/test doubles predate the kill seam)
            if self.chaos is not None:
                kill = getattr(self.chaos, "maybe_kill", None)
                if kill is not None:
                    kill()
            # lifecycle sweeps: expired deadlines retire first (queued
            # or mid-flight), then chaos may force-preempt a running row
            self._sweep_deadlines(completed)
            if self.chaos is not None and self.max_preemptions:
                eligible = [i for i, s in enumerate(self._slots)
                            if s.req is not None and
                            s.req.preemptions < self.max_preemptions]
                v = self.chaos.forced_preempt(eligible)
                if v is not None:
                    self._preempt(v, requeue_front=True)
            # 1. continuous refill: admit queued requests into free slots.
            # Paged: strict-FIFO admission gated on the worst-case block
            # reservation — shortfalls preempt the youngest/lowest-priority
            # bounded victim (when allowed), else stall the head
            for i in range(self.batch):
                if self._slots[i].req is None and self._queue:
                    if not self._admit_head(i):
                        break
            # priority preemption: a waiting head that OUTRANKS a running
            # request does not sit behind it just because the batch is full
            while (self._queue and self.max_preemptions and
                   all(s.req is not None for s in self._slots)):
                v = self._pick_victim(self._queue[0].priority, strict=True)
                if v is None:
                    break
                self._preempt(v)
                if not self._admit_head(v):
                    break
            live = [i for i, s in enumerate(self._slots) if s.req is not None]
            if not live:
                stalled = bool(self._queue)
                if not stalled:
                    break          # queue drained and no row in flight
                # work is queued but nothing runs.  Without chaos this is
                # permanent (submit bounds worst case by the pool, and with
                # no live rows cache eviction can always free the rest) —
                # under injection a denial is transient, so retry, bounded
                # by max_steps idle ticks
                idle += 1
                if self.chaos is None or idle >= max_steps:
                    break
                continue
            idle = 0
            chunks = self._schedule_chunks()
            stall = (self.prefill_policy == "stall" and any(chunks))
            decoding = [i for i in live
                        if not self._slots[i].prefilling and not stall]
            drafts = self._schedule_drafts(chunks, decoding, sample)
            if self.chaos is not None and drafts:
                # garbage drafts: same length (leases are sized by it), but
                # possibly nonsense tokens — verify must reject losslessly
                drafts = {i: self.chaos.garble_draft(d, self.cfg.vocab_size)
                          for i, d in drafts.items()}
            if self.paged:
                # on-demand leases for every row advancing this tick (the
                # admission reservation guarantees these succeed — verify
                # rows stay inside it via the drafts' remaining-need cap)
                for i, s in enumerate(self._slots):
                    if chunks[i]:
                        self._lease_to(i, s.length + chunks[i])
                    elif i in decoding:
                        self._lease_to(
                            i, s.length + 1 + len(drafts.get(i, ())))
                page_table = jnp.asarray(self._page_table)

            greedy_np = None
            if any(chunks) or drafts:
                # 2a. mixed tick: prompt chunks + decode + verify rows,
                # one dispatch
                wide = max([max(chunks), 2] +
                           [1 + len(d) for d in drafts.values()])
                w = self.chunk_buckets.bucket(wide)
                tokens = np.zeros((self.batch, w), np.int32)
                lengths = np.zeros(self.batch, np.int32)
                q_lens = np.zeros(self.batch, np.int32)
                for i, s in enumerate(self._slots):
                    lengths[i] = s.length
                    if chunks[i]:
                        q_lens[i] = chunks[i]
                        tokens[i, :chunks[i]] = \
                            s.req.prompt[s.pos:s.pos + chunks[i]]
                    elif i in decoding:
                        d = drafts.get(i, ())
                        q_lens[i] = 1 + len(d)
                        tokens[i, 0] = s.last_token
                        if d:
                            tokens[i, 1:1 + len(d)] = d
                fn = self.cache_compiles.get("mixed", w, self._build_mixed)
                args = (jnp.asarray(tokens), jnp.asarray(lengths),
                        jnp.asarray(q_lens))
                if self.paged:
                    args += (page_table,)
                if self.spec_k:
                    next_tok, logits, self.cache, greedy = fn(
                        self.params, self.cache, *args)
                    if drafts:
                        greedy_np = np.asarray(greedy)
                        self.spec_ticks += 1
                        self.spec_rows += len(drafts)
                        self.spec_drafted += sum(
                            len(d) for d in drafts.values())
                else:
                    next_tok, logits, self.cache = fn(
                        self.params, self.cache, *args)
                self.mixed_ticks += 1
            else:
                # 2b. pure-decode tick: the classic executable (bit-identical
                # to the batch-1 oracle; dead rows ride along, output ignored)
                tokens = np.fromiter((s.last_token for s in self._slots),
                                     np.int32, self.batch).reshape(-1, 1)
                lengths = np.fromiter(
                    (s.length + 1 if i in decoding else max(s.length, 1)
                     for i, s in enumerate(self._slots)),
                    np.int32, self.batch)
                fn = self.cache_compiles.get("decode", self.batch,
                                             self._build_decode)
                args = (jnp.asarray(tokens), jnp.asarray(lengths))
                if self.paged:
                    adv = np.zeros(self.batch, bool)
                    adv[decoding] = True
                    args += (page_table, jnp.asarray(adv))
                next_tok, logits, self.cache = fn(
                    self.params, self.cache, *args)

            self.steps += 1
            self.dispatches += 1
            self._occupancy_sum += len(live) / self.batch
            if self.paged:
                self.peak_pool_blocks = max(
                    self.peak_pool_blocks,
                    self.pool_blocks - self.alloc.n_free)
            self.peak_resident_tokens = max(
                self.peak_resident_tokens,
                sum(self._slots[i].length + chunks[i] + (i in decoding) +
                    len(drafts.get(i, ()))
                    for i in live))
            next_np = np.asarray(next_tok)
            logits_np = None
            if (sample is not None or self.check_finite or
                    self.chaos is not None):
                logits_np = np.asarray(logits)
            advancing = [i for i in live if chunks[i] or i in decoding]
            bad: set[int] = set()
            if self.chaos is not None and logits_np is not None:
                hit = self.chaos.corrupt_rows(advancing)
                if hit:
                    logits_np = logits_np.copy()  # device arrays read-only
                    for i in hit:
                        logits_np[i] = np.nan
            if self.check_finite and logits_np is not None:
                bad = {i for i in advancing
                       if not np.isfinite(logits_np[i]).all()}

            # 3. consume: advance cursors, emit tokens, retire finished
            # rows; faulted rows quarantine here, the rest are untouched
            for i in list(live):
                slot = self._slots[i]
                if slot.req is None:
                    continue        # freed earlier this tick
                if i in bad:
                    self._fault_row(i, "non-finite logits", completed)
                    continue
                if chunks[i]:
                    slot.pos += chunks[i]
                    slot.length += chunks[i]
                    if slot.pos == len(slot.req.prompt):
                        if self.prefix is not None:
                            # fully-written prompt blocks join the cache
                            self._cache_prompt(i)
                        # final chunk: this row's logits are its first token
                        if sample is None:
                            tok = int(next_np[i])
                        else:
                            tok = self._safe_sample(i, sample, logits_np,
                                                    completed)
                            if tok is None or self._slots[i].req is None:
                                # hook threw (row quarantined) or cancelled
                                # this very row mid-sample
                                continue
                        self._emit(i, tok, completed, first=True)
                elif i in drafts:
                    # verify row: accept the longest draft prefix agreeing
                    # with the model's greedy tokens, emit the model's token
                    # at each accepted position PLUS the first disagreement
                    # (so a verify tick never emits less than plain decode),
                    # then roll back the rejected tail
                    d = drafts[i]
                    g = greedy_np[i]
                    a = 0
                    while a < len(d) and d[a] == int(g[a]):
                        a += 1
                    self.spec_accepted += a
                    if a:               # productive row: speculate freely
                        self._draft_wait[i] = self._draft_penalty[i] = 0
                    else:               # full miss: back off exponentially
                        self._draft_penalty[i] = min(
                            max(self._draft_penalty[i] * 2, 1),
                            _DRAFT_BACKOFF_MAX)
                        self._draft_wait[i] = self._draft_penalty[i]
                    base = slot.length
                    freed = False
                    for j in range(a + 1):
                        # emit-time length matches the sequential schedule:
                        # token j corresponds to cache length base + 1 + j,
                        # so the max_len/eos/max_new stop rules fire at
                        # exactly the oracle's token
                        slot.length = base + 1 + j
                        self._emit(i, int(g[j]), completed, first=False)
                        if self._slots[i].req is None:
                            freed = True   # finished: _free_slot did cleanup
                            break
                    if not freed and a < len(d):
                        self.spec_rewinds += 1
                        self._rewind_slot(i, base + 1 + a)
                elif i in decoding:
                    slot.length += 1
                    if sample is None:
                        tok = int(next_np[i])
                    else:
                        tok = self._safe_sample(i, sample, logits_np,
                                                completed)
                        if tok is None or self._slots[i].req is None:
                            # hook threw (row quarantined) or cancelled
                            # this very row mid-sample
                            continue
                    self._emit(i, tok, completed, first=False)
            if self.audit_every and self.steps % self.audit_every == 0:
                self.audit()
            # 4. durability: one fsync per tick batch, then maybe rotate a
            # fresh snapshot — the snapshot sees every event the journal
            # committed, so a kill between them loses nothing
            if self._journal is not None:
                self._journal.commit()
            if (self.snapshot_dir is not None and self.snapshot_every and
                    self.steps % self.snapshot_every == 0):
                self.snapshot()
        if self._journal is not None:
            self._journal.commit()
        in_flight = sum(s.req is not None for s in self._slots)
        truncated = (self.steps - start_steps >= max_steps and
                     bool(in_flight or self._queue))
        if truncated:
            warnings.warn(
                f"Engine.run hit max_steps={max_steps} with {in_flight} "
                f"request(s) in flight and {len(self._queue)} queued — "
                "work is NOT drained; call run() again to continue",
                RuntimeWarning, stacklevel=2)
        return RunResult(completed, truncated=truncated,
                         in_flight=in_flight, queued=len(self._queue),
                         stalled=stalled)

    # -- metrics ---------------------------------------------------------------

    @property
    def slot_occupancy(self) -> float:
        """Mean fraction of slots live per tick (1.0 = saturated)."""
        return self._occupancy_sum / self.steps if self.steps else 0.0

    def spec_stats(self) -> dict[str, float]:
        """Speculation counters: how much the verify ticks amortized.

        ``accepted_per_dispatch`` is the headline — extra tokens a verify
        dispatch yielded beyond the one plain decode would have (so
        verify-row tokens/dispatch is ``1 + accepted_per_dispatch``)."""
        return {
            "spec_k": self.spec_k,
            "spec_requested": self.spec_requested,
            "spec_supported": self.spec_supported,
            "spec_ticks": self.spec_ticks,
            "verify_rows": self.spec_rows,
            "draft_tokens": self.spec_drafted,
            "accepted_tokens": self.spec_accepted,
            "rewinds": self.spec_rewinds,
            "acceptance_rate": (self.spec_accepted /
                                max(self.spec_drafted, 1)),
            "accepted_per_dispatch": (self.spec_accepted /
                                      max(self.spec_ticks, 1)),
        }

    @staticmethod
    def summarize(reqs: list[Request]) -> dict[str, float]:
        if not reqs:
            return {}
        ttft = [r.first_token_at - r.submitted_at for r in reqs
                if r.first_token_at]
        # decode throughput: measured from the first token so queue-wait
        # does not pollute the device tokens/s number
        tps = [(len(r.output) - 1) /
               max(r.finished_at - r.first_token_at, 1e-9)
               for r in reqs
               if r.finished_at and r.first_token_at and len(r.output) > 1]
        itl = [dt for r in reqs
               for dt in np.diff(r.token_times).tolist()]
        out = {
            "n": len(reqs),
            "total_tokens": float(sum(len(r.output) for r in reqs)),
            # lifecycle outcome counts (ISSUE 8): empty buckets OMIT their
            # mean_* keys below rather than emitting nan — nan poisons JSON
            # diffs of BENCH_serving.json
            "completed": sum(r.status == "done" for r in reqs),
            "errors": sum(r.status == "error" for r in reqs),
            "cancelled": sum(r.status == "cancelled" for r in reqs),
            "deadline_missed": sum(r.status == "deadline_missed"
                                   for r in reqs),
            "preempted": sum(r.preemptions > 0 for r in reqs),
            "preemptions": sum(r.preemptions for r in reqs),
        }
        if ttft:
            out["mean_ttft_s"] = float(np.mean(ttft))
            out["ttft_p50_s"] = float(np.percentile(ttft, 50))
            out["ttft_p99_s"] = float(np.percentile(ttft, 99))
        if tps:
            out["mean_tokens_per_s"] = float(np.mean(tps))
        if itl:
            out["itl_p50_s"] = float(np.percentile(itl, 50))
            out["itl_p99_s"] = float(np.percentile(itl, 99))
        return out


def reference_decode(cfg: ModelConfig, params: Any, prompt: np.ndarray,
                     max_new_tokens: int, *, max_len: int = 512,
                     eos_id: int | None = None,
                     frames: np.ndarray | None = None,
                     compile_cache: CompileCache | None = None) -> list[int]:
    """Per-request batch-1 greedy decode — the EXACT numerics oracle.

    Teacher-forces the prompt through ``api.decode_step`` one token at a
    time (true positions, true lengths, no pad tokens in the context), so
    the resulting cache/state is the ground truth for EVERY family —
    including the post-prompt recurrent state of ssm/hybrid — then decodes
    greedily.  The chunked engine must match this token-for-token.
    """
    if len(prompt) > max_len:
        raise ValueError(f"prompt length {len(prompt)} exceeds {max_len}")
    cc = compile_cache if compile_cache is not None else CompileCache()
    if cfg.family == "audio":
        f = np.asarray(frames)
        fr = jnp.asarray(f[None] if f.ndim == 2 else f)
        admit = cc.get("ref_admit", fr.shape[1],
                       lambda: _admit_executable(cfg, max_len))
        cache = admit(params, fr)
    else:
        cache = api.init_cache(cfg, 1, max_len)
    dec = cc.get("ref_decode", 1, lambda: jax.jit(
        lambda p, c, t, l: api.decode_step(cfg, p, c, t, l)))
    logits = None
    n_cached = 0
    for t in np.asarray(prompt).tolist():
        logits, cache = dec(params, cache,
                            jnp.asarray([[t]], jnp.int32),
                            jnp.asarray([n_cached + 1], jnp.int32))
        n_cached += 1
    out = [int(np.argmax(np.asarray(logits[0])))]
    while (len(out) < max_new_tokens and n_cached < max_len and
           (eos_id is None or out[-1] != eos_id)):
        n_cached += 1
        logits, cache = dec(params, cache,
                            jnp.asarray([[out[-1]]], jnp.int32),
                            jnp.asarray([n_cached], jnp.int32))
        out.append(int(np.argmax(np.asarray(logits[0]))))
    return out
