"""Serving engine: batched decode over a request queue (EdgeLLM §IV-B).

The paper's deployment: FPGA as the inference server, a Python client that
encodes/decodes token ids; the compiler pre-builds per-token-length
instruction streams and the host pipelines instruction upload behind device
compute (Fig. 9).  The JAX restatement:

* ``Engine`` holds quantized params + a prefill/decode executable pair per
  token-length *bucket* (``CompileCache`` + ``TokenBuckets`` from
  core/compiler.py — the dynamic-compilation half);
* requests join a queue; a scheduler packs them into the fixed decode batch
  (continuous-batching style: finished rows are refilled from the queue);
* JAX's async dispatch IS the Fig. 9 latency hiding: the host prepares the
  next step's inputs while the device executes — ``core/pipeline.py``
  measures that overlap explicitly.
"""

from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import CompileCache, TokenBuckets
from repro.models import api
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int = 32
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float | None = None
    finished_at: float | None = None


class Engine:
    """Single-host batched decode engine with bucketed prefill."""

    def __init__(self, cfg: ModelConfig, params: Any, *, batch_size: int = 4,
                 max_len: int = 512, eos_id: int | None = None):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.buckets = TokenBuckets(max_tokens=max_len)
        self.cache_compiles = CompileCache()
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._decode_fn = jax.jit(
            lambda p, c, t, l: api.decode_step(cfg, p, c, t, l))
        self.steps = 0

    # -- client API ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.submitted_at = time.monotonic()
        self._queue.put(req)

    # -- internals -----------------------------------------------------------

    def _prefill_one(self, req: Request):
        """Prefill a single request at its length bucket."""
        bucket = self.buckets.bucket(len(req.prompt))

        def build():
            def fn(p, tokens):
                return api.prefill(self.cfg, p, {"tokens": tokens}, self.max_len)
            return jax.jit(fn)

        fn = self.cache_compiles.get("prefill", bucket, build)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, -len(req.prompt):] = req.prompt  # left-pad into the bucket
        logits, cache = fn(self.params, jnp.asarray(padded))
        return logits, cache, bucket

    def run(self, *, max_steps: int = 10_000,
            sample: Callable | None = None) -> list[Request]:
        """Drain the queue; returns completed requests.

        Simple generational batching: take up to ``batch`` requests, prefill
        each, decode them in lockstep until all finish, repeat.  (True
        continuous batching needs per-row cache paging; the scheduler and
        queue plumbing here are the production-shaped parts.)
        """
        completed: list[Request] = []
        while not self._queue.empty() and self.steps < max_steps:
            group: list[Request] = []
            while len(group) < self.batch and not self._queue.empty():
                group.append(self._queue.get())

            states = [self._prefill_one(r) for r in group]
            lengths = [self.buckets.bucket(len(r.prompt)) for r in group]
            caches = [s[1] for s in states]
            last_logits = [s[0] for s in states]

            for r, lg in zip(group, last_logits):
                tok = int(np.argmax(np.asarray(lg[0])))
                r.output.append(tok)
                r.first_token_at = time.monotonic()

            # lockstep decode (per-request cache; batch=1 decode calls are
            # grouped by bucket through the compile cache)
            alive = list(range(len(group)))
            while alive and self.steps < max_steps:
                self.steps += 1
                still = []
                for i in alive:
                    r = group[i]
                    tok = r.output[-1]
                    lengths[i] += 1
                    logits, caches[i] = self._decode_fn(
                        self.params, caches[i],
                        jnp.asarray([[tok]], jnp.int32),
                        jnp.int32(lengths[i]))
                    nxt = (int(np.argmax(np.asarray(logits[0])))
                           if sample is None else sample(logits[0]))
                    r.output.append(nxt)
                    if (len(r.output) >= r.max_new_tokens or
                            (self.eos_id is not None and nxt == self.eos_id)):
                        r.done = True
                        r.finished_at = time.monotonic()
                        completed.append(r)
                    else:
                        still.append(i)
                alive = still
        return completed

    # -- metrics ---------------------------------------------------------------

    @staticmethod
    def summarize(reqs: list[Request]) -> dict[str, float]:
        if not reqs:
            return {}
        ttft = [r.first_token_at - r.submitted_at for r in reqs
                if r.first_token_at]
        tps = [len(r.output) / max(r.finished_at - r.submitted_at, 1e-9)
               for r in reqs if r.finished_at]
        return {
            "n": len(reqs),
            "mean_ttft_s": float(np.mean(ttft)) if ttft else float("nan"),
            "mean_tokens_per_s": float(np.mean(tps)) if tps else float("nan"),
        }
