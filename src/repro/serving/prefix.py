"""Prefix sharing: refcounted block allocator + radix prompt cache.

EdgeLLM's memory premise (one data shape per operator, tight HBM budgets)
makes repeated prefill the worst place to spend edge bandwidth: millions of
users open with the same system prompts and few-shot headers, and the paged
engine (PR 5) re-prefilled them per request and held a private copy of
every block.  The pool's null-block write routing already tolerates
read-only aliasing — many page tables may point at the same physical block
as long as nobody writes through it — so sharing needs exactly two pieces
of HOST bookkeeping, both here:

* **``BlockAllocator``** — the engine's free list with per-block refcounts.
  A freshly leased block has refcount 1 (its slot); mapping the same block
  into another slot's page table ``incref``s it; retiring/rewinding a slot
  ``decref``s instead of freeing, and a block returns to the free list only
  at refcount 0.  The PR 5 leak/double-free invariants generalize: every
  block is either free with refcount 0, or live with refcount >= 1 — a
  decref at 0 is a double free, and ``check()`` asserts the partition.

* **``RadixPrefixCache``** — a radix tree over prompt tokens (per engine,
  so per (cfg, params) identity) whose edges are BLOCK-sized token runs and
  whose nodes name the fully-written physical block holding that run's K/V.
  Admission of a prompt that walks a cached path becomes a page-table copy
  (incref the shared blocks) plus chunked prefill of only the uncovered
  suffix.  A divergence MID-block still salvages the matched head of the
  next cached block: the engine copies that one block (copy-on-write — the
  only copy sharing ever does, because serving writes are append-only) and
  overwrites from the divergence point.  Cache residency itself holds one
  reference per node, so cached blocks survive their author's retirement
  and are evicted LRU-last under pool pressure (leaf nodes only, so every
  cached path stays reachable root-to-node).

Sharing is exact, not approximate: ``mixed_step`` is bitwise equal to
sequential ``decode_step`` (the PR 3 invariant), so the K/V a cached block
holds is bit-identical to what the admitted request would have recomputed —
token streams with the cache ON match the cache-OFF engine and the
``reference_decode`` oracle exactly.
"""

from __future__ import annotations

from typing import Callable, Iterable


class BlockAllocator:
    """Refcounted free-list allocator over ``n_blocks`` physical KV blocks.

    Pure host bookkeeping (no device state).  The free list is LIFO like the
    PR 5 allocator it replaces, so lease order — and therefore the block
    recycling the paged tests scramble — is unchanged when every refcount
    stays at 1.

    Topology (``n_homes > 1``, the sharded paged path): the POOL's rows —
    ``n_blocks`` usable blocks plus the null row, ``n_blocks + 1`` total —
    are partitioned into ``n_homes`` contiguous runs of equal size; block
    ``b`` is HOME to shard ``b // rows_per_home`` (the null row lands in
    the last home by construction).  A home is a pure function of the
    block id, so a block keeps its home across incref/decref — prefix
    sharing and CoW never migrate K/V between shards.  ``lease(home=h)``
    takes specifically from home ``h`` (LIFO within the home);
    ``lease()`` with no home rotates round-robin across non-empty homes so
    unconstrained leases still spread context over the mesh.
    """

    def __init__(self, n_blocks: int, n_homes: int = 1):
        if n_blocks < 1:
            raise ValueError(f"need >= 1 block, got {n_blocks}")
        if n_homes < 1:
            raise ValueError(f"need >= 1 home, got {n_homes}")
        if (n_blocks + 1) % n_homes:
            raise ValueError(
                f"pool rows {n_blocks + 1} (incl. null) must split evenly "
                f"into {n_homes} block homes")
        self.n_blocks = n_blocks
        self.n_homes = n_homes
        self.rows_per_home = (n_blocks + 1) // n_homes
        self.free: list[int] = list(range(n_blocks))
        self.refs: list[int] = [0] * n_blocks
        self._next_home = 0

    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def n_live(self) -> int:
        return sum(1 for r in self.refs if r > 0)

    def ref(self, blk: int) -> int:
        return self.refs[blk]

    def home(self, blk: int) -> int:
        """The shard block ``blk`` is home to (pure function of the id)."""
        return blk // self.rows_per_home

    def free_by_home(self) -> list[int]:
        """Free-block count per home."""
        counts = [0] * self.n_homes
        for blk in self.free:
            counts[self.home(blk)] += 1
        return counts

    def lease(self, home: int | None = None) -> int:
        """Take a free block (refcount 0 -> 1), from home ``home`` when
        given (LIFO within the home), else round-robin across homes."""
        if not self.free:
            raise RuntimeError("KV block pool exhausted")
        if home is None and self.n_homes > 1:
            by_home = self.free_by_home()
            for step in range(self.n_homes):
                h = (self._next_home + step) % self.n_homes
                if by_home[h]:
                    home = h
                    self._next_home = (h + 1) % self.n_homes
                    break
        if home is None:
            blk = self.free.pop()
        else:
            idx = next((i for i in range(len(self.free) - 1, -1, -1)
                        if self.home(self.free[i]) == home), None)
            if idx is None:
                raise RuntimeError(
                    f"KV block pool exhausted in home {home}")
            blk = self.free.pop(idx)
        if self.refs[blk] != 0:
            raise RuntimeError(
                f"free list corrupt: block {blk} freed at refcount "
                f"{self.refs[blk]}")
        self.refs[blk] = 1
        return blk

    def incref(self, blk: int) -> None:
        """Add a holder to a LIVE block (sharing an already-written block)."""
        if self.refs[blk] < 1:
            raise RuntimeError(
                f"incref of dead KV block {blk} — a shared mapping must "
                "target a live block")
        self.refs[blk] += 1

    def decref(self, blk: int) -> bool:
        """Drop one holder; returns True when the block went back to the
        free list (refcount hit 0)."""
        if self.refs[blk] <= 0:
            raise RuntimeError(f"double free of KV block {blk}")
        self.refs[blk] -= 1
        if self.refs[blk] == 0:
            self.free.append(blk)
            return True
        return False

    def n_shared(self) -> int:
        """Blocks currently mapped by more than one holder."""
        return sum(1 for r in self.refs if r >= 2)

    def check(self) -> None:
        """The allocator partition invariant: every block is either on the
        free list with refcount 0, or off it with refcount >= 1; homes
        partition the pool rows with the null row in the last home."""
        if sorted(set(self.free)) != sorted(self.free):
            raise AssertionError("free list holds duplicate block ids")
        free = set(self.free)
        if not free <= set(range(self.n_blocks)):
            raise AssertionError("free list holds foreign block ids")
        for blk, r in enumerate(self.refs):
            if (blk in free) == (r > 0):
                raise AssertionError(
                    f"block {blk}: refcount {r} vs free={blk in free} — "
                    "leak or double lease")
        if self.rows_per_home * self.n_homes != self.n_blocks + 1:
            raise AssertionError(
                f"homes {self.n_homes} x {self.rows_per_home} do not tile "
                f"the {self.n_blocks + 1} pool rows")
        if self.home(self.n_blocks) != self.n_homes - 1:
            raise AssertionError("null row must be home to the last shard")
        if sum(self.free_by_home()) != self.n_free:
            raise AssertionError("per-home free counts do not partition "
                                 "the free list")


class _Node:
    """One radix edge: ``tokens`` (exactly ``block_size`` ids) labels the
    edge from ``parent``; ``block`` is the physical block whose K/V was
    written from those tokens at this tree depth."""

    __slots__ = ("tokens", "block", "children", "parent", "last_used")

    def __init__(self, tokens: tuple, block: int, parent):
        self.tokens = tokens
        self.block = block
        self.children: dict[tuple, _Node] = {}
        self.parent = parent
        self.last_used = 0


class RadixPrefixCache:
    """Radix tree over prompt tokens at KV-block granularity.

    Edges are ``block_size``-token runs; a node maps its root-to-node token
    path to the physical block holding that run's K/V.  Matching returns the
    longest fully-cached block chain plus, at the divergence point, the
    longest PARTIAL head of any next cached block (the engine turns that
    into a copy-on-write admission).  Eviction removes least-recently-used
    LEAF nodes only, so every surviving node's path stays walkable.
    """

    def __init__(self, block_size: int):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.block_size = block_size
        self.root: dict[tuple, _Node] = {}
        self._nodes: list[_Node] = []
        self._clock = 0          # LRU timestamps without wall-clock time

    def __len__(self) -> int:
        return len(self._nodes)

    def blocks(self) -> list[int]:
        """Every block the cache currently holds a reference on."""
        return [n.block for n in self._nodes]

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens) -> tuple[list[int], tuple[int, int] | None]:
        """Longest cached prefix of ``tokens``.

        Returns ``(full_blocks, partial)``: ``full_blocks`` is the chain of
        physical blocks covering ``len(full_blocks) * block_size`` leading
        tokens exactly; ``partial`` is ``(block, n)`` when the next cached
        edge agrees with the following ``n`` (``0 < n < block_size``) tokens
        — reusable only via copy-on-write, since its tail differs.  Every
        node on the walk (and the partial node) is LRU-touched.
        """
        toks = [int(t) for t in tokens]
        bs = self.block_size
        now = self._tick()
        level, full, i = self.root, [], 0
        while True:
            chunk = tuple(toks[i:i + bs])
            if len(chunk) == bs and chunk in level:
                node = level[chunk]
                node.last_used = now
                full.append(node.block)
                i += bs
                level = node.children
                continue
            best: tuple[_Node, int] | None = None
            rest = toks[i:]
            for key, child in level.items():
                n = 0
                for a, b in zip(key, rest):
                    if a != b:
                        break
                    n += 1
                if n and (best is None or n > best[1]):
                    best = (child, n)
            if best is None:
                return full, None
            best[0].last_used = now
            return full, (best[0].block, best[1])

    def insert(self, tokens, blocks: Iterable[int]) -> list[int]:
        """Register ``blocks`` as the fully-written chain for ``tokens``
        (``len(tokens) == len(blocks) * block_size``).  Existing nodes win —
        concurrent identical prompts keep the FIRST author's block, and the
        duplicate block stays private to its slot.  Returns the blocks of
        newly created nodes; the caller holds the cache's reference on
        exactly those (one incref each).
        """
        toks = [int(t) for t in tokens]
        blocks = list(blocks)
        bs = self.block_size
        if len(toks) != len(blocks) * bs:
            raise ValueError(
                f"{len(toks)} tokens cannot map {len(blocks)} blocks of "
                f"{bs} — only whole fully-written blocks are cacheable")
        now = self._tick()
        level, parent, fresh = self.root, None, []
        for j, blk in enumerate(blocks):
            chunk = tuple(toks[j * bs:(j + 1) * bs])
            node = level.get(chunk)
            if node is None:
                node = _Node(chunk, int(blk), parent)
                level[chunk] = node
                self._nodes.append(node)
                fresh.append(int(blk))
            node.last_used = now
            parent, level = node, node.children
        return fresh

    def evict_lru(self, keep: Callable[[int], bool] | None = None
                  ) -> int | None:
        """Remove the least-recently-used LEAF node (skipping blocks for
        which ``keep(block)`` is True) and return its block — the caller
        drops the cache's reference on it.  Returns None when nothing is
        evictable.  Leaf-only eviction keeps every cached path reachable;
        repeated calls peel a cold chain back from its tip.
        """
        victim = None
        for node in self._nodes:
            if node.children:
                continue
            if keep is not None and keep(node.block):
                continue
            if victim is None or node.last_used < victim.last_used:
                victim = node
        if victim is None:
            return None
        level = victim.parent.children if victim.parent else self.root
        del level[victim.tokens]
        self._nodes.remove(victim)
        return victim.block

    def clear(self) -> list[int]:
        """Drop every node; returns their blocks for the caller to decref."""
        blocks = [n.block for n in self._nodes]
        self.root = {}
        self._nodes = []
        return blocks

    def dump(self) -> dict:
        """JSON-safe structural capture for serving snapshots.

        Each node is recorded with its FULL root-to-node token path (not
        just the edge), so ``load`` can rebuild the tree by inserting paths
        in depth order without assuming anything about dict ordering.  LRU
        timestamps and the clock survive, so eviction order after restore
        matches the never-killed engine.
        """
        entries = []
        for n in self._nodes:
            path, cur = [], n
            while cur is not None:
                path.append(cur.tokens)
                cur = cur.parent
            toks = [int(t) for chunk in reversed(path) for t in chunk]
            entries.append({"tokens": toks, "block": int(n.block),
                            "last_used": int(n.last_used)})
        return {"block_size": self.block_size, "clock": self._clock,
                "nodes": entries}

    def load(self, state: dict) -> None:
        """Rebuild from a ``dump()`` capture into an EMPTY cache.

        Only structure is restored — the cache's per-node block references
        are accounted for by the restored allocator refcount arrays, so no
        increfs happen here.
        """
        if self._nodes:
            raise RuntimeError("load() requires an empty prefix cache")
        if state["block_size"] != self.block_size:
            raise ValueError(
                f"snapshot block_size {state['block_size']} != engine "
                f"block_size {self.block_size}")
        bs = self.block_size
        # parents before children: shorter paths first
        for e in sorted(state["nodes"], key=lambda e: len(e["tokens"])):
            toks = e["tokens"]
            level, parent = self.root, None
            for j in range(0, len(toks) - bs, bs):
                parent = level[tuple(toks[j:j + bs])]
                level = parent.children
            chunk = tuple(toks[-bs:])
            node = _Node(chunk, int(e["block"]), parent)
            node.last_used = int(e["last_used"])
            level[chunk] = node
            self._nodes.append(node)
        self._clock = int(state["clock"])
