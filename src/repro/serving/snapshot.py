"""Durable serving: atomic engine snapshots + write-ahead request journal.

EdgeLLM's deployment target is an edge device where power loss and process
kills are ROUTINE, not rare — PR 8 made the engine resilient to in-process
faults, and this module closes the process boundary.  The durability
contract has three parts:

* **Point-in-time snapshots.**  ``save(engine)`` captures the device KV
  pool leaves (paged pool + int8 scales, or the slot cache — bit-exact
  through the training checkpoint's bf16/fp8 view codec) together with the
  FULL host control plane: slot leases, page tables, ``BlockAllocator``
  refcounts, the ``RadixPrefixCache`` token→block chains, every live
  ``Request``'s lifecycle fields (status, accepted output, ``folded``
  high-water mark, preemption count, deadline as REMAINING budget),
  drafter history, engine counters, and the bounded compile-key list for
  warm re-jit.  Writes are atomic (``core.atomic.atomic_dir``: temp dir +
  ``os.replace``) — a snapshot interrupted mid-write is NEVER observed by
  restore; the previous complete one wins.

* **Write-ahead journal.**  An append-only JSONL of submit/emit/terminal
  events, fsync'd once per tick batch (and immediately on out-of-tick
  submits/cancels).  Each snapshot epoch N owns ``journal_N.jsonl``: the
  file records exactly what happened AFTER snapshot N, and the engine
  rotates to a fresh journal only after the next snapshot commits, so the
  (snapshot, journal) pair is always a consistent recovery point.  Chaos
  kills fire at the TOP of a tick — after the previous tick's fsync — so
  an emitted token is never lost and never duplicated.

* **Restore + replay.**  ``restore_engine(dir, params)`` (the body of
  ``Engine.restore``) loads the latest complete snapshot, warms the saved
  compile keys (one throwaway dispatch each, so the first real tick is not
  a cold jit), loads the device state bit-exactly, rebuilds the host
  control plane, then replays the epoch's journal: submits re-enter the
  queue, emits extend the owning request's accepted output, terminals
  retire (surfaced via ``engine.restored_terminal`` — the dead process's
  caller objects are gone).  Any live request whose output grew past the
  snapshot is re-folded into its prompt via the PR 8 ``_fold_slot``
  preemption primitive and requeued at the FRONT in admission order — so
  replayed admission is mostly prefix-cache page-table copies, and the
  resumed token streams are BITWISE equal to the never-killed engine's
  (hence to ``reference_decode``).  Journals are never pruned: the
  concatenation of every epoch's emits is each request's full durable
  token stream, exactly once, in order (``journaled_streams``) — the
  parity source the kill/restore chaos soak checks against the oracle.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import re
import shutil
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.atomic import atomic_dir
from repro.models import api
from repro.models.config import ModelConfig
from repro.serving.engine import Engine, Request, _Slot
from repro.train import checkpoint

SNAPSHOT_VERSION = 1
_SNAP_RE = re.compile(r"snap_(\d+)$")
_JOURNAL_RE = re.compile(r"journal_(\d+)\.jsonl$")

# engine counters that round-trip verbatim through the host manifest
_COUNTERS = (
    "steps", "dispatches", "mixed_ticks", "_occupancy_sum",
    "peak_pool_blocks", "peak_resident_tokens", "admission_stalls",
    "prefix_hits", "prefix_hit_tokens", "cow_copies", "prefix_evictions",
    "preemptions", "deadline_misses", "row_faults", "cancels", "audits",
    "spec_ticks", "spec_rows", "spec_drafted", "spec_accepted",
    "spec_rewinds", "_admit_seq", "snapshots_taken",
)


# -- paths ------------------------------------------------------------------

def snap_path(root: str, epoch: int) -> str:
    return os.path.join(root, f"snap_{epoch:06d}")


def journal_path(root: str, epoch: int) -> str:
    return os.path.join(root, f"journal_{epoch:06d}.jsonl")


def snapshots(root: str) -> list[tuple[int, str]]:
    """Every COMPLETE snapshot under ``root``, epoch-ascending.  A dir is
    complete only when both its host manifest and its device manifest
    exist — ``.tmp`` turds and half-written dirs are invisible here, which
    is the torn-snapshot guarantee."""
    out = []
    if not os.path.isdir(root):
        return out
    for d in os.listdir(root):
        m = _SNAP_RE.match(d)
        if not m:
            continue
        p = os.path.join(root, d)
        if (os.path.isfile(os.path.join(p, "host.json")) and
                os.path.isfile(os.path.join(p, "device", "manifest.json"))):
            out.append((int(m.group(1)), p))
    return sorted(out)


def latest_snapshot(root: str) -> tuple[int, str]:
    snaps = snapshots(root)
    if not snaps:
        raise FileNotFoundError(f"no complete snapshot under {root!r}")
    return snaps[-1]


# -- write-ahead journal ----------------------------------------------------

class Journal:
    """Append-only JSONL event log.  ``append`` is line-buffered (a dying
    in-process engine still leaves whole lines); ``commit`` is the real
    durability point — flush + ``os.fsync``, called once per tick batch."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a", buffering=1)
        self.appended = 0

    def append(self, ev: dict) -> None:
        self._f.write(json.dumps(ev) + "\n")
        self.appended += 1

    def commit(self) -> None:
        if self._f.closed:
            return
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self.commit()
            self._f.close()


def read_journal(path: str) -> list[dict]:
    """Parse a journal; a torn trailing line (kill mid-write) ends the
    replay — everything before it was a complete, fsync-able record."""
    events: list[dict] = []
    if not os.path.exists(path):
        return events
    with open(path) as f:
        for line in f:
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                break
    return events


def journaled_streams(root: str) -> tuple[dict[int, list[int]],
                                          dict[int, str]]:
    """The durable per-request record across every epoch, in order.

    Returns ``(streams, status)``: ``streams[rid]`` is the full emitted
    token stream (each token journaled exactly once — snapshots restore
    output state but emits are only ever journaled when first generated),
    ``status[rid]`` the last journaled lifecycle word ("submitted" until a
    terminal event lands).  This is what the kill/restore soak diffs
    against the ``reference_decode`` oracle."""
    streams: dict[int, list[int]] = collections.defaultdict(list)
    status: dict[int, str] = {}
    epochs = sorted(
        (int(m.group(1)), os.path.join(root, d))
        for d in os.listdir(root)
        if (m := _JOURNAL_RE.match(d)) is not None)
    for _, path in epochs:
        for ev in read_journal(path):
            if ev["ev"] == "emit":
                streams[ev["rid"]].append(int(ev["tok"]))
            elif ev["ev"] == "submit":
                status.setdefault(ev["rid"], "submitted")
            elif ev["ev"] == "terminal":
                status[ev["rid"]] = ev["status"]
    return dict(streams), status


# -- config / request codecs ------------------------------------------------

def cfg_to_dict(cfg: ModelConfig) -> dict:
    d = dataclasses.asdict(cfg)
    d["dtype"] = jnp.dtype(cfg.dtype).name
    return d


def cfg_from_dict(d: dict) -> ModelConfig:
    d = dict(d)
    d["dtype"] = jnp.dtype(d["dtype"]).type
    if isinstance(d.get("mrope_sections"), list):
        d["mrope_sections"] = tuple(d["mrope_sections"])
    return ModelConfig(**d)


def _dump_req(req: Request, now: float) -> dict:
    """Serialize one LIVE request.  Times go out as ages/offsets from the
    save-time clock: a restored process has a different monotonic base, so
    deadlines are stored as REMAINING budget and re-anchored at load —
    downtime does not count against a request."""
    age = now - req.submitted_at
    return {
        "rid": req.rid,
        "prompt": np.asarray(req.prompt).tolist(),
        "max_new": req.max_new_tokens,
        "frames": (None if req.frames is None
                   else np.asarray(req.frames).tolist()),
        "priority": req.priority,
        "deadline_remaining": (None if req.deadline_s is None
                               else req.deadline_s - age),
        "output": [int(t) for t in req.output],
        "status": req.status,
        "error": req.error,
        "preemptions": req.preemptions,
        "folded": req.folded,
        "age": age,
        "ttft": (None if req.first_token_at is None
                 else req.first_token_at - req.submitted_at),
        "token_offsets": [t - req.submitted_at for t in req.token_times],
    }


def _load_req(d: dict, now: float) -> Request:
    req = Request(
        rid=d["rid"],
        prompt=np.asarray(d["prompt"], np.int64),
        max_new_tokens=d["max_new"],
        frames=(None if d["frames"] is None
                else np.asarray(d["frames"], np.float32)),
        priority=d["priority"])
    req.output = [int(t) for t in d["output"]]
    req.status = d["status"]
    req.error = d["error"]
    req.preemptions = d["preemptions"]
    req.folded = d["folded"]
    req.submitted_at = now - d["age"]
    # remaining budget: the miss fires ``deadline_remaining`` seconds after
    # restore, regardless of how long the process was dead
    req.deadline_s = (None if d["deadline_remaining"] is None
                      else d["deadline_remaining"] + d["age"])
    req.first_token_at = (None if d["ttft"] is None
                          else req.submitted_at + d["ttft"])
    req.token_times = [req.submitted_at + o for o in d["token_offsets"]]
    return req


# -- save -------------------------------------------------------------------

def _ctor_kwargs(eng: Engine) -> dict:
    return {
        "batch_size": eng.batch, "max_len": eng.max_len,
        "eos_id": eng.eos_id, "chunk_size": eng.chunk_size,
        "prefill_token_budget": eng.prefill_token_budget,
        "prefill_policy": eng.prefill_policy,
        "spec_k": eng.spec_requested,
        "prefix_cache": eng.prefix_requested,
        "max_preemptions": eng.max_preemptions,
        "enforce_deadlines": eng.enforce_deadlines,
        "check_finite": eng.check_finite,
        "audit_every": eng.audit_every,
        "snapshot_every": eng.snapshot_every,
        "snapshot_keep": eng.snapshot_keep,
        "journal": eng.journal_enabled,
    }


def _dump_host(eng: Engine, epoch: int) -> dict:
    now = eng.clock()
    live: dict[int, Request] = {}
    for s in eng._slots:
        if s.req is not None:
            live[s.req.rid] = s.req
    for r in eng._queue:
        live[r.rid] = r
    host: dict[str, Any] = {
        "version": SNAPSHOT_VERSION,
        "epoch": epoch,
        "cfg": cfg_to_dict(eng.cfg),
        "kwargs": _ctor_kwargs(eng),
        "counters": {k: getattr(eng, k) for k in _COUNTERS},
        "draft_wait": list(eng._draft_wait),
        "draft_penalty": list(eng._draft_penalty),
        "slots": [{
            "rid": None if s.req is None else s.req.rid,
            "length": s.length, "pos": s.pos,
            "last_token": s.last_token, "seq": s.seq,
        } for s in eng._slots],
        "requests": [_dump_req(r, now) for r in live.values()],
        "queue": [r.rid for r in eng._queue],
        "compile_keys": [[name, bucket]
                         for name, bucket in eng.cache_compiles.keys()],
        "prefix": None if eng.prefix is None else eng.prefix.dump(),
        "drafter": None if eng.drafter is None else eng.drafter.dump(),
    }
    if eng.paged:
        host["paged"] = {
            "page_table": eng._page_table.tolist(),
            "slot_blocks": [list(b) for b in eng._slot_blocks],
            "slot_reserve": list(eng._slot_reserve),
            "n_homes": eng.n_homes,
            "reserve_home": [list(v) for v in eng._reserve_home],
            "free": list(eng.alloc.free),
            "refs": list(eng.alloc.refs),
        }
    return host


def _write_snapshot(eng: Engine, root: str, epoch: int) -> str:
    final = snap_path(root, epoch)
    with atomic_dir(final) as tmp:
        with open(os.path.join(tmp, "host.json"), "w") as f:
            json.dump(_dump_host(eng, epoch), f)
        checkpoint.write_state(
            os.path.join(tmp, "device"),
            {"cache": api.export_cache(eng.cfg, eng.cache)},
            extra={"epoch": epoch}, step=epoch)
    return final


def _prune(root: str, keep: int) -> None:
    """Drop all but the newest ``keep`` complete snapshots.  Journals are
    never pruned: concatenated epochs are the full durable stream."""
    if not keep:
        return
    for _, path in snapshots(root)[:-keep]:
        shutil.rmtree(path, ignore_errors=True)


def save(eng: Engine) -> str:
    """Write snapshot epoch N+1 and rotate the journal to it.

    Order is the crash-consistency argument: commit the OLD journal, write
    the new snapshot atomically, and only then close the old journal and
    open the new epoch's — a kill anywhere in between leaves a complete
    (snapshot, journal) recovery pair on disk."""
    root = eng.snapshot_dir
    epoch = eng._snap_epoch + 1
    if eng._journal is not None:
        eng._journal.commit()
    final = _write_snapshot(eng, root, epoch)
    if eng._journal is not None:
        eng._journal.close()
    eng._snap_epoch = epoch
    if eng.journal_enabled:
        eng._journal = Journal(journal_path(root, epoch))
    eng.snapshots_taken += 1
    _prune(root, eng.snapshot_keep)
    return final


def attach(eng: Engine, root: str) -> None:
    """Start durability on a FRESH engine: take the baseline snapshot (so
    restore always has a complete snapshot to stand on) and open its
    journal.  Called from ``Engine.__init__`` when ``snapshot_dir`` is
    set; a stale store from a previous run just yields a higher epoch."""
    os.makedirs(root, exist_ok=True)
    eng.snapshot_dir = root
    snaps = snapshots(root)
    eng._snap_epoch = snaps[-1][0] if snaps else -1
    save(eng)


# -- restore ----------------------------------------------------------------

def _warm_executables(eng: Engine, keys: list) -> None:
    """Re-jit the dead process's executables by EXECUTING one throwaway
    dispatch per saved compile key, threading the pristine zero cache
    through the donated calls.  Runs BEFORE the device state loads, so the
    garbage these dispatches write is overwritten bit-exactly.  Keys that
    need request data (audio ``admit``) re-jit on demand instead."""
    b = eng.batch
    pt = jnp.asarray(eng._page_table) if eng.paged else None
    for name, bucket in keys:
        if name == "mixed":
            fn = eng.cache_compiles.get("mixed", bucket, eng._build_mixed)
            tokens = jnp.zeros((b, bucket), jnp.int32)
            q_lens = np.zeros(b, np.int32)
            q_lens[0] = min(2, bucket)
            args = (tokens, jnp.zeros((b,), jnp.int32), jnp.asarray(q_lens))
            if eng.paged:
                args += (pt,)
            out = fn(eng.params, eng.cache, *args)
            eng.cache = out[2]
        elif name == "decode":
            fn = eng.cache_compiles.get("decode", bucket, eng._build_decode)
            args = (jnp.zeros((b, 1), jnp.int32), jnp.ones((b,), jnp.int32))
            if eng.paged:
                args += (pt, jnp.zeros((b,), bool))
            out = fn(eng.params, eng.cache, *args)
            eng.cache = out[2]
        elif name == "insert":
            fn = eng.cache_compiles.get("insert", bucket, eng._build_insert)
            row = api.init_cache(eng._row_cfg, 1, eng.max_len)
            eng.cache = fn(eng.cache, row, np.int32(0))
        elif name == "cow":
            fn = eng.cache_compiles.get("cow", bucket, eng._build_cow)
            eng.cache = fn(eng.cache, np.int32(0), np.int32(0))


def _load_host(eng: Engine, host: dict) -> None:
    now = eng.clock()
    reqs = {d["rid"]: _load_req(d, now) for d in host["requests"]}
    eng._queue = collections.deque(reqs[rid] for rid in host["queue"])
    for i, sd in enumerate(host["slots"]):
        s = _Slot(req=None if sd["rid"] is None else reqs[sd["rid"]],
                  length=sd["length"], pos=sd["pos"],
                  last_token=sd["last_token"], seq=sd["seq"])
        eng._slots[i] = s
    eng._live_rids = set(reqs)
    if eng.paged:
        pg = host["paged"]
        eng._page_table = np.asarray(pg["page_table"], np.int32)
        eng._slot_blocks = [list(bs) for bs in pg["slot_blocks"]]
        eng._slot_reserve = list(pg["slot_reserve"])
        # block homes must round-trip: a snapshot taken under a mesh only
        # restores into an engine built under the same home topology (the
        # page-table block spread is meaningless otherwise)
        homes = int(pg.get("n_homes", 1))
        if homes != eng.n_homes:
            raise RuntimeError(
                f"snapshot was taken with {homes} block homes but the "
                f"restoring engine derived {eng.n_homes} — restore under "
                "the same device mesh the snapshot was saved under")
        if "reserve_home" in pg:
            eng._reserve_home = [[int(x) for x in v]
                                 for v in pg["reserve_home"]]
        else:           # pre-home snapshot: only valid single-home
            eng._reserve_home = [[int(r)] for r in eng._slot_reserve]
        eng.alloc.free = [int(x) for x in pg["free"]]
        eng.alloc.refs = [int(x) for x in pg["refs"]]
    if eng.prefix is not None and host["prefix"] is not None:
        eng.prefix.load(host["prefix"])
    if eng.drafter is not None and host["drafter"] is not None:
        eng.drafter.ngram_max = host["drafter"]["ngram_max"]
        eng.drafter.ngram_min = host["drafter"]["ngram_min"]
        eng.drafter.load(host["drafter"])
    for k in _COUNTERS:
        setattr(eng, k, host["counters"][k])
    eng._draft_wait = list(host["draft_wait"])
    eng._draft_penalty = list(host["draft_penalty"])


def _find_live(eng: Engine, rid: int) -> Request:
    for s in eng._slots:
        if s.req is not None and s.req.rid == rid:
            return s.req
    for r in eng._queue:
        if r.rid == rid:
            return r
    raise RuntimeError(f"journal references unknown live rid {rid}")


def _replay(eng: Engine, events: list[dict]) -> set[int]:
    """Apply one epoch's journal to the freshly loaded snapshot state, in
    order.  Returns the rids whose accepted output grew past the snapshot
    (and are still live) — those must be re-folded, because the restored
    device KV only covers the snapshot's lengths."""
    emitted: set[int] = set()
    for ev in events:
        kind = ev["ev"]
        if kind == "submit":
            eng.submit(Request(
                rid=ev["rid"],
                prompt=np.asarray(ev["prompt"], np.int64),
                max_new_tokens=ev["max_new"],
                priority=ev["priority"],
                deadline_s=ev["deadline"],
                frames=(None if ev["frames"] is None
                        else np.asarray(ev["frames"], np.float32))))
        elif kind == "emit":
            req = _find_live(eng, ev["rid"])
            now = eng.clock()
            if req.first_token_at is None:
                req.first_token_at = now
            req.output.append(int(ev["tok"]))
            req.token_times.append(now)
            emitted.add(ev["rid"])
        elif kind == "terminal":
            rid = ev["rid"]
            req = None
            for r in list(eng._queue):
                if r.rid == rid:
                    eng._queue.remove(r)
                    req = r
                    break
            if req is None:
                for i, s in enumerate(eng._slots):
                    if s.req is not None and s.req.rid == rid:
                        req = s.req
                        eng._free_slot(i)
                        break
            if req is None:
                continue            # already terminal (duplicate event)
            req.error = ev.get("error")
            if ev["status"] == "deadline_missed":
                eng.deadline_misses += 1
            elif ev["status"] == "cancelled":
                eng.cancels += 1
            elif ev["status"] == "error":
                eng.row_faults += 1
            eng._terminal(req, ev["status"])
            eng.restored_terminal.append(req)
            emitted.discard(rid)
    return emitted


def _fold_replayed(eng: Engine, emitted: set[int]) -> None:
    """Re-fold every live request whose output grew past the snapshot.

    Slot residents fold through the preemption primitive (donating their
    snapshot-resident blocks to the radix cache, so re-admission is mostly
    a page-table copy) and requeue at the FRONT in admission order; a
    restore-fold does NOT count against ``max_preemptions`` — the request
    did nothing wrong.  Queued requests (admitted and preempted entirely
    after the snapshot) fold prompt-only."""
    resident = sorted(
        ((s.seq, i) for i, s in enumerate(eng._slots)
         if s.req is not None and s.req.rid in emitted),
        reverse=True)
    for _, i in resident:
        # front-requeue in reverse seq order leaves the queue seq-ascending
        req = eng._slots[i].req
        eng._fold_slot(i)
        req.status = "queued"
        eng._free_slot(i)
        eng._queue.appendleft(req)
    for r in eng._queue:
        if r.rid in emitted and len(r.output) > r.folded:
            r.prompt = np.concatenate([
                np.asarray(r.prompt, np.int64),
                np.asarray(r.output[r.folded:], np.int64)])
            r.folded = len(r.output)


def restore_engine(root: str, params: Any, **overrides) -> Engine:
    """Rebuild a process-equivalent engine from the latest complete
    snapshot + its journal.  See the module docstring for the contract;
    ``Engine.restore`` is the public face of this function."""
    epoch, snapdir = latest_snapshot(root)
    with open(os.path.join(snapdir, "host.json")) as f:
        host = json.load(f)
    cfg = cfg_from_dict(host["cfg"])
    kwargs = dict(host["kwargs"])
    kwargs.update(overrides)
    eng = Engine(cfg, params, **kwargs)     # snapshot_dir wired after replay
    _warm_executables(eng, host["compile_keys"])
    state, _ = checkpoint.read_state(os.path.join(snapdir, "device"),
                                     {"cache": eng.cache})
    eng.cache = state["cache"]
    _load_host(eng, host)
    emitted = _replay(eng, read_journal(journal_path(root, epoch)))
    _fold_replayed(eng, emitted)
    eng.audit()
    # resume durability on the SAME epoch: post-restore events append to
    # its journal, so concatenated epochs stay the full exactly-once stream
    eng.snapshot_dir = root
    eng._snap_epoch = epoch
    if eng.journal_enabled:
        eng._journal = Journal(journal_path(root, epoch))
    return eng
