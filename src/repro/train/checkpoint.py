"""Checkpoint save/restore with elastic resharding.

Format: one directory per step —

    step_000123/
      manifest.json        # tree structure, shapes, dtypes, step, data-state
      arrays/<leaf-id>.npy # one file per leaf (quantized leaves keep their
                           # packed/scales/idx arrays separately)

Properties the tests pin down:

* round-trip identity (params, optimizer state, data-pipeline cursor);
* **elastic restore**: arrays are saved as full (unsharded) npy and restored
  with ``jax.device_put`` against the *target* mesh's shardings — a 16×16
  checkpoint restores onto 4×2 or 2×16×16 unchanged (mesh-shape elasticity);
* atomicity: writes go through ``core.atomic.atomic_dir`` (``<dir>.tmp``
  then ``os.replace``) — a preempted save never corrupts the latest complete
  checkpoint; the same helper backs serving snapshots;
* retention: ``keep`` newest checkpoints are preserved, older ones pruned.

The leaf codec (``write_state``/``read_state``) is exposed for the serving
snapshot store, which wants the same bit-exact bf16/fp8 round-trip for KV
pool leaves without the step-directory naming scheme.

On a real multi-host pod each host would write its addressable shards
(process-local npy per shard) — the manifest layout already carries the
per-leaf sharding spec string needed for that; single-host full-array files
are the degenerate case.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any

import jax
import numpy as np

from repro.core.atomic import atomic_dir
from repro.core.quant import QuantizedTensor
from repro.core.sparsity import SparseQuantizedTensor

_SPECIALS = (QuantizedTensor, SparseQuantizedTensor)

# numpy can't round-trip ml_dtypes (bfloat16, fp8) through .npy cleanly —
# store them bit-exactly as unsigned views + a dtype tag
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name]), name
    return arr, name


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_paths(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, _SPECIALS))


def _path_str(path) -> str:
    parts = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            parts.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            parts.append(str(e.idx))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return "/".join(parts)


def write_state(final: str, state: dict[str, Any],
                extra: dict | None = None, step: int = 0) -> str:
    """Atomically write ``state`` (arbitrary pytree dict) to directory
    ``final`` in the manifest+arrays format.  Used by both training
    checkpoints (as ``step_*`` dirs) and serving snapshots."""
    with atomic_dir(final) as tmp:
        os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
        leaves, treedef = _flatten_with_paths(state)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (path, leaf) in enumerate(leaves):
            entry: dict[str, Any] = {"path": _path_str(path), "id": i}
            if isinstance(leaf, _SPECIALS):
                entry["kind"] = type(leaf).__name__
                entry["meta"] = {"shape": list(leaf.shape),
                                 "group_size": leaf.group_size}
                if isinstance(leaf, SparseQuantizedTensor):
                    entry["meta"]["density"] = leaf.density
                    entry["meta"]["tile_uniform"] = leaf.tile_uniform
                sub = leaf.tree_flatten()[0]
                entry["fields"] = []
                entry["field_dtypes"] = []
                for j, arr in enumerate(sub):
                    fn = f"{i:05d}_{j}.npy"
                    sav, dt = _to_savable(np.asarray(jax.device_get(arr)))
                    np.save(os.path.join(tmp, "arrays", fn), sav)
                    entry["fields"].append(fn)
                    entry["field_dtypes"].append(dt)
            else:
                fn = f"{i:05d}.npy"
                sav, dt = _to_savable(np.asarray(jax.device_get(leaf)))
                np.save(os.path.join(tmp, "arrays", fn), sav)
                entry["file"] = fn
                entry["dtype"] = dt
            manifest["leaves"].append(entry)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    return final


def read_state(d: str, like: dict[str, Any],
               shardings: Any = None) -> tuple[dict[str, Any], dict]:
    """Read a ``write_state`` directory into the structure of ``like``
    (shape/dtype tree), placing leaves with ``shardings`` (same tree
    structure) if given — the elastic-resharding path: stored full arrays
    are re-partitioned for whatever mesh the restoring job runs on."""
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    leaves, treedef = _flatten_with_paths(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = _flatten_with_paths(shardings)[0]

    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    for i, (path, leaf) in enumerate(leaves):
        entry = by_path[_path_str(path)]
        sharding = shard_leaves[i][1] if shard_leaves else None
        if isinstance(leaf, _SPECIALS):
            arrs = [_from_savable(np.load(os.path.join(d, "arrays", fn)), dt)
                    for fn, dt in zip(entry["fields"], entry["field_dtypes"])]
            sub_shard = (sharding.tree_flatten()[0]
                         if isinstance(sharding, _SPECIALS) else
                         [None] * len(arrs))
            placed = [jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                      for a, s in zip(arrs, sub_shard)]
            meta = entry["meta"]
            if entry["kind"] == "SparseQuantizedTensor":
                out.append(SparseQuantizedTensor(
                    placed[0], placed[1], placed[2],
                    tuple(meta["shape"]), meta["density"], meta["group_size"],
                    meta.get("tile_uniform", False)))
            else:
                out.append(QuantizedTensor(
                    placed[0], placed[1], tuple(meta["shape"]),
                    meta["group_size"]))
        else:
            arr = _from_savable(np.load(os.path.join(d, "arrays", entry["file"])),
                                entry["dtype"])
            target_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            arr = arr.astype(target_dtype)
            if sharding is not None:
                out.append(jax.device_put(arr, sharding))
            else:
                out.append(jax.numpy.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, manifest["extra"]


def save(ckpt_dir: str, step: int, state: dict[str, Any],
         extra: dict | None = None, keep: int = 3) -> str:
    """state: arbitrary pytree dict (params, opt_state, ...)."""
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    write_state(final, state, extra, step)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        (d for d in os.listdir(ckpt_dir) if re.match(r"step_\d+$", d)))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if re.match(r"step_\d+$", d)]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: dict[str, Any],
            shardings: Any = None) -> tuple[dict[str, Any], dict]:
    """Restore into the structure of ``like`` — see ``read_state``."""
    return read_state(os.path.join(ckpt_dir, f"step_{step:09d}"),
                      like, shardings)
