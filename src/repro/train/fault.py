"""Fault tolerance: preemption handling, straggler detection, restart policy.

This container is one host, so multi-host failures are *simulated* — but the
control logic is the real thing a 1000-node job needs, and the tests drive
it through failure scenarios:

* ``PreemptionGuard`` — converts SIGTERM/SIGINT (the TPU preemption notice)
  into a "checkpoint now, then exit cleanly" request the train loop polls;
* ``StragglerWatchdog`` — per-step wall-time EWMA; a step slower than
  ``threshold ×`` the EWMA marks a straggler incident; ``trip_limit``
  consecutive incidents escalate to a relayout request (on a real pod:
  checkpoint + restart excluding the slow host; here: the callback);
* ``RestartPolicy`` — bounded exponential backoff with a failure budget
  (gives up after ``max_restarts`` within ``window_s``);
* ``run_resumable`` — the glue: resume from the latest checkpoint, step
  until done, checkpoint every N steps and on preemption.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax

from repro.train import checkpoint as ckpt_lib


class PreemptionGuard:
    """SIGTERM-safe: flips a flag the loop polls; second signal raises."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._old = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        if self._requested:
            raise KeyboardInterrupt("second preemption signal")
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self):  # for tests / manual triggering
        self._requested = True


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.0          # step slower than 2x EWMA = incident
    trip_limit: int = 3             # consecutive incidents before escalation
    alpha: float = 0.2              # EWMA smoothing
    warmup_steps: int = 3

    _ewma: float = 0.0
    _steps: int = 0
    _consecutive: int = 0
    incidents: int = 0
    escalations: int = 0

    def observe(self, step_time_s: float,
                on_escalate: Callable[[], None] | None = None) -> bool:
        """Returns True if this step was a straggler incident."""
        self._steps += 1
        if self._steps <= self.warmup_steps:
            self._ewma = (step_time_s if self._ewma == 0 else
                          (1 - self.alpha) * self._ewma + self.alpha * step_time_s)
            return False
        is_incident = step_time_s > self.threshold * self._ewma
        if is_incident:
            self.incidents += 1
            self._consecutive += 1
            if self._consecutive >= self.trip_limit:
                self.escalations += 1
                self._consecutive = 0
                if on_escalate:
                    on_escalate()
        else:
            self._consecutive = 0
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_time_s
        return is_incident


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    window_s: float = 3600.0
    base_backoff_s: float = 1.0
    max_backoff_s: float = 60.0

    _failures: list = dataclasses.field(default_factory=list)

    def record_failure(self, now: float | None = None) -> float | None:
        """Returns backoff seconds, or None if the budget is exhausted."""
        now = time.monotonic() if now is None else now
        self._failures = [t for t in self._failures if now - t < self.window_s]
        self._failures.append(now)
        if len(self._failures) > self.max_restarts:
            return None
        return min(self.base_backoff_s * 2 ** (len(self._failures) - 1),
                   self.max_backoff_s)


def run_resumable(
    *,
    ckpt_dir: str,
    total_steps: int,
    init_state: Callable[[], dict],
    step_fn: Callable[[dict, int], tuple[dict, dict]],
    ckpt_every: int = 50,
    guard: PreemptionGuard | None = None,
    watchdog: StragglerWatchdog | None = None,
    shardings: Any = None,
    on_metrics: Callable[[int, dict], None] | None = None,
) -> tuple[dict, int, bool]:
    """Resume-from-latest training driver.

    Returns (state, last_step, completed).  ``completed`` is False when a
    preemption checkpoint-and-exit happened.
    """
    start = ckpt_lib.latest_step(ckpt_dir)
    if start is not None:
        state, _extra = ckpt_lib.restore(ckpt_dir, start, init_state(), shardings)
        step0 = start
    else:
        state = init_state()
        step0 = 0

    step = step0
    for step in range(step0, total_steps):
        t0 = time.monotonic()
        state, metrics = step_fn(state, step)
        dt = time.monotonic() - t0
        if watchdog is not None:
            watchdog.observe(dt)
        if on_metrics:
            on_metrics(step, metrics)
        done = step + 1
        if guard is not None and guard.preempted:
            ckpt_lib.save(ckpt_dir, done, state)
            return state, done, False
        if done % ckpt_every == 0 or done == total_steps:
            ckpt_lib.save(ckpt_dir, done, state)
    return state, step + 1, True
