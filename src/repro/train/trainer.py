"""Training step: microbatched gradient accumulation + AdamW (ZeRO-sharded).

``make_train_step(cfg, opt, accum_steps)`` builds a pure function

    (params_f32, opt_state, batch, rng) -> (params, opt_state, metrics)

* params are f32 masters; each microbatch casts to ``cfg.dtype`` (bf16)
  before the forward — one cast per step, amortized across microbatches;
* the global batch (G, S) is reshaped to (A, G/A, S) and scanned, gradients
  accumulate in f32 with the same sharding as the params (so accumulation
  never gathers — ZeRO-2 behaviour for grads, ZeRO-3 for states);
* optional int8 gradient *compression* emulation for the cross-pod
  all-reduce (stochastic-rounding quantize/dequantize around the mean) —
  the distributed-optimization trick is exercised numerically; the actual
  wire compression is a runtime concern XLA owns.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW


def cast_tree(tree, dtype):
    def f(x):
        if isinstance(x, jax.Array) or hasattr(x, "dtype"):
            if jnp.issubdtype(x.dtype, jnp.floating):
                return x.astype(dtype)
        return x
    return jax.tree.map(f, tree)


def _grad_compress_int8(tree, rng):
    """Stochastic-rounding int8 quantize/dequantize of gradients — models
    low-precision gradient exchange (per-leaf absmax scale)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        a = jnp.max(jnp.abs(g)) + 1e-12
        scale = a / 127.0
        noise = jax.random.uniform(k, g.shape, jnp.float32, -0.5, 0.5)
        q = jnp.clip(jnp.round(g / scale + noise), -127, 127)
        out.append(q * scale)
    return jax.tree.unflatten(treedef, out)


def make_train_step(cfg: ModelConfig, opt: AdamW, accum_steps: int = 1,
                    compress_grads: bool = False, grad_specs=None):
    """grad_specs: optional PartitionSpec tree matching the params — each
    microbatch's gradients are constrained to it inside the accumulation
    scan, which turns the per-microbatch full-size grad all-reduce into a
    reduce-scatter (4.5 TB -> ~0.3 TB per step on mixtral train;
    EXPERIMENTS.md §Perf iteration 2)."""

    def _constrain(g):
        from repro.parallel.hints import active_mesh
        if grad_specs is None or active_mesh() is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), g, grad_specs)

    def train_step(params, opt_state, batch, rng):
        compute_params = cast_tree(params, cfg.dtype)

        def loss_of(p, mb):
            loss, metrics = api.loss_fn(cfg, p, mb)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_of, has_aux=True)

        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(compute_params, batch)
            grads = _constrain(grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]),
                batch)

            def body(acc, mb):
                gsum, lsum = acc
                (l, _), g = grad_fn(compute_params, mb)
                g = _constrain(g)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0)), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {"loss": loss, "aux": jnp.float32(0)}

        if compress_grads:
            grads = _grad_compress_int8(grads, rng)

        new_params, new_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_params, new_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt: AdamW, rng):
    """f32 master params + optimizer state."""
    import dataclasses
    cfg32 = dataclasses.replace(cfg, dtype=jnp.float32)
    params = api.init_params(cfg32, rng)
    return params, opt.init(params)
