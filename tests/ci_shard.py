"""Deterministic two-way shard split of the test suite for CI.

The suite is past 300 tests and the CI runner is 2-core, so the workflow
runs two parallel shard jobs, each with the tier-1 ``-x -q`` semantics.
Shards are whole FILES (pytest's per-file fixtures/caches stay warm) packed
greedily by a static runtime weight; unknown new test files pick up a
default weight, so adding a file never drops it from CI.

Usage:  python tests/ci_shard.py <1|2>     -> space-separated file list
        python tests/ci_shard.py --check   -> print both shards
"""

from __future__ import annotations

import pathlib
import sys

# coarse relative runtimes (measured on the 2-core CI runner); the exact
# numbers only matter for balance, not correctness
WEIGHTS = {
    "test_archs.py": 10,
    "test_decode_kernel.py": 6,
    "test_distribution.py": 8,
    "test_ffn_fused.py": 6,
    "test_kernels.py": 4,
    "test_mixed.py": 12,
    "test_paged_engine.py": 7,
    "test_paged_fuzz.py": 3,
    "test_quant.py": 2,
    "test_serving.py": 5,
    "test_sparsity.py": 2,
    "test_substrate.py": 3,
}
DEFAULT_WEIGHT = 4
N_SHARDS = 2


def shards() -> list[list[str]]:
    tests_dir = pathlib.Path(__file__).parent
    files = sorted(p.name for p in tests_dir.glob("test_*.py"))
    # greedy longest-processing-time packing: deterministic for a given
    # file set (sorted by weight desc, then name; ties to the lighter shard)
    order = sorted(files, key=lambda f: (-WEIGHTS.get(f, DEFAULT_WEIGHT), f))
    buckets: list[list[str]] = [[] for _ in range(N_SHARDS)]
    loads = [0] * N_SHARDS
    for f in order:
        i = loads.index(min(loads))
        buckets[i].append(f)
        loads[i] += WEIGHTS.get(f, DEFAULT_WEIGHT)
    return [sorted(b) for b in buckets]


def main() -> None:
    arg = sys.argv[1] if len(sys.argv) > 1 else "--check"
    parts = shards()
    if arg == "--check":
        for i, part in enumerate(parts, 1):
            print(f"shard {i}: {' '.join(part)}")
        return
    idx = int(arg) - 1
    if not 0 <= idx < N_SHARDS:
        raise SystemExit(f"shard must be 1..{N_SHARDS}, got {arg}")
    print(" ".join(f"tests/{f}" for f in parts[idx]))


if __name__ == "__main__":
    main()
