"""Deterministic two-way shard split of the test suite for CI.

The suite is past 350 tests and the CI runner is 2-core, so the workflow
runs two parallel shard jobs, each with the tier-1 ``-x -q`` semantics.
Shards are whole FILES (pytest's per-file fixtures/caches stay warm) packed
greedily by COLLECTED TEST COUNT (``pytest --collect-only -q``; the
hypothesis-gated files count their test functions); unknown new test files
pick up a default weight, so adding a file never drops it from CI — and
``--assert-partition`` makes that a checked invariant: every
``tests/test_*.py`` lands in exactly one shard.

Usage:  python tests/ci_shard.py <1|2>               -> shard's file list
        python tests/ci_shard.py --check             -> print both shards
        python tests/ci_shard.py --assert-partition  -> exit 1 on any file
                                                        missing/duplicated
"""

from __future__ import annotations

import pathlib
import sys

# collected-test counts (refresh with: pytest --collect-only -q tests/);
# the exact numbers only matter for balance, not correctness
WEIGHTS = {
    "test_archs.py": 45,
    "test_chaos.py": 11,
    "test_decode_kernel.py": 79,
    "test_distribution.py": 12,
    "test_ffn_fused.py": 42,
    "test_kernels.py": 45,
    "test_lifecycle.py": 18,
    # 17 collected, weighted up: its 8-device subprocess worker re-imports
    # jax and compiles the sharded paths — wall-clock like ~40 plain tests
    "test_mesh_serving.py": 40,
    "test_mixed.py": 27,
    "test_paged_engine.py": 11,
    "test_paged_fuzz.py": 14,
    "test_prefix.py": 27,
    "test_quant.py": 10,
    "test_serving.py": 12,
    "test_snapshot.py": 15,
    "test_sparsity.py": 14,
    "test_spec.py": 27,
    "test_substrate.py": 24,
}
DEFAULT_WEIGHT = 15
N_SHARDS = 2


def _test_files() -> list[str]:
    tests_dir = pathlib.Path(__file__).parent
    return sorted(p.name for p in tests_dir.glob("test_*.py"))


def shards() -> list[list[str]]:
    # greedy longest-processing-time packing: deterministic for a given
    # file set (sorted by weight desc, then name; ties to the lighter shard)
    order = sorted(_test_files(),
                   key=lambda f: (-WEIGHTS.get(f, DEFAULT_WEIGHT), f))
    buckets: list[list[str]] = [[] for _ in range(N_SHARDS)]
    loads = [0] * N_SHARDS
    for f in order:
        i = loads.index(min(loads))
        buckets[i].append(f)
        loads[i] += WEIGHTS.get(f, DEFAULT_WEIGHT)
    return [sorted(b) for b in buckets]


def assert_partition() -> None:
    """Every tests/test_*.py in EXACTLY one shard — catches a future edit
    that hand-curates shard lists and silently drops a file from CI."""
    files = _test_files()
    placed = [f for part in shards() for f in part]
    dupes = sorted({f for f in placed if placed.count(f) > 1})
    missing = sorted(set(files) - set(placed))
    foreign = sorted(set(placed) - set(files))
    if dupes or missing or foreign:
        raise SystemExit(f"shard partition broken: duplicated={dupes} "
                         f"missing={missing} foreign={foreign}")
    print(f"OK: {len(files)} test files partitioned into {N_SHARDS} shards")


def main() -> None:
    arg = sys.argv[1] if len(sys.argv) > 1 else "--check"
    if arg == "--assert-partition":
        assert_partition()
        return
    parts = shards()
    if arg == "--check":
        for i, part in enumerate(parts, 1):
            load = sum(WEIGHTS.get(f, DEFAULT_WEIGHT) for f in part)
            print(f"shard {i} ({load} tests): {' '.join(part)}")
        return
    idx = int(arg) - 1
    if not 0 <= idx < N_SHARDS:
        raise SystemExit(f"shard must be 1..{N_SHARDS}, got {arg}")
    print(" ".join(f"tests/{f}" for f in parts[idx]))


if __name__ == "__main__":
    main()
