"""Shared test setup.

``pyproject.toml``'s ``pythonpath = ["src"]`` covers in-process imports; this
conftest additionally exports ``PYTHONPATH=src`` so tests that spawn worker
subprocesses (e.g. the multi-device harness in test_distribution.py) work
under a bare ``python -m pytest`` too.
"""

import os

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
_existing = os.environ.get("PYTHONPATH", "")
if _SRC not in _existing.split(os.pathsep):
    os.environ["PYTHONPATH"] = _SRC + (os.pathsep + _existing if _existing else "")
