"""Per-architecture smoke tests: reduced config, one forward / train-grad /
prefill+decode step on CPU; assert output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config, get_config, SHAPES, skip_reason
from repro.models import api

ALL = ARCHS + ["chatglm-6b", "qwen-7b"]


def _batch(cfg, rng, batch=2, seq=16):
    tokens = jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)
    b = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(
            rng, (batch, cfg.encoder_frames, cfg.d_model), jnp.float32)
    return b


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_no_nans(arch, rng):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    logits, aux = api.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert not np.isnan(float(aux))


@pytest.mark.parametrize("arch", ALL)
def test_train_grad_step(arch, rng):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, rng)
    batch = _batch(cfg, rng)

    def loss(p):
        l, _ = api.loss_fn(cfg, p, batch)
        return l

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients"
    for g in leaves:
        assert not np.any(np.isnan(np.asarray(g, np.float32)))


@pytest.mark.parametrize("arch", ALL)
def test_prefill_then_decode(arch, rng):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, rng)
    batch = _batch(cfg, rng, batch=2, seq=8)
    max_len = 32
    logits, cache = api.prefill(cfg, params, batch, max_len)
    assert logits.shape == (2, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    # one decode step
    next_tok = jnp.argmax(logits, axis=-1)[:, None]
    logits2, cache2 = api.decode_step(cfg, params, cache, next_tok,
                                      jnp.int32(9))
    assert logits2.shape == (2, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits2, np.float32)))
    # cache tree structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "xlstm-1.3b", "zamba2-7b"])
def test_decode_matches_forward(arch, rng):
    """Sequential decode of a short prompt must agree with the parallel
    forward pass (the KV-cache / recurrent-state correctness invariant)."""
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, rng)
    seq = 8
    tokens = jax.random.randint(rng, (1, seq), 0, cfg.vocab_size)
    full_logits, _ = api.forward(cfg, params, {"tokens": tokens})

    cache = api.init_cache(cfg, 1, 16)
    outs = []
    for t in range(seq):
        logits, cache = api.decode_step(
            cfg, params, cache, tokens[:, t:t + 1], jnp.int32(t + 1))
        outs.append(logits)
    dec = np.stack([np.asarray(o, np.float32) for o in outs], axis=1)
    np.testing.assert_allclose(
        dec, np.asarray(full_logits, np.float32), rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """Pin the assignment-exact numbers for every full config."""
    expect = {
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, h, kv, ff, v), (arch, got)
    assert get_config("mixtral-8x22b").n_experts == 8
    assert get_config("mixtral-8x22b").top_k == 2
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("granite-moe-3b-a800m").top_k == 8
    assert get_config("zamba2-7b").ssm_state == 64


def test_skip_rules():
    # long_500k must run exactly for the sub-quadratic archs
    runs = [a for a in ARCHS if skip_reason(a, "long_500k") is None]
    assert sorted(runs) == ["mixtral-8x22b", "xlstm-1.3b", "zamba2-7b"]
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert skip_reason(a, s) is None


class TestMlstmChunked:
    """Chunkwise mLSTM == quadratic parallel form == recurrent decode."""

    def test_chunked_equals_parallel(self):
        import numpy as np
        from repro.models import xlstm
        rng = np.random.default_rng(0)
        b, h, L, dh = 2, 3, 200, 16
        mk = lambda *s: jnp.asarray(rng.normal(0, 1, s).astype(np.float32))
        q, k, v = mk(b, h, L, dh), mk(b, h, L, dh), mk(b, h, L, dh)
        ig, fg = mk(b, h, L), mk(b, h, L) + 2.0
        full = xlstm._mlstm_parallel(q, k, v, ig, fg)
        chunked = xlstm._mlstm_chunked(q, k, v, ig, fg, chunk=64)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)

    def test_chunked_equals_recurrent_decode(self):
        """xlstm smoke decode already validates recurrence == forward; here
        force the forward through the chunked path at L > chunk."""
        import numpy as np
        from repro.models import xlstm
        rng = np.random.default_rng(1)
        b, h, L, dh = 1, 2, 300, 8
        mk = lambda *s: jnp.asarray(rng.normal(0, 1, s).astype(np.float32))
        q, k, v = mk(b, h, L, dh), mk(b, h, L, dh), mk(b, h, L, dh)
        ig, fg = mk(b, h, L), mk(b, h, L) + 1.0
        chunked = xlstm._mlstm_chunked(q, k, v, ig, fg, chunk=128)
        full = xlstm._mlstm_parallel(q, k, v, ig, fg)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)


class TestChunkedPrefill:
    """Chunked (Sarathi-style) prefill == one-shot prefill: same last-token
    logits, same KV cache, same subsequent decode."""

    def _run(self, arch, seq, chunk, monkeypatch, **over):
        from repro.models import transformer
        cfg = get_smoke_config(arch, **over)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, seq), 0,
                                    cfg.vocab_size)
        max_len = seq + 16
        full_logits, full_cache = transformer.prefill(cfg, params, tokens, max_len)
        monkeypatch.setattr(transformer, "PREFILL_CHUNK", chunk)
        ch_logits, ch_cache = transformer.prefill(cfg, params, tokens, max_len)
        np.testing.assert_allclose(
            np.asarray(ch_logits, np.float32), np.asarray(full_logits, np.float32),
            rtol=2e-2, atol=2e-2)
        # decode one token from both caches
        nt = jnp.argmax(full_logits, axis=-1)[:, None]
        l1, _ = api.decode_step(cfg, params, full_cache, nt, jnp.int32(seq + 1))
        l2, _ = api.decode_step(cfg, params, ch_cache, nt, jnp.int32(seq + 1))
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_dense_arch(self, monkeypatch):
        self._run("qwen3-8b", seq=48, chunk=16, monkeypatch=monkeypatch)

    def test_swa_arch(self, monkeypatch):
        # mixtral smoke: window 64 == chunk (the rolling-buffer case).
        # capacity_factor high enough that no tokens drop — capacity-based
        # MoE drops depend on the routing-group length, so one-shot and
        # chunked prefill legitimately differ when tokens overflow.
        self._run("mixtral-8x22b", seq=192, chunk=64, monkeypatch=monkeypatch,
                  moe_capacity_factor=4.0)
