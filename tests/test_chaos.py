"""Chaos-harness tests (ISSUE 8): injector determinism, per-row fault
quarantine (NaN logits and throwing sample hooks), garbage-draft
losslessness, the audit()'s teeth, and a soak-cell subset (the full
6-cell matrix runs as the CI chaos-soak step)."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache
from repro.models import api
from repro.serving.chaos import (ChaosConfig, ChaosMonkey, SOAK_CELLS,
                                 run_soak_cell)
from repro.serving.engine import Engine, Request, reference_decode

_REF_CC = CompileCache()


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                           kv_layout="paged", kv_block_size=8,
                           kv_pool_blocks=24)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, rng, n, max_new=6):
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 17))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


# -- injector determinism ---------------------------------------------------

def test_chaos_monkey_same_seed_same_faults():
    a = ChaosMonkey(ChaosConfig(seed=7, deny_rate=0.3, preempt_rate=0.3,
                                nan_rate=0.3, garbage_draft_rate=0.3))
    b = ChaosMonkey(ChaosConfig(seed=7, deny_rate=0.3, preempt_rate=0.3,
                                nan_rate=0.3, garbage_draft_rate=0.3))
    trace_a, trace_b = [], []
    for m, t in ((a, trace_a), (b, trace_b)):
        for _ in range(50):
            t.append(m.deny_reservation())
            t.append(m.forced_preempt([0, 1, 2]))
            t.append(tuple(m.corrupt_rows([0, 1, 2, 3])))
            t.append(tuple(m.garble_draft([5, 6, 7], 256)))
    assert trace_a == trace_b
    assert a.stats() == b.stats()
    c = ChaosMonkey(seed=8, deny_rate=0.3, preempt_rate=0.3,
                    nan_rate=0.3, garbage_draft_rate=0.3)
    assert [c.deny_reservation() for _ in range(50)] != trace_a[::4]


def test_soak_cell_is_reproducible(setup):
    """Same (cell, seed) → identical outcomes AND identical injected-fault
    counters, end to end through a real engine."""
    first = run_soak_cell("paged", "paged", "none", 0, False,
                          seed=3, n_requests=6)
    second = run_soak_cell("paged", "paged", "none", 0, False,
                           seed=3, n_requests=6)
    assert first == second


def test_zero_rates_inject_nothing(setup):
    """A ChaosMonkey with all-zero rates is a no-op: the run matches the
    chaos-free engine bitwise and counts zero injections."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = _reqs(cfg, rng, 4)
    oracle = {r.rid: reference_decode(cfg, params, r.prompt,
                                      r.max_new_tokens, max_len=64,
                                      compile_cache=_REF_CC)
              for r in reqs}
    monkey = ChaosMonkey(seed=0)
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 chaos=monkey, audit_every=1)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.status == "done" and r.output == oracle[r.rid]
               for r in reqs)
    assert all(v == 0 for v in monkey.injected.values())


# -- per-row fault isolation ------------------------------------------------

def test_nan_rate_one_quarantines_everything_pool_intact(setup):
    """nan_rate=1.0: every advancing row faults at its first dispatch.
    All requests end status="error" with empty output, and the pool comes
    back fully free — quarantine leaks nothing."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    reqs = _reqs(cfg, rng, 5)
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 chaos=ChaosMonkey(seed=0, nan_rate=1.0), audit_every=1)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert done.drained
    assert all(r.status == "error" and r.error == "non-finite logits"
               for r in reqs)
    assert eng.row_faults == 5
    assert eng.alloc.n_free == eng.pool_blocks      # nothing leaked
    eng.audit()


def test_nan_row_never_donated_to_prefix_cache(setup):
    """A faulted row's blocks are freed, NOT donated: the prefix cache
    must never serve KV pages that came from a quarantined row."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    reqs = _reqs(cfg, rng, 3)
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 prefix_cache=True,
                 chaos=ChaosMonkey(seed=0, nan_rate=1.0), audit_every=1)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.status == "error" for r in reqs)
    # faulted before any prompt completed → nothing was cacheable
    assert not eng.prefix.blocks()
    assert eng.alloc.n_free == eng.pool_blocks


def test_sample_hook_exception_quarantines_only_that_row(setup):
    """A throwing sample hook errors the row it fired on; the other
    request still finishes bitwise equal to the oracle."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    a = Request(rid=0, prompt=rng.integers(0, 256, 6).astype(np.int32),
                max_new_tokens=6)
    b = Request(rid=1, prompt=rng.integers(0, 256, 6).astype(np.int32),
                max_new_tokens=6)
    ref_a = reference_decode(cfg, params, a.prompt, 6, max_len=64,
                             compile_cache=_REF_CC)
    eng = Engine(cfg, params, batch_size=1, max_len=64, chunk_size=16,
                 audit_every=1)
    eng.submit(a)
    eng.submit(b)

    def sample(row):
        if b.status == "running":       # batch_size=1: b's own row
            raise RuntimeError("boom")
        return int(np.argmax(row))

    done = eng.run(sample=sample)
    assert done.drained
    assert a.status == "done" and a.output == ref_a
    assert b.status == "error" and "boom" in b.error
    assert eng.row_faults == 1
    assert eng.alloc.n_free == eng.pool_blocks


# -- garbage drafts ---------------------------------------------------------

def test_garbage_drafts_are_lossless(setup):
    """garbage_draft_rate=1.0: every draft is junk.  Greedy verification
    rejects them; outputs stay bitwise the oracle's, at near-zero
    acceptance."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    reqs = _reqs(cfg, rng, 4, max_new=8)
    oracle = {r.rid: reference_decode(cfg, params, r.prompt, 8, max_len=64,
                                      compile_cache=_REF_CC)
              for r in reqs}
    monkey = ChaosMonkey(seed=0, garbage_draft_rate=1.0)
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 spec_k=3, chaos=monkey, audit_every=1)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.status == "done" and r.output == oracle[r.rid]
               for r in reqs)
    assert monkey.injected["garbled_drafts"] > 0
    # random junk over a 256-token vocab essentially never verifies
    s = eng.spec_stats()
    assert s["acceptance_rate"] < 0.25


# -- deadline storm ---------------------------------------------------------

def test_deadline_storm_kills_only_deadlined_rows(setup):
    """Half the workload carries deadline_s=0.0 (guaranteed storm): those
    rows all miss; the rest drain bitwise-correct."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    reqs = _reqs(cfg, rng, 6)
    oracle = {r.rid: reference_decode(cfg, params, r.prompt,
                                      r.max_new_tokens, max_len=64,
                                      compile_cache=_REF_CC)
              for r in reqs}
    for r in reqs:
        if r.rid % 2:
            r.deadline_s = 0.0
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 audit_every=1)
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        if r.rid % 2:
            assert r.status == "deadline_missed"
        else:
            assert r.status == "done" and r.output == oracle[r.rid]
    assert eng.deadline_misses == 3
    assert eng.alloc.n_free == eng.pool_blocks


# -- the audit has teeth ----------------------------------------------------

def test_audit_catches_corrupted_state(setup):
    """audit() must FAIL on a genuinely corrupt engine — otherwise the
    soak's per-tick green audits prove nothing."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16)
    eng.submit(Request(rid=0, prompt=rng.integers(0, 256, 8)
                       .astype(np.int32), max_new_tokens=4))
    eng.run()
    eng.audit()                         # clean after drain
    eng._slot_reserve[0] = eng.pool_blocks + 1   # over-reservation
    with pytest.raises(AssertionError):
        eng.audit()
    eng._slot_reserve[0] = 0
    eng.audit()
    eng._slot_blocks[0] = [0]           # dead slot claiming a block
    with pytest.raises(AssertionError):
        eng.audit()
    eng._slot_blocks[0] = []
    eng.audit()


# -- soak subset (full matrix = CI chaos-soak step) -------------------------

@pytest.mark.parametrize("cell", [SOAK_CELLS[0], SOAK_CELLS[-1]],
                         ids=lambda c: c[0])
def test_soak_cell_subset(cell):
    stats = run_soak_cell(*cell, seed=0, n_requests=8)
    outcomes = stats["outcomes"]
    assert sum(outcomes.values()) == 8
    assert outcomes.get("done", 0) >= 1     # chaos didn't kill everything
