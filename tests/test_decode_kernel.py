"""Flash-decoding kernel sweeps: Pallas (interpret) vs length-blocked XLA vs
the dense full-cache oracle, across {GQA, MQA} x {fp16, int8-KV} x {ragged
lengths, rolling SWA} x B in {1, 4} — plus an engine-level check that the
batched slot engine still matches the batch-1 oracle token-for-token with the
new decode path (and an int8 cache) enabled."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models.attention import quantize_kv

TOL = dict(rtol=3e-2, atol=3e-2)


def _rand(shape, seed=0, dtype=jnp.bfloat16):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 1, shape).astype(np.float32)).astype(dtype)


def _operands(B, hq, hkv, S, d, quant, seed=0):
    q = _rand((B, hq, 1, d), seed=seed)
    k = _rand((B, hkv, S, d), seed=seed + 1)
    v = _rand((B, hkv, S, d), seed=seed + 2)
    ks = vs = None
    if quant:
        k, ks = quantize_kv(k)
        v, vs = quantize_kv(v)
    return q, k, v, ks, vs


def _check(impl, q, k, v, lengths, ks, vs, window=None):
    want = ops.decode_attention(q, k, v, lengths, window=window,
                                k_scale=ks, v_scale=vs, impl="ref")
    got = ops.decode_attention(q, k, v, lengths, window=window,
                               k_scale=ks, v_scale=vs, impl=impl)
    assert got.shape == want.shape == q.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL)


@pytest.mark.parametrize("impl", ["pallas", "xla"])
@pytest.mark.parametrize("B", [1, 4])
@pytest.mark.parametrize("hq,hkv", [(8, 2), (4, 1), (4, 4)])  # GQA/MQA/MHA
@pytest.mark.parametrize("quant", [False, True])
class TestDecodeParity:
    S, d = 256, 64

    def test_ragged_lengths(self, impl, B, hq, hkv, quant):
        q, k, v, ks, vs = _operands(B, hq, hkv, self.S, self.d, quant,
                                    seed=B + hq)
        lengths = jnp.asarray([self.S, 100, 17, 1][:B], jnp.int32)
        _check(impl, q, k, v, lengths, ks, vs)

    def test_sliding_window(self, impl, B, hq, hkv, quant):
        q, k, v, ks, vs = _operands(B, hq, hkv, self.S, self.d, quant,
                                    seed=B + hq + 7)
        lengths = jnp.asarray([200, 64, 130, 65][:B], jnp.int32)
        _check(impl, q, k, v, lengths, ks, vs, window=64)

    def test_rolling_swa(self, impl, B, hq, hkv, quant):
        """Rolling buffer contract (cache_len <= window): the caller clamps
        lengths to the buffer size and drops the window — every slot below
        min(length, S) participates, slot order irrelevant."""
        q, k, v, ks, vs = _operands(B, hq, hkv, self.S, self.d, quant,
                                    seed=B + hq + 13)
        raw = jnp.asarray([1000, 256, 300, 80][:B], jnp.int32)
        _check(impl, q, k, v, jnp.minimum(raw, self.S), ks, vs)


class TestDecodeDispatch:
    def test_scalar_length_matches_vector(self):
        q, k, v, _, _ = _operands(2, 4, 2, 128, 32, False)
        a = ops.decode_attention(q, k, v, 77, impl="xla")
        b = ops.decode_attention(q, k, v, jnp.full((2,), 77, jnp.int32),
                                 impl="pallas")
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **TOL)

    def test_non_divisor_max_len(self):
        """A cache length with no block-size divisor (prime max_len): the
        blocked path clamps the final block's slice and masks the re-covered
        positions instead of degrading to 1-token blocks."""
        q, k, v, _, _ = _operands(2, 4, 2, 331, 32, False, seed=21)
        lengths = jnp.asarray([331, 57], jnp.int32)
        _check("xla", q, k, v, lengths, None, None)
        _check("xla", q, k, v, lengths, None, None, window=48)

    def test_unknown_impl_raises(self):
        q, k, v, _, _ = _operands(1, 2, 2, 64, 32, False)
        with pytest.raises(ValueError, match="unknown impl"):
            ops.decode_attention(q, k, v, 8, impl="einsum")

    def test_scale_threading(self):
        """A non-default scale reaches every impl (the old dispatch dropped
        impl on the floor; scale/window now ride through all paths)."""
        q, k, v, _, _ = _operands(2, 4, 2, 128, 32, False, seed=3)
        lengths = jnp.asarray([128, 40], jnp.int32)
        outs = [ops.decode_attention(q, k, v, lengths, scale=0.25, impl=i)
                for i in ("ref", "xla", "pallas")]
        base = ops.decode_attention(q, k, v, lengths, impl="ref")
        assert not np.allclose(np.asarray(outs[0], np.float32),
                               np.asarray(base, np.float32))
        for got in outs[1:]:
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(outs[0], np.float32), **TOL)

    def test_blocked_batch_max_invariance(self):
        """A row's result must not depend on how far *other* rows extend the
        while_loop (blocks past a row's context contribute exact zeros) —
        the property that keeps the batched engine equal to the batch-1
        oracle bit for bit."""
        q, k, v, _, _ = _operands(4, 4, 2, 512, 32, False, seed=9)
        short = ops.decode_attention(q[:1], k[:1], v[:1],
                                     jnp.asarray([70], jnp.int32), impl="xla")
        mixed = ops.decode_attention(q, k, v,
                                     jnp.asarray([70, 512, 300, 1], jnp.int32),
                                     impl="xla")
        np.testing.assert_array_equal(np.asarray(short), np.asarray(mixed[:1]))


class TestEngineFusedPath:
    """Engine-level: the slot engine on the new decode path (int8 KV cache,
    GQA smoke config) still matches per-request batch-1 greedy decode
    token-for-token."""

    @pytest.mark.parametrize("kv_quant", ["none", "int8"])
    def test_matches_reference_decode(self, kv_quant):
        from repro.configs import get_smoke_config
        from repro.core.compiler import CompileCache, quantize_model
        from repro.models import api
        from repro.serving.engine import Engine, Request, reference_decode
        cfg = get_smoke_config("qwen3-8b", kv_quant=kv_quant)
        params = quantize_model(api.init_params(cfg, jax.random.PRNGKey(0)),
                                "dense")
        rng = np.random.default_rng(4)
        reqs = [Request(rid=i,
                        prompt=rng.integers(
                            0, cfg.vocab_size,
                            int(rng.integers(3, 14))).astype(np.int32),
                        max_new_tokens=int(rng.integers(3, 6)))
                for i in range(5)]
        engine = Engine(cfg, params, batch_size=2, max_len=32)
        for r in reqs:
            engine.submit(r)
        done = engine.run()
        assert len(done) == len(reqs)
        cc = CompileCache()
        for r in done:
            ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                                   max_len=32, compile_cache=cc)
            assert r.output == ref, f"req {r.rid} diverged from batch-1 oracle"
