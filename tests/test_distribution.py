"""Distribution tests — run in a subprocess with 8 host devices (the main
pytest process must keep 1 device for everything else).

Covers: shard_map MoE == local MoE numerics, sharding-spec legality,
trainer grad-accum equivalence, mesh construction, hint no-op behaviour.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as shd
from repro.parallel.hints import hint, active_mesh

_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, dataclasses
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.models import api, moe
    from repro.parallel import sharding as shd
    from repro.parallel.hints import use_mesh
    from repro.optim.adamw import AdamW
    from repro.train import trainer

    out = {}
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    # --- shard_map MoE vs local MoE numerics -----------------------------
    cfg = get_smoke_config("mixtral-8x22b", d_ff=64, dtype=jnp.float32)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, cfg.d_model), jnp.float32)
    local_out, local_aux = moe._moe_apply_local(cfg, p, x)
    with use_mesh(mesh):
        sm_out, sm_aux = jax.jit(lambda p, x: moe.moe_apply(cfg, p, x))(p, x)
    out["moe_max_err"] = float(jnp.max(jnp.abs(local_out - sm_out)))
    out["moe_aux_err"] = float(jnp.abs(local_aux - sm_aux))

    # --- train step under mesh == train step without mesh ----------------
    cfg2 = get_smoke_config("qwen3-8b")
    opt = AdamW(lr=1e-3, grad_clip=None, weight_decay=0.0)
    params, opt_state = trainer.init_train_state(cfg2, opt, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(3), (8, 16), 0, cfg2.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(4), (8, 16), 0, cfg2.vocab_size),
    }
    rng = jax.random.PRNGKey(5)

    params_shape = jax.eval_shape(lambda: params)
    specs = shd.param_specs(params_shape, mesh, "train")
    step_plain = trainer.make_train_step(cfg2, opt, accum_steps=2)
    step_mesh = trainer.make_train_step(cfg2, opt, accum_steps=2, grad_specs=specs)

    p1, _, m1 = jax.jit(step_plain)(params, opt_state, batch, rng)
    with use_mesh(mesh):
        p_sh = shd.shardings_for(params_shape, mesh, "train")
        o_sh = shd.shardings_for(jax.eval_shape(lambda: opt_state), mesh, "train")
        p2, _, m2 = jax.jit(step_mesh, in_shardings=(p_sh, o_sh, None, None))(
            params, opt_state, batch, rng)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    out["train_max_param_diff"] = max(jax.tree.leaves(diffs))
    out["loss_plain"] = float(m1["loss"]); out["loss_mesh"] = float(m2["loss"])

    # --- decode step compiles + runs under serve shardings ---------------
    cfg3 = get_smoke_config("gemma-2b")
    sp = api.init_params(cfg3, jax.random.PRNGKey(7))
    cache = api.init_cache(cfg3, 8, 64)
    with use_mesh(mesh):
        c_sh = shd.kv_cache_specs(jax.eval_shape(lambda: cache), mesh, 8)
        logits, new_cache = jax.jit(
            lambda p, c, t, l: api.decode_step(cfg3, p, c, t, l),
        )(sp, cache, jnp.zeros((8, 1), jnp.int32), jnp.int32(5))
    out["decode_ok"] = bool(np.isfinite(np.asarray(logits, np.float32)).all())

    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def worker_result():
    proc = subprocess.run(
        [sys.executable, "-c", _WORKER], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(
        f"worker failed:\nstdout={proc.stdout[-2000:]}\nstderr={proc.stderr[-3000:]}")


class TestShardMapMoE:
    def test_matches_local_path(self, worker_result):
        assert worker_result["moe_max_err"] < 2e-4
        assert worker_result["moe_aux_err"] < 1e-5


class TestDistributedTrainStep:
    def test_sharded_equals_unsharded(self, worker_result):
        assert worker_result["loss_plain"] == pytest.approx(
            worker_result["loss_mesh"], rel=1e-4)
        assert worker_result["train_max_param_diff"] < 5e-3

    def test_decode_under_mesh(self, worker_result):
        assert worker_result["decode_ok"]


class TestShardingRules:
    def _mesh(self):
        # single-device "mesh" is enough to compute specs
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_col_row_parallel_specs(self):
        mesh = self._mesh()
        tree = {
            "attn": {"wq": jax.ShapeDtypeStruct((256, 512), np.float32),
                     "wo": jax.ShapeDtypeStruct((512, 256), np.float32)},
            "ln": {"gamma": jax.ShapeDtypeStruct((256,), np.float32)},
        }
        specs = shd.param_specs(tree, mesh, "train")
        assert specs["attn"]["wq"] == P(("data",), "model")
        assert specs["attn"]["wo"] == P("model", ("data",))
        assert specs["ln"]["gamma"] == P()

    def test_moe_expert_specs_match_shard_map(self):
        mesh = self._mesh()
        tree = {"moe": {
            "gate": jax.ShapeDtypeStruct((4, 8, 256, 512), np.float32),
            "down": jax.ShapeDtypeStruct((4, 8, 512, 256), np.float32),
            "router": jax.ShapeDtypeStruct((256, 8), np.float32),
        }}
        specs = shd.param_specs(tree, mesh, "train")
        assert specs["moe"]["gate"] == P(None, None, None, ("data", "model"))
        assert specs["moe"]["down"] == P(None, None, ("data", "model"))
        assert specs["moe"]["router"] == P()

    def test_serve_mode_no_fsdp(self):
        mesh = self._mesh()
        tree = {"mlp": {"up": jax.ShapeDtypeStruct((256, 512), np.float32)}}
        specs = shd.param_specs(tree, mesh, "serve")
        assert specs["mlp"]["up"] == P(None, "model")

    def test_indivisible_dims_drop_sharding(self):
        mesh = jax.make_mesh((1,), ("model",))
        tree = {"attn": {"wq": jax.ShapeDtypeStruct((100, 7), np.float32)}}
        specs = shd.param_specs(tree, mesh, "serve")
        assert specs["attn"]["wq"] == P(None, "model")  # 7 % 1 == 0 fine
        mesh2 = jax.make_mesh((1, 1), ("data", "model"))
        # legalization keeps only divisible axes
        t2 = {"attn": {"wq": jax.ShapeDtypeStruct((3, 5), np.float32)}}
        s2 = shd.param_specs(t2, mesh2, "train")
        assert s2["attn"]["wq"] == P(("data",), "model")  # 1-sized axes divide

    def test_quantized_leaf_specs(self):
        from repro.core.quant import quantize
        mesh = self._mesh()
        import jax.numpy as jnp
        qt = quantize(jnp.ones((256, 512), jnp.float32))
        tree = {"mlp": {"up": qt}}
        specs = shd.param_specs(tree, mesh, "serve")
        assert specs["mlp"]["up"].packed == P(None, "model")
        assert specs["mlp"]["up"].scales == P(None, "model")


class TestHints:
    def test_noop_without_mesh(self):
        import jax.numpy as jnp
        x = jnp.ones((4, 8))
        assert active_mesh() is None
        y = hint(x, "batch", "heads")
        assert y is x  # exact object: no constraint emitted


class TestMeshConstruction:
    def test_make_host_mesh(self):
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        assert set(mesh.axis_names) == {"data", "model"}


class TestInt8KVCache:
    """int8 KV quantization (beyond-paper): decode matches the bf16 cache
    to int8-rounding tolerance, on both the fallback and sharded paths."""

    def test_fallback_path_close_to_fp(self):
        import jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import api
        cfg_fp = get_smoke_config("qwen3-8b")
        cfg_q = get_smoke_config("qwen3-8b", kv_quant="int8")
        params = api.init_params(cfg_fp, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                    cfg_fp.vocab_size)
        l_fp, c_fp = api.prefill(cfg_fp, params, {"tokens": tokens}, 16)
        l_q, c_q = api.prefill(cfg_q, params, {"tokens": tokens}, 16)
        assert c_q["k"].dtype == np.int8
        np.testing.assert_allclose(np.asarray(l_q, np.float32),
                                   np.asarray(l_fp, np.float32),
                                   rtol=0.05, atol=0.1)
        nt = np.argmax(np.asarray(l_fp), -1).reshape(2, 1).astype(np.int32)
        import jax.numpy as jnp2
        d_fp, _ = api.decode_step(cfg_fp, params, c_fp, jnp2.asarray(nt),
                                  jnp2.int32(9))
        d_q, _ = api.decode_step(cfg_q, params, c_q, jnp2.asarray(nt),
                                 jnp2.int32(9))
        np.testing.assert_allclose(np.asarray(d_q, np.float32),
                                   np.asarray(d_fp, np.float32),
                                   rtol=0.05, atol=0.15)

    def test_sharded_path_matches_fallback(self):
        """Run inside the 8-device worker: sharded int8 decode == the
        unsharded int8 reference."""
        import subprocess, sys, os, json, textwrap
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys, json
            sys.path.insert(0, "src")
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_smoke_config
            from repro.models import api
            from repro.parallel.hints import use_mesh
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            cfg = get_smoke_config("qwen3-8b", kv_quant="int8")
            params = api.init_params(cfg, jax.random.PRNGKey(0))
            cache = api.init_cache(cfg, 8, 64)
            tok = jnp.zeros((8, 1), jnp.int32)
            ref, _ = api.decode_step(cfg, params, cache, tok, jnp.int32(5))
            with use_mesh(mesh):
                got, nc = jax.jit(lambda p, c, t, l: api.decode_step(
                    cfg, p, c, t, l))(params, cache, tok, jnp.int32(5))
            err = float(jnp.max(jnp.abs(ref - got)))
            print("RESULT " + json.dumps({"err": err,
                                          "int8": str(nc["k"].dtype)}))
        """)
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=600)
        for line in proc.stdout.splitlines():
            if line.startswith("RESULT "):
                r = json.loads(line[len("RESULT "):])
                assert r["int8"] == "int8"
                assert r["err"] < 2e-2
                return
        raise AssertionError(proc.stderr[-2000:])
