"""Fused FFN datapath tests (kernels/ffn_fused.py + ops.ffn_w4a16).

Coverage per the PR-4 checklist:
* fused (Pallas, interpret) ≡ blocked-XLA twin ≡ unfused ref across
  {swiglu, geglu, gelu+bias} × {dense, W4A16, sparse} × token counts
  including non-multiples of the block;
* ops dispatch: static variant selection, graceful fallback (non-128
  groups, non-tile-uniform sparse down);
* mlp_apply wiring: plain 16-bit weights stay bit-identical to the seed
  composition; quantized weights route through the twin;
* MoE: quantized experts dispatch through ops (no dense dequantize-
  everything oracle in the hot loop);
* engine-vs-oracle token parity for a SPARSE-strategy quantized model and
  the compile-cache bound (the fused FFN adds no executables);
* decode-shaped token blocking (no 8-row pad at batch 1).
"""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize
from repro.core.sparsity import block_sparsify_quantize
from repro.kernels import ffn_fused, ops, ref
from repro.kernels.pallas_compat import token_block


def _rand(shape, seed=0, dtype=jnp.bfloat16, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32)).astype(dtype)


def _weights(kind: str, d: int, f: int, seed=0):
    """(gate, up, down) for a weight kind: dense | w4 | sparse-<density>."""
    wg = _rand((d, f), seed + 1, jnp.float32, 0.05)
    wu = _rand((d, f), seed + 2, jnp.float32, 0.05)
    wd = _rand((f, d), seed + 3, jnp.float32, 0.05)
    if kind == "dense":
        return (wg.astype(jnp.bfloat16), wu.astype(jnp.bfloat16),
                wd.astype(jnp.bfloat16))
    if kind == "w4":
        return quantize(wg), quantize(wu), quantize(wd)
    density = float(kind.split("-")[1])
    return (block_sparsify_quantize(wg, density),
            block_sparsify_quantize(wu, density),
            block_sparsify_quantize(wd, density, tile_uniform=True))


TOL = dict(rtol=4e-2, atol=4e-2)


class TestFusedParity:
    """fused ≡ twin ≡ unfused-ref for every activation × weight kind."""

    @pytest.mark.parametrize("activation", ["swiglu", "geglu", "gelu"])
    @pytest.mark.parametrize("kind", ["dense", "w4", "sparse-0.5",
                                      "sparse-0.25"])
    @pytest.mark.parametrize("tokens", [1, 57])
    def test_three_impls_agree(self, activation, kind, tokens):
        d = f = 1024 if kind.startswith("sparse") else 512
        gate, up, down = _weights(kind, d, f, seed=tokens)
        x = _rand((tokens, d), seed=tokens + 9)
        ub = db = None
        if activation == "gelu":
            ub = _rand((f,), seed=31, scale=0.1)
            db = _rand((d,), seed=32, scale=0.1)
        kw = dict(activation=activation, up_bias=ub, down_bias=db)
        want = np.asarray(ops.ffn_w4a16(x, gate, up, down, impl="ref", **kw),
                          np.float32)
        for impl in ("pallas", "xla"):
            got = np.asarray(ops.ffn_w4a16(x, gate, up, down, impl=impl, **kw),
                             np.float32)
            np.testing.assert_allclose(got, want, err_msg=impl, **TOL)

    def test_leading_batch_dims(self):
        gate, up, down = _weights("w4", 256, 384)
        x = _rand((2, 3, 5, 256), seed=4)
        got = ops.ffn_w4a16(x, gate, up, down, impl="pallas")
        want = ops.ffn_w4a16(x, gate, up, down, impl="ref")
        assert got.shape == (2, 3, 5, 256)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL)

    def test_block_boundary_tokens(self):
        """Token counts straddling the block cap pad correctly."""
        gate, up, down = _weights("w4", 256, 256)
        for tokens in (ffn_fused.DEFAULT_BLOCK_TOKENS - 1,
                       ffn_fused.DEFAULT_BLOCK_TOKENS,
                       ffn_fused.DEFAULT_BLOCK_TOKENS + 3):
            x = _rand((tokens, 256), seed=tokens)
            got = ops.ffn_w4a16(x, gate, up, down, impl="pallas")
            want = ops.ffn_w4a16(x, gate, up, down, impl="ref")
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(want, np.float32), **TOL)

    def test_sparse_skips_dropped_hidden_tiles(self):
        """With a tile-uniform sparse down, the fused grid walks only the
        kept f-blocks — result still matches the unfused oracle that
        computes every hidden tile."""
        gate, up, down = _weights("sparse-0.25", 1024, 1024)
        assert down.tile_uniform and down.kept_blocks == 2  # of 8 f-tiles
        x = _rand((8, 1024), seed=77)
        got = ffn_fused.ffn_fused_sparse_pallas(x, gate, up, down)
        want = ref.ffn_ref(x, gate, up, down)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL)


class TestDispatch:
    def test_variant_selection(self):
        d = f = 1024
        fp = _weights("dense", d, f)
        q = _weights("w4", d, f)
        sp = _weights("sparse-0.5", d, f)
        assert ffn_fused.fused_variant(
            _rand((1, d)), *fp, "swiglu", None, None) == "fp"
        assert ffn_fused.fused_variant(
            _rand((1, d)), *q, "swiglu", None, None) == "quant"
        assert ffn_fused.fused_variant(
            _rand((1, d)), *sp, "swiglu", None, None) == "sparse"
        # sparse gate/up + dense-quant down is also fused
        assert ffn_fused.fused_variant(
            _rand((1, d)), sp[0], sp[1], q[2], "swiglu", None, None) == "sparse"
        # non-tile-uniform sparse down cannot fuse (falls back, stays correct)
        dn = block_sparsify_quantize(
            _rand((f, d), 9, jnp.float32, 0.05), 0.5, tile_uniform=False)
        assert ffn_fused.fused_variant(
            _rand((1, d)), sp[0], sp[1], dn, "swiglu", None, None) is None
        x = _rand((3, d), seed=5)
        got = ops.ffn_w4a16(x, sp[0], sp[1], dn, impl="pallas")
        want = ops.ffn_w4a16(x, sp[0], sp[1], dn, impl="ref")
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL)

    def test_sparse_gate_up_with_16bit_down_falls_back(self):
        """A strategy may keep a kind 16-bit: sparse gate/up + plain down
        must return None (not crash on down.group_size) and stay correct."""
        d = f = 1024
        sp = _weights("sparse-0.5", d, f)
        dn16 = _rand((f, d), 9, jnp.bfloat16, 0.05)
        assert ffn_fused.fused_variant(
            _rand((1, d)), sp[0], sp[1], dn16, "swiglu", None, None) is None
        x = _rand((2, d), seed=14)
        got = ops.ffn_w4a16(x, sp[0], sp[1], dn16, impl="pallas")
        want = ops.ffn_w4a16(x, sp[0], sp[1], dn16, impl="ref")
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL)

    def test_gated_bias_rejected_on_every_impl(self):
        """Biases with gated activations are a contract violation — one
        ValueError at the op boundary, not silent per-impl divergence."""
        gate, up, down = _weights("w4", 256, 256)
        x = _rand((2, 256), seed=15)
        b = _rand((256,), seed=16)
        for impl in ("pallas", "xla", "ref"):
            with pytest.raises(ValueError, match="no FFN biases"):
                ops.ffn_w4a16(x, gate, up, down, activation="swiglu",
                              down_bias=b, impl=impl)

    def test_small_group_falls_back_to_twin(self):
        """MoE-style 64-channel quant groups don't fit the kernel; the twin
        handles them with the same numerics contract."""
        d, f = 256, 256
        gq = quantize(_rand((d, f), 1, jnp.float32, 0.05), group_size=64)
        uq = quantize(_rand((d, f), 2, jnp.float32, 0.05), group_size=64)
        dq = quantize(_rand((f, d), 3, jnp.float32, 0.05), group_size=64)
        assert ffn_fused.fused_variant(
            _rand((1, d)), gq, uq, dq, "swiglu", None, None) is None
        x = _rand((4, d), seed=8)
        got = ops.ffn_w4a16(x, gq, uq, dq, impl="pallas")  # falls back
        want = ops.ffn_w4a16(x, gq, uq, dq, impl="ref")
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL)


class TestMlpWiring:
    def test_dense_weights_bit_identical_to_seed_composition(self):
        """Plain 16-bit weights must keep the training path's exact
        numerics (same dots, same dtype chain)."""
        from repro.models import layers
        cfg = type("C", (), {"activation": "swiglu", "use_kernels": False})()
        d, f = 96, 160  # deliberately NOT 128-tileable
        p = {"gate": _rand((d, f), 1), "up": _rand((d, f), 2),
             "down": _rand((f, d), 3)}
        x = _rand((4, 7, d), seed=4)
        got = layers.mlp_apply(cfg, p, x)
        want = layers.linear(
            jax.nn.silu(layers.linear(x, p["gate"])) * layers.linear(x, p["up"]),
            p["down"])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_gelu_bias_bit_identical(self):
        from repro.models import layers
        cfg = type("C", (), {"activation": "gelu", "use_kernels": False})()
        d, f = 96, 160
        p = {"up": _rand((d, f), 1), "up_bias": _rand((f,), 2),
             "down": _rand((f, d), 3), "down_bias": _rand((d,), 4)}
        x = _rand((2, 5, d), seed=6)
        got = layers.mlp_apply(cfg, p, x)
        want = layers.linear(
            jax.nn.gelu(layers.linear(x, p["up"], p["up_bias"]),
                        approximate=True),
            p["down"], p["down_bias"])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_dense_use_kernels_stays_differentiable(self):
        """use_kernels=True with plain 16-bit weights must keep the seed's
        dot path (differentiable, same numerics) — the fused Pallas kernel
        is for the quantized serving path only."""
        from repro.models import layers
        cfg = type("C", (), {"activation": "swiglu", "use_kernels": True})()
        d, f = 128, 256
        p = {"gate": _rand((d, f), 1), "up": _rand((d, f), 2),
             "down": _rand((f, d), 3)}
        x = _rand((2, 4, d), seed=4)
        got = layers.mlp_apply(cfg, p, x)
        want = layers.linear(
            jax.nn.silu(layers.linear(x, p["gate"])) * layers.linear(x, p["up"]),
            p["down"])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        g = jax.grad(lambda xx: layers.mlp_apply(cfg, p, xx).astype(
            jnp.float32).sum())(x)
        assert g.shape == x.shape

    def test_quantized_weights_route_through_twin(self):
        from repro.models import layers
        cfg = type("C", (), {"activation": "swiglu", "use_kernels": False})()
        gate, up, down = _weights("w4", 256, 384)
        p = {"gate": gate, "up": up, "down": down}
        x = _rand((3, 256), seed=7)
        got = layers.mlp_apply(cfg, p, x)
        want = ffn_fused.ffn_w4a16_xla(x, gate, up, down, activation="swiglu")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestMoE:
    def test_no_dense_oracle_in_hot_path(self):
        """The quantized MoE paths dispatch through ops, not the
        dequantize-everything ref oracle."""
        import repro.models.moe as moe
        src = inspect.getsource(moe)
        assert "w4a16_matmul_ref" not in src
        assert "kref" not in src
        assert "ops.ffn_w4a16" in src

    def test_local_quantized_experts_match_dequantized(self):
        """Quantized expert FFNs (through ops.ffn_w4a16) ≈ the same MoE run
        on the dequantized weights — identical routing, group-exact FFN."""
        from repro.configs import get_smoke_config
        from repro.models import moe
        cfg = get_smoke_config("mixtral-8x22b")
        p = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = _rand((2, 8, cfg.d_model), seed=3, dtype=cfg.dtype)
        qp = dict(p)
        qp["gate"] = jax.vmap(quantize)(p["gate"].astype(jnp.float32))
        qp["up"] = jax.vmap(quantize)(p["up"].astype(jnp.float32))
        qp["down"] = jax.vmap(quantize)(p["down"].astype(jnp.float32))
        dq = dict(p)
        dq["gate"] = jax.vmap(lambda q: q.dequantize(cfg.dtype))(qp["gate"])
        dq["up"] = jax.vmap(lambda q: q.dequantize(cfg.dtype))(qp["up"])
        dq["down"] = jax.vmap(lambda q: q.dequantize(cfg.dtype))(qp["down"])
        out_q, aux_q = moe._moe_apply_local(cfg, qp, x)
        out_d, aux_d = moe._moe_apply_local(cfg, dq, x)
        np.testing.assert_allclose(np.asarray(out_q, np.float32),
                                   np.asarray(out_d, np.float32),
                                   rtol=5e-2, atol=5e-2)
        np.testing.assert_allclose(float(aux_q), float(aux_d), rtol=1e-3)


class TestTokenBlocking:
    def test_token_block_decode_shapes(self):
        assert token_block(1, 256) == 1          # B=1 decode: no 8-row pad
        assert token_block(3, 256) == 3
        assert token_block(200, 256) == 200      # exact fit below the cap
        assert token_block(256, 256) == 256
        assert token_block(1000, 256) == 256     # prefill: tile at the cap

    def test_single_token_kernels_exact_fit(self):
        """tokens=1 through both standalone kernels (the old path padded to
        8 rows; the new one runs a 1-row block)."""
        from repro.kernels.sparse_w4a16 import sparse_w4a16_matmul_pallas
        from repro.kernels.w4a16_matmul import w4a16_matmul_pallas
        x = _rand((1, 1024), seed=11)
        qt = quantize(_rand((1024, 256), 12, jnp.float32))
        st = block_sparsify_quantize(_rand((1024, 256), 13, jnp.float32), 0.5)
        np.testing.assert_allclose(
            np.asarray(w4a16_matmul_pallas(x, qt), np.float32),
            np.asarray(ref.w4a16_matmul_ref(x, qt), np.float32), **TOL)
        np.testing.assert_allclose(
            np.asarray(sparse_w4a16_matmul_pallas(x, st), np.float32),
            np.asarray(ref.sparse_w4a16_matmul_ref(x, st), np.float32), **TOL)


class TestTileUniform:
    def test_rows_identical_and_flagged(self):
        w = _rand((2048, 256), 21, jnp.float32)
        st = block_sparsify_quantize(w, 0.25, tile_uniform=True)
        idx = np.asarray(st.block_idx)
        assert st.tile_uniform
        assert (idx == idx[0]).all()
        # and the plain layout stays per-tile
        st2 = block_sparsify_quantize(w, 0.25)
        assert not st2.tile_uniform

    def test_strategy_plumbing_marks_ffn_down(self):
        """quantize_model's 4h_to_h (down) sparse tensors are tile-uniform
        so serving models hit the fused down-gather."""
        from repro.configs import get_smoke_config
        from repro.core.compiler import quantize_model
        from repro.core.sparsity import SparseQuantizedTensor
        from repro.models import api
        cfg = get_smoke_config("qwen-7b", d_model=1024, d_ff=1024,
                               vocab_size=256)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        q = quantize_model(params, "strategy3")
        mlp = q["blocks"]["mlp"]
        assert isinstance(mlp["down"], SparseQuantizedTensor)
        assert mlp["down"].tile_uniform
        assert isinstance(mlp["gate"], SparseQuantizedTensor)
        assert not mlp["gate"].tile_uniform


class TestServingQuantizedSparse:
    """Engine-vs-oracle decode with a sparse-strategy quantized model, and
    the compile-cache bound: the fused FFN must add no executables."""

    def test_engine_token_parity_and_bounded_compiles(self):
        from repro.configs import get_smoke_config
        from repro.core.compiler import CompileCache, quantize_model
        from repro.models import api
        from repro.serving.engine import Engine, Request, reference_decode

        cfg = get_smoke_config("qwen-7b", n_layers=1, d_model=1024,
                               d_ff=1024, vocab_size=256)
        params = quantize_model(api.init_params(cfg, jax.random.PRNGKey(0)),
                                "strategy3")
        rng = np.random.default_rng(7)
        engine = Engine(cfg, params, batch_size=2, max_len=32, chunk_size=8)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, 256,
                                            int(rng.integers(3, 12))
                                            ).astype(np.int32),
                        max_new_tokens=3) for i in range(3)]
        for r in reqs:
            engine.submit(r)
        done = engine.run()
        assert len(done) == 3
        assert engine.cache_compiles.misses <= engine.compile_budget
        oracle_cc = CompileCache()
        for r in done:
            want = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                                    max_len=32, compile_cache=oracle_cc)
            assert r.output == want, f"req {r.rid} diverged from oracle"
