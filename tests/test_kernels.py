"""Kernel sweeps: Pallas (interpret=True) vs pure-jnp oracles.

Per assignment: for each Pallas kernel, sweep shapes/dtypes and
assert_allclose against the ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize
from repro.core.sparsity import block_sparsify_quantize
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.sparse_w4a16 import sparse_w4a16_matmul_pallas
from repro.kernels.w4a16_matmul import w4a16_matmul_pallas


def _rand(shape, seed=0, dtype=jnp.bfloat16):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 1, shape).astype(np.float32)).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-3, atol=2e-3)


class TestW4A16Kernel:
    @pytest.mark.parametrize("tokens,in_f,out_f", [
        (8, 256, 128),        # tiny
        (1, 512, 512),        # decode-style single token
        (128, 1024, 512),     # prefill tile
        (200, 384, 256),      # non-multiple-of-block tokens
    ])
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_vs_ref(self, tokens, in_f, out_f, dtype):
        x = _rand((tokens, in_f), seed=tokens + in_f, dtype=dtype)
        qt = quantize(_rand((in_f, out_f), seed=7, dtype=jnp.float32))
        got = w4a16_matmul_pallas(x, qt, block_tokens=64, block_out=128)
        want = ref.w4a16_matmul_ref(x, qt)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))

    def test_batched_lead_dims(self):
        x = _rand((2, 4, 16, 256), seed=3)
        qt = quantize(_rand((256, 128), seed=9, dtype=jnp.float32))
        got = w4a16_matmul_pallas(x, qt, block_tokens=16, block_out=128)
        want = ref.w4a16_matmul_ref(x, qt)
        assert got.shape == (2, 4, 16, 128)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(jnp.bfloat16))

    def test_unit_error_vs_exact_math(self):
        """Paper Table-I methodology: the computing unit's error is measured
        against exact math on the *same* int4 weights — ours is tiny because
        the integer dot is exact and only the f32 accumulation order differs."""
        from repro.core.quant import dequantize
        x = _rand((32, 512), seed=5, dtype=jnp.float32)
        w = _rand((512, 256), seed=6, dtype=jnp.float32) * 0.05
        qt = quantize(w, scale_dtype=jnp.float32)
        got = np.asarray(w4a16_matmul_pallas(x, qt, block_tokens=32, block_out=128), np.float32)
        exact = np.asarray(x, np.float64) @ np.asarray(
            dequantize(qt, jnp.float32), np.float64)
        rel = np.abs(got - exact) / (np.abs(exact) + 1e-3)
        assert np.median(rel) < 1e-5  # paper: 0.047% error rate; ours is f32-accum

    def test_quantization_error_moderate(self):
        """End-to-end int4 quantization error on the matmul output is bounded
        by the usual sqrt(K)*scale/2 accumulation estimate."""
        x = _rand((32, 512), seed=5, dtype=jnp.float32)
        w = _rand((512, 256), seed=6, dtype=jnp.float32) * 0.05
        qt = quantize(w, scale_dtype=jnp.float32)
        got = np.asarray(w4a16_matmul_pallas(x, qt, block_tokens=32, block_out=128), np.float32)
        want = np.asarray(x @ w, np.float32)
        # rms error vs rms signal
        nrmse = np.sqrt(np.mean((got - want) ** 2)) / np.sqrt(np.mean(want ** 2))
        assert nrmse < 0.2

    def test_ops_dispatch_consistency(self):
        x = _rand((16, 256), seed=11)
        qt = quantize(_rand((256, 128), seed=12, dtype=jnp.float32))
        a = ops.w4a16_matmul(x, qt, impl="pallas")
        b = ops.w4a16_matmul(x, qt, impl="xla")
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-2)


class TestSparseW4A16Kernel:
    @pytest.mark.parametrize("density", [1.0, 0.5, 0.25, 0.125])
    @pytest.mark.parametrize("tokens", [1, 64])
    def test_vs_ref(self, density, tokens):
        in_f, out_f = 1024, 256
        x = _rand((tokens, in_f), seed=int(density * 8) + tokens)
        st = block_sparsify_quantize(_rand((in_f, out_f), seed=21, dtype=jnp.float32), density)
        got = sparse_w4a16_matmul_pallas(x, st, block_tokens=64)
        want = ref.sparse_w4a16_matmul_ref(x, st)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)

    @pytest.mark.parametrize("in_f,out_f", [(2048, 128), (1024, 512)])
    def test_shapes(self, in_f, out_f):
        x = _rand((16, in_f), seed=31)
        st = block_sparsify_quantize(_rand((in_f, out_f), seed=32, dtype=jnp.float32), 0.5)
        got = sparse_w4a16_matmul_pallas(x, st, block_tokens=16)
        assert got.shape == (16, out_f)
        want = ref.sparse_w4a16_matmul_ref(x, st)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)

    def test_xla_gather_path_matches(self):
        x = _rand((8, 1024), seed=41)
        st = block_sparsify_quantize(_rand((1024, 256), seed=42, dtype=jnp.float32), 0.25)
        a = ops.sparse_w4a16_matmul(x, st, impl="pallas")
        b = ops.sparse_w4a16_matmul(x, st, impl="xla")
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=3e-2, atol=3e-2)

    def test_sparse_equals_masked_dense_matmul(self):
        """The sparse kernel computes x @ W_masked exactly (up to quant)."""
        from repro.core.sparsity import sparse_dequantize
        x = _rand((8, 1024), seed=51, dtype=jnp.float32)
        w = _rand((1024, 128), seed=52, dtype=jnp.float32)
        st = block_sparsify_quantize(w, 0.5)
        got = np.asarray(sparse_w4a16_matmul_pallas(x, st, block_tokens=8), np.float32)
        want = np.asarray(x @ sparse_dequantize(st, jnp.float32), np.float32)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
        (1, 4, 4, 256, 256, 64),      # MHA square
        (2, 8, 2, 256, 256, 64),      # GQA
        (1, 8, 1, 256, 256, 128),     # MQA
        (1, 4, 4, 256, 1024, 64),     # decode-ish: q at the end of context
        (1, 2, 2, 512, 512, 256),     # gemma head_dim 256
    ])
    def test_causal_vs_ref(self, b, hq, hkv, sq, skv, d):
        q = _rand((b, hq, sq, d), seed=sq + d)
        k = _rand((b, hkv, skv, d), seed=skv + d + 1)
        v = _rand((b, hkv, skv, d), seed=skv + d + 2)
        got = flash_attention_pallas(q, k, v, causal=True, block_q=128, block_kv=128)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)

    def test_noncausal_cross_attention(self):
        q = _rand((1, 4, 128, 64), seed=61)
        k = _rand((1, 4, 512, 64), seed=62)
        v = _rand((1, 4, 512, 64), seed=63)
        got = flash_attention_pallas(q, k, v, causal=False, block_q=128, block_kv=128)
        want = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)

    @pytest.mark.parametrize("window", [128, 384])
    def test_sliding_window(self, window):
        q = _rand((1, 4, 512, 64), seed=71)
        k = _rand((1, 4, 512, 64), seed=72)
        v = _rand((1, 4, 512, 64), seed=73)
        got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     block_q=128, block_kv=128)
        want = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)

    def test_scale_override(self):
        q = _rand((1, 2, 128, 64), seed=81)
        k = _rand((1, 2, 128, 64), seed=82)
        v = _rand((1, 2, 128, 64), seed=83)
        got = flash_attention_pallas(q, k, v, causal=True, scale=0.25,
                                     block_q=128, block_kv=128)
        want = ref.attention_ref(q, k, v, causal=True, scale=0.25)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


class TestDecodeAttention:
    def test_matches_full_attention_last_token(self):
        """decode(q_new, cache) == full attention's last row."""
        b, h, d, ctx = 2, 4, 64, 256
        q_full = _rand((b, h, ctx, d), seed=91)
        k = _rand((b, h, ctx, d), seed=92)
        v = _rand((b, h, ctx, d), seed=93)
        full = ref.attention_ref(q_full, k, v, causal=True)
        # preallocated cache larger than ctx
        max_len = 512
        kc = jnp.zeros((b, h, max_len, d), jnp.bfloat16).at[:, :, :ctx].set(k)
        vc = jnp.zeros((b, h, max_len, d), jnp.bfloat16).at[:, :, :ctx].set(v)
        dec = ops.decode_attention(q_full[:, :, -1:], kc, vc,
                                   jnp.full((b,), ctx, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(dec[:, :, 0], np.float32),
            np.asarray(full[:, :, -1], np.float32), rtol=3e-2, atol=3e-2)

    def test_window_limits_context(self):
        b, h, d, ctx, w = 1, 2, 64, 256, 64
        q = _rand((b, h, 1, d), seed=94)
        kc = _rand((b, h, 512, d), seed=95)
        vc = _rand((b, h, 512, d), seed=96)
        got = ops.decode_attention(q, kc, vc, ctx, window=w)
        # equivalent: slice the last w tokens and do full attention
        ks = kc[:, :, ctx - w:ctx]
        vs = vc[:, :, ctx - w:ctx]
        want = ref.attention_ref(q, ks, vs, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


class TestXlaChunkedAttention:
    """The dry-run twin of the flash kernel: chunked XLA attention must
    match the dense oracle across masking modes and chunk boundaries."""

    @pytest.mark.parametrize("b,hq,hkv,sq,skv,caus,win", [
        (1, 4, 4, 256, 256, True, None),
        (2, 8, 2, 256, 512, True, None),      # GQA, decode-aligned q
        (1, 4, 4, 384, 384, True, 130),       # window not chunk-aligned
        (1, 2, 2, 256, 256, False, None),     # cross-attention
        (1, 2, 1, 100, 300, True, None),      # ragged, padding path
    ])
    def test_vs_dense_ref(self, b, hq, hkv, sq, skv, caus, win):
        from repro.kernels.xla_attention import attention_chunked
        q = _rand((b, hq, sq, 64), seed=sq)
        k = _rand((b, hkv, skv, 64), seed=skv + 1)
        v = _rand((b, hkv, skv, 64), seed=skv + 2)
        got = attention_chunked(q, k, v, causal=caus, window=win,
                                chunk_q=128, chunk_kv=96)
        want = ref.attention_ref(q, k, v, causal=caus, window=win)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2)

    def test_ops_routes_long_context_through_chunks(self):
        q = _rand((1, 2, 2048, 64), seed=5)
        k = _rand((1, 2, 2048, 64), seed=6)
        v = _rand((1, 2, 2048, 64), seed=7)
        a = ops.attention(q, k, v, causal=True, impl="xla")
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2)


class TestSlstmScanKernel:
    """Pallas sLSTM (VMEM-resident recurrent weights) vs the lax.scan oracle
    in models/xlstm."""

    @pytest.mark.parametrize("b,L,h,dh,chunk", [
        (2, 64, 4, 32, 16),
        (1, 96, 2, 64, 32),
        (3, 128, 1, 128, 128),   # single chunk
    ])
    def test_vs_scan_oracle(self, b, L, h, dh, chunk):
        import jax
        from repro.kernels.slstm_scan import slstm_scan_pallas
        from repro.models import xlstm as mx
        rng = np.random.default_rng(b * L)
        gx = jnp.asarray(rng.normal(0, 1, (b, L, h, 4 * dh)).astype(np.float32))
        r = jnp.asarray(rng.normal(0, 0.05, (h, dh, 4 * dh)).astype(np.float32))
        bias = jnp.asarray(rng.normal(0, 0.1, (h, 4 * dh)).astype(np.float32))

        got = slstm_scan_pallas(gx, r, bias, time_chunk=chunk)

        # oracle: the models/xlstm step under lax.scan
        p = {"r_gates": r, "b_gates": bias}
        def body(state, g):
            new = mx._slstm_step(p, state, g)
            return new, new[2]
        init = tuple(jnp.zeros((b, h, dh), jnp.float32) for _ in range(3)) + (
            jnp.full((b, h, dh), -1e30, jnp.float32),)
        _, hs = jax.lax.scan(body, init, jnp.moveaxis(gx, 1, 0))
        want = jnp.moveaxis(hs, 0, 1)                     # (b, L, h, dh)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_vmem_budget_xlstm13b(self):
        """The resident weights for xlstm-1.3b fit v5e VMEM (the kernel's
        premise): block-diag R = (4, 512, 2048) bf16 = 8 MB < 16 MB."""
        h, dh = 4, 512
        resident = h * dh * 4 * dh * 2   # bf16
        assert resident <= 16 * 2**20 * 0.75
