"""Request-lifecycle tests (ISSUE 8): submit/admission edge cases, cancel
at every stage, deterministic deadline misses, explicit run() truncation,
and lossless bounded preemption under pool pressure and priorities."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache, quantize_model
from repro.models import api
from repro.serving.engine import (Engine, Request, RunResult,
                                  TERMINAL_STATES, reference_decode)

# one oracle cache PER CONFIG — executables close over cfg, so sharing a
# cache across the slot and paged fixtures would replay the wrong shapes
_REF_CC = CompileCache()
_REF_CC_PAGED = CompileCache()


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen-7b", d_model=128, d_ff=256, vocab_size=512)
    params = quantize_model(
        api.init_params(cfg, jax.random.PRNGKey(0)), "dense")
    return cfg, params


@pytest.fixture(scope="module")
def paged_setup():
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                           kv_layout="paged", kv_block_size=8,
                           kv_pool_blocks=6)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


@pytest.fixture(scope="module")
def engine(setup):
    cfg, params = setup
    return Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16)


def _prompt(rng, n, vocab=512):
    return rng.integers(0, vocab, n).astype(np.int32)


def _run_some(eng, n, **kw):
    """run(max_steps=n) where truncation is the POINT — swallow the
    (expected) not-drained warning."""
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("ignore", RuntimeWarning)
        return eng.run(max_steps=n, **kw)


# -- submit / admission edge cases ----------------------------------------

def test_prompt_exactly_max_len_admits(setup):
    """len(prompt) == max_len is admissible: the request emits exactly one
    token (the cache is full after prefill) and matches the oracle."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=2, max_len=32, chunk_size=16)
    rng = np.random.default_rng(0)
    prompt = _prompt(rng, 32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    done = eng.run()
    assert done[0].status == "done" and len(done[0].output) == 1
    assert done[0].output == reference_decode(
        cfg, params, prompt, 8, max_len=32, compile_cache=_REF_CC)


def test_max_new_tokens_zero_rejected(engine):
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(Request(rid=900, prompt=np.zeros(4, np.int32),
                              max_new_tokens=0))


def test_duplicate_rid_rejected_while_live(setup):
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16)
    rng = np.random.default_rng(1)
    eng.submit(Request(rid=7, prompt=_prompt(rng, 4), max_new_tokens=2))
    with pytest.raises(ValueError, match="rid already queued"):
        eng.submit(Request(rid=7, prompt=_prompt(rng, 4), max_new_tokens=2))
    eng.run()
    # the rid is free again once its first holder reached a terminal state
    eng.submit(Request(rid=7, prompt=_prompt(rng, 4), max_new_tokens=2))
    assert eng.run()[0].status == "done"


# -- cancel at every lifecycle stage --------------------------------------

def test_cancel_before_admit(setup):
    """A queued request cancels without ever touching a slot; the rest of
    the queue is unaffected and still matches the oracle."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=1, max_len=64, chunk_size=16)
    rng = np.random.default_rng(2)
    keep = Request(rid=0, prompt=_prompt(rng, 5), max_new_tokens=3)
    doomed = Request(rid=1, prompt=_prompt(rng, 5), max_new_tokens=3)
    eng.submit(keep)
    eng.submit(doomed)
    assert eng.cancel(1) is True
    assert doomed.status == "cancelled" and doomed.done
    assert doomed.output == [] and doomed.finished_at is not None
    done = eng.run()
    assert [r.rid for r in done] == [0]
    assert keep.output == reference_decode(cfg, params, keep.prompt, 3,
                                           max_len=64, compile_cache=_REF_CC)


def test_cancel_unknown_rid_is_false(engine):
    assert engine.cancel(12345) is False


def test_cancel_mid_flight_frees_row_and_spares_neighbors(setup):
    """Cancelling a RUNNING request (from inside a sample hook, mid-tick)
    frees only its slot; the surviving row's stream is still bitwise the
    oracle's."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16)
    rng = np.random.default_rng(3)
    a = Request(rid=0, prompt=_prompt(rng, 6), max_new_tokens=8)
    b = Request(rid=1, prompt=_prompt(rng, 6), max_new_tokens=8)
    eng.submit(a)
    eng.submit(b)
    fired = []

    def sample(row):
        if len(a.output) == 2 and not fired:
            fired.append(eng.cancel(1))
        return int(np.argmax(row))

    done = eng.run(sample=sample)
    assert fired == [True]
    assert b.status == "cancelled" and len(b.output) < 8
    assert a.status == "done"
    assert a.output == reference_decode(cfg, params, a.prompt, 8,
                                        max_len=64, compile_cache=_REF_CC)
    # cancel() retires the request at the call site — it is not echoed
    # through run()'s result (the caller already holds the object)
    assert {r.rid for r in done} == {0}
    # cancelled slot was reusable afterwards
    assert eng._slots[0].req is None and eng._slots[1].req is None


# -- deadlines -------------------------------------------------------------

def test_deadline_zero_misses_deterministically(setup):
    """deadline_s=0.0 expires at the FIRST sweep — deterministic in CI —
    whether the request is still queued or already running; neighbors
    without deadlines are untouched."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=1, max_len=64, chunk_size=16)
    rng = np.random.default_rng(4)
    doomed = Request(rid=0, prompt=_prompt(rng, 5), max_new_tokens=4,
                     deadline_s=0.0)
    queued_doomed = Request(rid=1, prompt=_prompt(rng, 5), max_new_tokens=4,
                            deadline_s=0.0)
    survivor = Request(rid=2, prompt=_prompt(rng, 5), max_new_tokens=4)
    for r in (doomed, queued_doomed, survivor):
        eng.submit(r)
    done = eng.run()
    assert doomed.status == "deadline_missed" and doomed.output == []
    assert queued_doomed.status == "deadline_missed"
    assert survivor.status == "done" and len(survivor.output) == 4
    assert eng.deadline_misses == 2
    assert {r.rid for r in done} == {0, 1, 2}


def test_nonzero_deadline_fires_on_injected_clock(setup):
    """With an injectable engine clock a NONZERO deadline is deterministic:
    the miss fires exactly when the clock crosses submit + deadline_s, and
    one tick before it does not."""
    cfg, params = setup

    class Clock:
        t = 1000.0

        def __call__(self):
            return self.t

    clock = Clock()
    eng = Engine(cfg, params, batch_size=1, max_len=64, chunk_size=16,
                 clock=clock)
    rng = np.random.default_rng(11)
    r = Request(rid=0, prompt=_prompt(rng, 5), max_new_tokens=4,
                deadline_s=30.0)
    eng.submit(r)
    assert r.submitted_at == 1000.0
    clock.t = 1029.9                     # inside budget: runs to done
    eng.run()
    assert r.status == "done" and len(r.output) == 4

    late = Request(rid=1, prompt=_prompt(rng, 5), max_new_tokens=4,
                   deadline_s=30.0)
    eng.submit(late)
    clock.t = 1060.0                     # 30.1 s after ITS submit: expired
    eng.run()
    assert late.status == "deadline_missed" and late.output == []
    assert eng.deadline_misses == 1


def test_deadlines_not_enforced_when_disabled(setup):
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=1, max_len=64, chunk_size=16,
                 enforce_deadlines=False)
    rng = np.random.default_rng(5)
    r = Request(rid=0, prompt=_prompt(rng, 5), max_new_tokens=3,
                deadline_s=0.0)
    eng.submit(r)
    eng.run()
    assert r.status == "done" and eng.deadline_misses == 0


# -- run() truncation is explicit ------------------------------------------

def test_run_truncation_is_explicit(setup):
    """max_steps exhaustion with work in flight returns truncated=True (and
    warns) instead of silently dropping it; draining later flips every
    flag back off."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=1, max_len=64, chunk_size=16)
    rng = np.random.default_rng(6)
    eng.submit(Request(rid=0, prompt=_prompt(rng, 5), max_new_tokens=8))
    eng.submit(Request(rid=1, prompt=_prompt(rng, 5), max_new_tokens=8))
    with pytest.warns(RuntimeWarning, match="NOT drained"):
        part = eng.run(max_steps=2)
    assert isinstance(part, RunResult)
    assert part.truncated and not part.drained
    assert part.in_flight == 1 and part.queued == 1
    assert part == []                     # still a list (compat)
    rest = eng.run()
    assert rest.drained and not rest.truncated
    assert rest.in_flight == 0 and rest.queued == 0 and not rest.stalled
    assert {r.rid for r in rest} == {0, 1}


def test_run_drained_has_no_warning(setup):
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=1, max_len=64, chunk_size=16)
    rng = np.random.default_rng(7)
    eng.submit(Request(rid=0, prompt=_prompt(rng, 4), max_new_tokens=2))
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", RuntimeWarning)
        res = eng.run()
    assert res.drained


# -- preemption -------------------------------------------------------------

def _preempt_pressure_run(cfg, params, *, prefix_cache: bool):
    """A hog mid-generation is preempted by a head it cannot share the pool
    with; both must finish bitwise equal to their never-preempted runs."""
    eng = Engine(cfg, params, batch_size=2, max_len=48, chunk_size=16,
                 prefix_cache=prefix_cache, max_preemptions=2,
                 audit_every=1)
    rng = np.random.default_rng(8)
    hog_prompt = _prompt(rng, 8, cfg.vocab_size)
    head_prompt = _prompt(rng, 16, cfg.vocab_size)
    oracle = {
        0: reference_decode(cfg, params, hog_prompt, 24, max_len=48,
                            compile_cache=_REF_CC_PAGED),
        1: reference_decode(cfg, params, head_prompt, 16, max_len=48,
                            compile_cache=_REF_CC_PAGED),
    }
    hog = Request(rid=0, prompt=hog_prompt, max_new_tokens=24)
    eng.submit(hog)
    _run_some(eng, 6)                     # hog emits a few tokens first
    assert 0 < len(hog.output) < 24
    head = Request(rid=1, prompt=head_prompt, max_new_tokens=16)
    eng.submit(head)                      # pool (6 blocks) can't hold both
    done = eng.run()
    assert done.drained
    assert eng.preemptions >= 1 and hog.preemptions >= 1
    assert hog.preemptions <= 2 and head.preemptions <= 2
    assert hog.status == head.status == "done"
    assert hog.output == oracle[0], "preempted request diverged from its " \
                                    "never-preempted stream"
    assert head.output == oracle[1]
    assert hog.first_token_at is not None
    eng.audit()
    return eng


def test_preemption_lossless_paged_with_prefix_donation(paged_setup):
    cfg, params = paged_setup
    eng = _preempt_pressure_run(cfg, params, prefix_cache=True)
    assert eng.prefix is not None         # donation path was live


def test_preemption_lossless_paged_plain_recompute(paged_setup):
    cfg, params = paged_setup
    eng = _preempt_pressure_run(cfg, params, prefix_cache=False)
    assert eng.prefix is None             # fell back to full recompute


def test_preemption_disabled_by_default(paged_setup):
    """max_preemptions=0 (the default) preserves the old stall-only
    admission: pool pressure stalls the head, nothing is evicted."""
    cfg, params = paged_setup
    eng = Engine(cfg, params, batch_size=2, max_len=48, chunk_size=16)
    rng = np.random.default_rng(9)
    reqs = [Request(rid=i, prompt=_prompt(rng, 8, cfg.vocab_size),
                    max_new_tokens=24) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert done.drained and eng.preemptions == 0
    assert eng.admission_stalls > 0       # pressure showed up as stalls
    assert all(r.status == "done" for r in reqs)


def test_priority_preemption_on_slot_layout(setup):
    """A higher-priority head evicts a lower-priority running request even
    on the non-paged layout (no pool: plain evict-and-recompute), and the
    victim still finishes bitwise-lossless."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=1, max_len=64, chunk_size=16,
                 max_preemptions=1)
    rng = np.random.default_rng(10)
    low_prompt = _prompt(rng, 6)
    low = Request(rid=0, prompt=low_prompt, max_new_tokens=20, priority=0)
    eng.submit(low)
    _run_some(eng, 4)
    assert 0 < len(low.output) < 20
    high = Request(rid=1, prompt=_prompt(rng, 6), max_new_tokens=3,
                   priority=1)
    eng.submit(high)
    done = eng.run()
    assert done.drained
    assert low.preemptions == 1 and eng.preemptions == 1
    # the high-priority request went FIRST despite arriving mid-flight
    assert high.finished_at < low.finished_at
    assert low.output == reference_decode(cfg, params, low_prompt, 20,
                                          max_len=64, compile_cache=_REF_CC)


def test_equal_priority_is_not_preempted_when_batch_full(setup):
    """Priority preemption is strict: an equal-priority head waits for a
    free slot instead of thrashing a peer."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=1, max_len=64, chunk_size=16,
                 max_preemptions=2)
    rng = np.random.default_rng(11)
    a = Request(rid=0, prompt=_prompt(rng, 5), max_new_tokens=6)
    b = Request(rid=1, prompt=_prompt(rng, 5), max_new_tokens=6)
    eng.submit(a)
    _run_some(eng, 2)
    eng.submit(b)
    eng.run()
    assert eng.preemptions == 0
    assert a.status == b.status == "done"


# -- summarize lifecycle counts --------------------------------------------

def test_summarize_omits_empty_buckets_and_counts_outcomes():
    r_done = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=3)
    r_done.status = "done"
    r_done.output = [1, 2]
    r_err = Request(rid=1, prompt=np.zeros(4, np.int32), max_new_tokens=3)
    r_err.status = "error"
    r_err.preemptions = 2
    r_miss = Request(rid=2, prompt=np.zeros(4, np.int32), max_new_tokens=3)
    r_miss.status = "deadline_missed"
    s = Engine.summarize([r_done, r_err, r_miss])
    # no request ever produced a first token: the mean keys are OMITTED,
    # never emitted as nan (nan poisons BENCH_serving.json diffs)
    assert "mean_ttft_s" not in s and "mean_tokens_per_s" not in s
    assert s["completed"] == 1 and s["errors"] == 1
    assert s["deadline_missed"] == 1 and s["cancelled"] == 0
    assert s["preempted"] == 1 and s["preemptions"] == 2
    assert all(not (isinstance(v, float) and np.isnan(v))
               for v in s.values())


def test_terminal_states_registry():
    assert set(TERMINAL_STATES) == {"done", "error", "cancelled",
                                    "deadline_missed"}
