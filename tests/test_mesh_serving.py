"""Sharded serving tests: block homes, the mesh dispatch gate, and
single-device parity for the sequence-sharded decode paths.

The expensive parity checks run in a subprocess with 8 host devices (the
main pytest process keeps 1 device — same pattern as test_distribution).
The sharded paths must agree with the single-device dispatch at the token
level (argmax — the psum merge may reorder float additions) and at the
POOL level bitwise (every pool row is written by exactly one shard, with
masked rows absorbed by the null row's home exactly like the single-device
write path).
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import api
from repro.parallel import decode_attn
from repro.parallel.hints import use_mesh
from repro.serving.prefix import BlockAllocator


# ---------------------------------------------------------------- allocator

class TestBlockAllocatorHomes:
    def test_partition_geometry(self):
        # 39 blocks + null row = 40 rows, 4 homes of 10
        alloc = BlockAllocator(39, n_homes=4)
        assert alloc.rows_per_home == 10
        assert alloc.home(0) == 0 and alloc.home(9) == 0
        assert alloc.home(10) == 1 and alloc.home(38) == 3
        assert alloc.home(39) == 3, "null row must land in the last home"
        alloc.check()

    def test_indivisible_pool_rejected(self):
        with pytest.raises(ValueError):
            BlockAllocator(40, n_homes=4)   # 41 rows % 4 != 0

    def test_round_robin_lease_balances(self):
        alloc = BlockAllocator(39, n_homes=4)
        leased = [alloc.lease() for _ in range(36)]
        per_home = [0] * 4
        for blk in leased:
            per_home[alloc.home(blk)] += 1
        assert per_home == [9, 9, 9, 9]
        alloc.check()

    def test_targeted_lease_and_exhaustion(self):
        alloc = BlockAllocator(39, n_homes=4)
        got = [alloc.lease(home=2) for _ in range(10)]
        assert all(alloc.home(b) == 2 for b in got)
        # home 2 held rows 20..29; all ten leased, so it is now empty
        assert alloc.free_by_home()[2] == 0
        with pytest.raises(RuntimeError, match="home 2"):
            alloc.lease(home=2)
        # other homes still serve
        assert alloc.home(alloc.lease(home=0)) == 0
        for b in got:
            alloc.decref(b)
        alloc.check()

    def test_free_by_home_sums_to_free(self):
        alloc = BlockAllocator(39, n_homes=4)
        for _ in range(7):
            alloc.lease()
        assert sum(alloc.free_by_home()) == len(alloc.free)
        alloc.check()

    def test_single_home_matches_legacy(self):
        # n_homes=1 must behave exactly like the pre-home allocator: LIFO
        a = BlockAllocator(10)
        b = BlockAllocator(10, n_homes=1)
        sa = [a.lease() for _ in range(5)]
        sb = [b.lease() for _ in range(5)]
        assert sa == sb
        a.check(), b.check()


# ------------------------------------------------------- paged_homes / gate

class TestPagedHomes:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_no_mesh_is_unsharded(self):
        assert decode_attn.paged_homes(None, 4, 40) == 1

    def test_window_disables_sharding(self):
        assert decode_attn.paged_homes(self._mesh(), 4, 40, window=16) == 1

    def test_agrees_with_usable(self):
        # the engine ctor and the dispatch gate derive from the same
        # function; on any mesh, homes > 1 implies usable(paged=True)
        mesh = self._mesh()
        lens = jnp.zeros((4,), jnp.int32)
        for rows in (40, 39, 8, 7):
            homes = decode_attn.paged_homes(mesh, 4, rows)
            if homes > 1:
                assert decode_attn.usable(mesh, 4, 8, 8, rows, lens,
                                          paged=True)

    def test_slot_usable_accepts_vector_lengths(self):
        # satellite regression: per-row (B,) lengths must not be rejected
        mesh = self._mesh()
        lens = jnp.asarray([3, 9, 17, 33], jnp.int32)
        assert decode_attn.usable(mesh, 4, 8, 8, 64, lens)
        assert decode_attn.usable(mesh, 4, 8, 8, 64, jnp.int32(5))


def test_paged_dispatch_reaches_mesh_gate(monkeypatch):
    """Regression for the dead ``paged=`` gate: a paged config decoded
    under a mesh must actually consult ``usable(..., paged=True)`` with the
    pool's row count — before PR 10 the dispatch returned early and the
    gate was unreachable."""
    seen = []
    real = decode_attn.usable

    def recorder(mesh, batch, hq, hkv, S, lengths, *, paged=False):
        seen.append({"paged": paged, "S": S, "mesh": mesh is not None})
        return real(mesh, batch, hq, hkv, S, lengths, paged=paged)

    monkeypatch.setattr(decode_attn, "usable", recorder)
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                           kv_layout="paged", kv_block_size=8,
                           kv_pool_blocks=39)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    cache = api.init_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    with use_mesh(jax.make_mesh((1, 1), ("data", "model"))):
        api.decode_step(cfg, params, cache, tok,
                        jnp.asarray([3, 5], jnp.int32))
    paged_calls = [c for c in seen if c["paged"]]
    assert paged_calls, "paged decode never consulted the sharded gate"
    assert all(c["mesh"] for c in paged_calls)
    # S must be the pool's ROW count (null block included)
    assert paged_calls[0]["S"] == 40


def test_paged_sharded_one_shard_matches_single_device():
    """A 1-shard mesh exercises the full shard_map paged path; its tokens
    must match the single-device dispatch at the argmax and its pools
    bitwise (including the null-row absorption of masked writes)."""
    rng = np.random.default_rng(0)
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                           kv_layout="paged", kv_block_size=8,
                           kv_pool_blocks=39)
    B, max_len = 3, 32
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    cache = api.init_cache(cfg, B, max_len)
    perm = rng.permutation(39)
    n_pages = max_len // cfg.kv_block_size
    table = jnp.asarray(perm[:B * n_pages].reshape(B, n_pages)
                        .astype(np.int32))
    lengths = jnp.asarray([9, 17, 25], jnp.int32)
    wmask = jnp.asarray([True, True, False])
    tok = jnp.asarray(rng.integers(0, 256, (B, 1)), jnp.int32)

    l_ref, c_ref = api.decode_step(cfg, params, cache, tok, lengths,
                                   page_table=table, write_mask=wmask)
    with use_mesh(jax.make_mesh((1, 1), ("data", "model"))):
        l_sh, c_sh = jax.jit(lambda p, c, t, l, pt, wm: api.decode_step(
            cfg, p, c, t, l, page_table=pt, write_mask=wm))(
            params, cache, tok, lengths, table, wmask)

    assert bool((jnp.argmax(l_ref, -1) == jnp.argmax(l_sh, -1)).all())
    np.testing.assert_allclose(np.asarray(l_sh, np.float32),
                               np.asarray(l_ref, np.float32),
                               rtol=2e-5, atol=2e-5)
    for (path, a), b in zip(
            jax.tree_util.tree_leaves_with_path(c_ref),
            jax.tree_util.tree_leaves(c_sh)):
        assert (np.asarray(a) == np.asarray(b)).all(), \
            f"pool mismatch at {jax.tree_util.keystr(path)}"


# ------------------------------------------- 8-device subprocess parity

_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.models import api
    from repro.parallel.hints import use_mesh

    out = {}
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    rng = np.random.default_rng(0)

    def pool_ok(a, b):
        # leaf leading axis is the layer: layer-0 writes are projections of
        # identical inputs so they must be BITWISE equal; deeper layers see
        # the psum-merged attention output of the layer below, whose float
        # additions the mesh may reorder — those stay within rounding (one
        # int8 step for quantized pools)
        ok = True
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            x, y = np.asarray(x), np.asarray(y)
            ok &= bool((x[0] == y[0]).all())
            atol = 1.0 if x.dtype == np.int8 else 5e-4
            ok &= bool(np.allclose(x.astype(np.float32),
                                   y.astype(np.float32), atol=atol))
        return ok

    # --- slot layout, per-row lengths --------------------------------
    cfg_s = get_smoke_config("qwen-7b", d_model=64, d_ff=128,
                             vocab_size=256)
    ps = api.init_params(cfg_s, jax.random.PRNGKey(0))
    cache_s = api.init_cache(cfg_s, 4, 64)          # S=64, 8 per shard
    tok = jnp.asarray(rng.integers(0, 256, (4, 1)), jnp.int32)
    lens = jnp.asarray([5, 17, 33, 64], jnp.int32)
    l_ref, c_ref = api.decode_step(cfg_s, ps, cache_s, tok, lens)
    with use_mesh(mesh):
        l_sh, c_sh = jax.jit(lambda p, c, t, l: api.decode_step(
            cfg_s, p, c, t, l))(ps, cache_s, tok, lens)
    out["slot_argmax"] = bool((jnp.argmax(l_ref, -1)
                               == jnp.argmax(l_sh, -1)).all())
    out["slot_err"] = float(jnp.max(jnp.abs(l_ref - l_sh)))
    out["slot_cache_ok"] = pool_ok(c_ref, c_sh)

    # --- paged layout (fp + int8), scrambled tables ------------------
    for tag, quant in (("paged", "none"), ("paged_int8", "int8")):
        cfg_p = get_smoke_config("qwen-7b", d_model=64, d_ff=128,
                                 vocab_size=256, kv_layout="paged",
                                 kv_block_size=8, kv_pool_blocks=39,
                                 kv_quant=quant)
        pp = api.init_params(cfg_p, jax.random.PRNGKey(1))
        cache_p = api.init_cache(cfg_p, 4, 32)      # 40 rows, 5 per home
        n_pages = 4
        perm = rng.permutation(39)
        table = jnp.asarray(perm[:16].reshape(4, n_pages).astype(np.int32))
        plens = jnp.asarray([7, 15, 23, 31], jnp.int32)
        wm = jnp.asarray([True, False, True, True])
        ptok = jnp.asarray(rng.integers(0, 256, (4, 1)), jnp.int32)
        l_r, c_r = api.decode_step(cfg_p, pp, cache_p, ptok, plens,
                                   page_table=table, write_mask=wm)
        with use_mesh(mesh):
            l_s, c_s = jax.jit(lambda p, c, t, l, pt, w: api.decode_step(
                cfg_p, p, c, t, l, page_table=pt, write_mask=w))(
                pp, cache_p, ptok, plens, table, wm)
        out[tag + "_argmax"] = bool((jnp.argmax(l_r, -1)
                                     == jnp.argmax(l_s, -1)).all())
        out[tag + "_cache_ok"] = pool_ok(c_r, c_s)

    # --- fragmented page-table fuzz ----------------------------------
    cfg_f = get_smoke_config("qwen-7b", d_model=64, d_ff=128,
                             vocab_size=256, kv_layout="paged",
                             kv_block_size=8, kv_pool_blocks=39)
    pf = api.init_params(cfg_f, jax.random.PRNGKey(2))
    fuzz_ok = True
    step = jax.jit(lambda p, c, t, l, pt: api.decode_step(
        cfg_f, p, c, t, l, page_table=pt))
    for trial in range(5):
        cache_f = api.init_cache(cfg_f, 4, 32)
        perm = rng.permutation(39)[:16].reshape(4, 4).astype(np.int32)
        table = jnp.asarray(perm)
        flens = jnp.asarray(rng.integers(1, 33, (4,)), jnp.int32)
        ftok = jnp.asarray(rng.integers(0, 256, (4, 1)), jnp.int32)
        l_r, c_r = api.decode_step(cfg_f, pf, cache_f, ftok, flens,
                                   page_table=table)
        with use_mesh(mesh):
            l_s, c_s = step(pf, cache_f, ftok, flens, table)
        fuzz_ok &= bool((jnp.argmax(l_r, -1) == jnp.argmax(l_s, -1)).all())
        fuzz_ok &= pool_ok(c_r, c_s)
    out["fuzz_ok"] = bool(fuzz_ok)

    # --- engine-level: sharded engine == single-device engine --------
    from repro.serving.engine import Engine, Request
    cfg_e = get_smoke_config("qwen-7b", d_model=64, d_ff=128,
                             vocab_size=256, kv_layout="paged",
                             kv_block_size=8, kv_pool_blocks=39)
    pe = api.init_params(cfg_e, jax.random.PRNGKey(3))

    def run_engine(in_mesh):
        reqs = [Request(rid=i,
                        prompt=np.random.default_rng(100 + i).integers(
                            0, 256, 5 + 3 * i).astype(np.int32),
                        max_new_tokens=4 + i)
                for i in range(5)]
        eng = Engine(cfg_e, pe, batch_size=3, max_len=48, chunk_size=8,
                     audit_every=1)
        if in_mesh:
            assert eng.n_homes == 8, eng.n_homes
        else:
            assert eng.n_homes == 1
        for r in reqs:
            eng.submit(r)
        while not all(r.done for r in reqs):
            eng.run(max_steps=4)
            eng.audit()
            assert eng.steps < 500
        return [list(r.output) for r in reqs]

    ref_out = run_engine(False)
    with use_mesh(mesh):
        mesh_out = run_engine(True)
    out["engine_tokens_equal"] = ref_out == mesh_out

    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def worker_result():
    proc = subprocess.run(
        [sys.executable, "-c", _WORKER], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(
        f"worker failed:\nstdout={proc.stdout[-2000:]}\nstderr={proc.stderr[-3000:]}")


class TestMeshParity:
    def test_slot_per_row_lengths(self, worker_result):
        assert worker_result["slot_argmax"]
        assert worker_result["slot_err"] < 2e-4
        assert worker_result["slot_cache_ok"]

    def test_paged(self, worker_result):
        assert worker_result["paged_argmax"]
        assert worker_result["paged_cache_ok"]

    def test_paged_int8(self, worker_result):
        assert worker_result["paged_int8_argmax"]
        assert worker_result["paged_int8_cache_ok"]

    def test_fragmented_table_fuzz(self, worker_result):
        assert worker_result["fuzz_ok"]

    def test_engine_token_streams_bitwise_equal(self, worker_result):
        assert worker_result["engine_tokens_equal"]
