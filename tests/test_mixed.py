"""Chunked-prefill mixed-step tests: kernel parity, model-level exactness vs
sequential decode, engine-vs-oracle token parity under chunked admission for
all four families, chunk-size invariance, and the true-recurrent-prefill
guarantee for ssm/hybrid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache, quantize_model
from repro.kernels import ops
from repro.kernels.decode_flash import mixed_flash_attention_pallas
from repro.kernels.xla_attention import (
    decode_attention_blocked,
    mixed_attention_blocked,
)
from repro.models import api
from repro.models.attention import quantize_kv
from repro.serving.engine import Engine, Request, reference_decode

# shared across reference_decode calls so the oracle compiles once per family
_REF_CC = {}


def _oracle_cc(key):
    return _REF_CC.setdefault(key, CompileCache())


def _reqs(cfg, n, rng, *, max_new=(2, 8), lo=3, hi=20, rid0=0):
    out = []
    for i in range(n):
        frames = None
        if cfg.family == "audio":
            frames = rng.normal(
                size=(cfg.encoder_frames, cfg.d_model)).astype(np.float32)
        out.append(Request(
            rid=rid0 + i,
            prompt=rng.integers(0, cfg.vocab_size,
                                int(rng.integers(lo, hi))).astype(np.int32),
            max_new_tokens=int(rng.integers(*max_new)), frames=frames))
    return out


def _assert_oracle_parity(cfg, params, done, max_len, key):
    for r in done:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=max_len, frames=r.frames,
                               compile_cache=_oracle_cc(key))
        assert r.output == ref, f"req {r.rid} diverged from batch-1 oracle"


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

class TestMixedAttentionKernels:
    def _operands(self, *, hq=8, hkv=2, c=16, d=32, max_len=128, quant=False):
        rng = np.random.default_rng(0)
        b = 3
        q = jnp.asarray(rng.normal(size=(b, hq, c, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, hkv, max_len, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, hkv, max_len, d)), jnp.float32)
        lengths = jnp.asarray([20, 1, 97], jnp.int32)   # incl. chunk
        q_lens = jnp.asarray([16, 1, 5], jnp.int32)
        scales = {}
        if quant:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            k, v = kq, vq
            scales = {"k_scale": ks, "v_scale": vs}
        return q, k, v, lengths, q_lens, scales

    @pytest.mark.parametrize("quant", [False, True])
    @pytest.mark.parametrize("window", [None, 24])
    def test_blocked_and_pallas_match_ref(self, window, quant):
        q, k, v, lengths, q_lens, sc = self._operands(quant=quant)
        ref = ops.mixed_attention(q, k, v, lengths, q_lens, window=window,
                                  impl="ref", **sc)
        xla = mixed_attention_blocked(q, k, v, lengths, q_lens,
                                      window=window, **sc)
        pls = mixed_flash_attention_pallas(q, k, v, lengths, q_lens,
                                           window=window, interpret=True,
                                           **sc)
        np.testing.assert_allclose(np.asarray(xla), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(pls), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_dead_queries_exact_zero(self):
        q, k, v, lengths, q_lens, _ = self._operands()
        for out in (mixed_attention_blocked(q, k, v, lengths, q_lens),
                    mixed_flash_attention_pallas(q, k, v, lengths, q_lens,
                                                 interpret=True)):
            np.testing.assert_array_equal(np.asarray(out[2, :, 5:]), 0.0)

    def test_qlen1_bitwise_equals_decode(self):
        """A chunk of one is literally the decode kernel's contract."""
        q, k, v, lengths, _, _ = self._operands(c=1)
        dec = decode_attention_blocked(q, k, v, lengths)
        mix = mixed_attention_blocked(q, k, v, lengths,
                                      jnp.ones((3,), jnp.int32))
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(mix))

    def test_mqa_group_packing(self):
        q, k, v, lengths, q_lens, _ = self._operands(hq=8, hkv=1)
        ref = ops.mixed_attention(q, k, v, lengths, q_lens, impl="ref")
        xla = mixed_attention_blocked(q, k, v, lengths, q_lens)
        np.testing.assert_allclose(np.asarray(xla), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# model level: mixed_step == sequential decode_step, bit for bit
# ---------------------------------------------------------------------------

ARCHS = ["qwen-7b", "xlstm-1.3b", "zamba2-7b", "whisper-small"]


def _setup_family(arch, **overrides):
    cfg = get_smoke_config(arch, **overrides)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(1, cfg.encoder_frames, cfg.d_model)), jnp.float32)
    return cfg, params, batch, rng


def _seq_feed(cfg, params, cache, toks, start=0):
    logits = None
    for t, tok in enumerate(toks):
        logits, cache = api.decode_step(
            cfg, params, cache, jnp.asarray([[tok]], jnp.int32),
            jnp.asarray([start + t + 1], jnp.int32))
    return logits, cache


def _chunk_feed(cfg, params, cache, toks, c, start=0):
    logits, length = None, start
    while length - start < len(toks):
        ql = min(c, len(toks) - (length - start))
        chunk = np.zeros(c, np.int32)
        chunk[:ql] = toks[length - start:length - start + ql]
        logits, cache = api.mixed_step(
            cfg, params, cache, jnp.asarray(chunk[None]),
            jnp.asarray([length], jnp.int32), jnp.asarray([ql], jnp.int32))
        length += ql
    return logits, cache


@pytest.mark.parametrize("arch", ARCHS + ["qwen-7b-int8"])
def test_mixed_step_equals_sequential_decode(arch):
    """Chunked admission through mixed_step must reproduce the sequential
    decode_step cache AND last-token logits exactly — this is what makes
    the engine's chunk path oracle-safe, and for ssm/hybrid it IS the
    true-recurrent-prefill guarantee."""
    overrides = {"kv_quant": "int8"} if arch.endswith("-int8") else {}
    cfg, params, batch, rng = _setup_family(arch.removesuffix("-int8"),
                                            **overrides)
    prompt = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)
    row0 = api.request_cache(cfg, params, batch, 32)
    sl, scache = _seq_feed(cfg, params, row0, prompt)
    ml, mcache = _chunk_feed(cfg, params, row0, prompt, c=8)
    np.testing.assert_array_equal(np.asarray(sl), np.asarray(ml))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), scache, mcache)


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "zamba2-7b"])
def test_true_recurrent_prefill(arch):
    """ssm/hybrid chunked admission materializes the POST-PROMPT state (the
    PR 1 forward-as-prefill gap): continuations condition on the prompt —
    two prompts sharing their last token diverge afterwards."""
    cfg, params, batch, rng = _setup_family(arch)
    p1 = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    p2[-1] = p1[-1]                     # same last token, different prefix
    row0 = api.request_cache(cfg, params, batch, 32)
    _, c1 = _chunk_feed(cfg, params, row0, p1, c=8)
    _, c2 = _chunk_feed(cfg, params, row0, p2, c=8)
    fresh = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), c1, row0)))
    diverged = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), c1, c2)))
    assert fresh > 0, "post-prompt state must differ from the fresh state"
    assert diverged > 0, "state must depend on the full prompt, not its tail"
    # and the continuation tokens themselves differ through the engine path
    o1 = reference_decode(cfg, params, p1, 4, max_len=32,
                          frames=None, compile_cache=_oracle_cc(arch))
    o2 = reference_decode(cfg, params, p2, 4, max_len=32,
                          frames=None, compile_cache=_oracle_cc(arch))
    assert o1 != o2


def test_chunk_size_invariance():
    """C=4 vs C=8 vs C=13 (!= power of two) give identical logits/cache."""
    cfg, params, batch, rng = _setup_family("qwen-7b")
    prompt = rng.integers(0, cfg.vocab_size, 26).astype(np.int32)
    row0 = api.request_cache(cfg, params, batch, 64)
    outs = [_chunk_feed(cfg, params, row0, prompt, c=c) for c in (4, 8, 13)]
    for logits, cache in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                      np.asarray(logits))
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), outs[0][1], cache)


def test_mixed_step_idle_rows_untouched():
    """q_lens == 0 rows must not move: cache unchanged even at the MAX
    boundary (the clamped-write hazard the roll-merge write guards)."""
    cfg, params, _, rng = _setup_family("qwen-7b")
    max_len = 32
    cache = api.init_cache(cfg, 2, max_len)
    # fill row 1 to the brim so a naive C-wide dynamic_update_slice at its
    # length would clamp backwards over valid KV
    prompt = rng.integers(0, cfg.vocab_size, max_len).astype(np.int32)
    full = jnp.asarray(np.stack([np.zeros(max_len, np.int32), prompt]))
    _, cache = api.mixed_step(cfg, params, cache, full,
                              jnp.asarray([0, 0], jnp.int32),
                              jnp.asarray([0, max_len], jnp.int32))
    before = jax.tree.map(lambda a: np.asarray(a).copy(), cache)
    tokens = np.zeros((2, 8), np.int32)
    tokens[0, :3] = prompt[:3]
    _, after = api.mixed_step(cfg, params, cache, jnp.asarray(tokens),
                              jnp.asarray([0, max_len], jnp.int32),
                              jnp.asarray([3, 0], jnp.int32))

    def row1_unchanged(b4, a):
        np.testing.assert_array_equal(np.asarray(a)[:, 1], b4[:, 1])
    jax.tree.map(row1_unchanged, before, after)


# ---------------------------------------------------------------------------
# engine level: chunked admission, all four families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS + ["qwen-7b-int8"])
def test_engine_chunked_admission_matches_oracle(arch):
    """Engine output token-for-token equal to the sequential batch-1 oracle
    under chunked admission, for every family (incl. int8-KV), with compile
    misses bounded by n_chunk_buckets + 2 (+1 audio encode)."""
    overrides = {"kv_quant": "int8"} if arch.endswith("-int8") else {}
    cfg, params, _, rng = _setup_family(arch.removesuffix("-int8"),
                                        **overrides)
    engine = Engine(cfg, params, batch_size=2, max_len=32, chunk_size=8)
    for r in _reqs(cfg, 5, rng):
        engine.submit(r)
    done = engine.run()
    assert len(done) == 5 and all(r.done for r in done)
    assert engine.dispatches == engine.steps   # one dispatch per tick
    assert engine.cache_compiles.misses <= engine.compile_budget
    _assert_oracle_parity(cfg, params, done, 32, arch)


def test_engine_chunk_size_invariance():
    """C=4 and C=16 engines emit identical tokens (schedule-independent)."""
    cfg, params, _, rng = _setup_family("qwen-7b")
    outs = []
    for c in (4, 16):
        engine = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=c)
        rng_c = np.random.default_rng(7)
        for r in _reqs(cfg, 6, rng_c, hi=40):
            engine.submit(r)
        done = engine.run()
        outs.append({r.rid: r.output for r in done})
    assert outs[0] == outs[1]


def test_engine_true_length_accounting():
    """Satellite regression: slots track TRUE lengths (cache occupancy ==
    real token count), never the padded bucket the old engine stored — so a
    10-token prompt in a 16-slot cache decodes 16-10+1 = 7 tokens instead
    of dying at admission (its bucket was 16) and never attends over pads."""
    cfg, params, _, rng = _setup_family("qwen-7b")
    engine = Engine(cfg, params, batch_size=1, max_len=16, chunk_size=8)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=100))
    assert engine.run(max_steps=1) == []
    assert engine._slots[0].length == 8          # first chunk, true cursor
    done = engine.run()
    assert engine._slots[0].req is None
    # decode fills the cache to EXACTLY max_len true tokens then retires:
    # prompt(10) + 6 generated-and-cached + 1 final pending = 7 out
    assert len(done[0].output) == 16 - 10 + 1
    _assert_oracle_parity(cfg, params, done, 16, "truelen")


def test_engine_admits_prompts_up_to_max_len():
    """Satellite regression: the old engine dropped prompts whose BUCKET hit
    max_len even though real cache room remained.  True-length admission
    decodes them in full; a prompt of exactly max_len still finishes at its
    first token (no room to decode into) and matches the oracle."""
    cfg, params, _, rng = _setup_family("qwen-7b")
    engine = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16)
    p_bucket = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)  # b=64
    p_full = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    engine.submit(Request(rid=0, prompt=p_bucket, max_new_tokens=5))
    engine.submit(Request(rid=1, prompt=p_full, max_new_tokens=5))
    done = {r.rid: r for r in engine.run()}
    assert len(done[0].output) == 5      # old engine finished this at 1
    assert len(done[1].output) == 1      # genuinely no room past max_len
    _assert_oracle_parity(cfg, params, done.values(), 64, "admit")
    with pytest.raises(ValueError, match="exceeds engine max_len"):
        engine.submit(Request(rid=2, prompt=np.zeros(65, np.int32)))


def test_engine_stall_policy_matches_mixed_tokens():
    """The stall-prefill baseline is a SCHEDULE, not different numerics:
    same tokens, strictly more ticks (decode rows stall during admission)."""
    cfg, params, _, _ = _setup_family("qwen-7b")
    outs, steps = [], []
    for policy in ("mixed", "stall"):
        engine = Engine(cfg, params, batch_size=3, max_len=64, chunk_size=8,
                        prefill_policy=policy)
        rng = np.random.default_rng(3)
        for r in _reqs(cfg, 6, rng, hi=40, max_new=(4, 9)):
            engine.submit(r)
        done = engine.run()
        outs.append({r.rid: r.output for r in done})
        steps.append(engine.steps)
    assert outs[0] == outs[1]
    assert steps[1] > steps[0]       # head-of-line blocking costs ticks


def test_engine_prefill_token_budget():
    """Sarathi budget caps chunk tokens per tick but never starves a tick
    (at least one admission row always advances); outputs are unchanged."""
    cfg, params, _, _ = _setup_family("qwen-7b")
    outs = []
    for budget in (None, 8):
        engine = Engine(cfg, params, batch_size=3, max_len=64, chunk_size=8,
                        prefill_token_budget=budget)
        rng = np.random.default_rng(4)
        for r in _reqs(cfg, 5, rng, hi=40):
            engine.submit(r)
        done = engine.run()
        outs.append({r.rid: r.output for r in done})
    assert outs[0] == outs[1]


def test_quantized_params_engine_parity():
    """W4A16 weights + chunked admission + int8 KV all at once."""
    cfg = get_smoke_config("qwen-7b", d_model=128, d_ff=256, vocab_size=512,
                           kv_quant="int8")
    params = quantize_model(api.init_params(cfg, jax.random.PRNGKey(0)),
                            "dense")
    rng = np.random.default_rng(5)
    engine = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16)
    for r in _reqs(cfg, 4, rng, hi=40):
        engine.submit(r)
    done = engine.run()
    assert len(done) == 4
    _assert_oracle_parity(cfg, params, done, 64, "w4a16-int8")
