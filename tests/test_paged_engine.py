"""Paged-KV serving-engine tests: a randomized admission/retire soak under
pool pressure (checked token-for-token against ``reference_decode``, with
free-list leak/double-free invariants), slot-reuse safety across all four
families (evict mid-run, readmit a different-length prompt into the same
slot and blocks), and the allocator's reservation guarantees."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache
from repro.models import api
from repro.serving.engine import Engine, Request, reference_decode

# shared so the oracle compiles once per (family, kv_quant, layout) key
_REF_CC = {}


def _oracle_cc(key):
    return _REF_CC.setdefault(key, CompileCache())


def _tiny_cfg(**over):
    return get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                            kv_layout="paged", kv_block_size=8, **over)


def _assert_pool_intact(engine):
    stats = engine.pool_stats()
    assert stats["leased"] == 0 and stats["reserved_outstanding"] == 0
    free = engine._free_blocks
    assert len(free) == engine.pool_blocks, "free list leaked blocks"
    assert sorted(free) == list(range(engine.pool_blocks)), \
        "free list holds duplicate or foreign block ids"


def _assert_oracle_parity(cfg, params, done, max_len, key):
    for r in done:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=max_len, frames=r.frames,
                               compile_cache=_oracle_cc(key))
        assert r.output == ref, \
            f"req {r.rid} diverged from the fresh-cache batch-1 oracle"


@pytest.mark.parametrize("kv_quant", ["none", "int8"])
def test_engine_soak_randomized(kv_quant):
    """Randomized admission/retire schedule under pool pressure: mixed
    prompt lengths, staggered mid-flight retirements (and the slot/block
    reuse they trigger), a pool too small to hold every request's worst
    case at once — every finished request must match ``reference_decode``
    token for token, and the free list must come back whole."""
    cfg = _tiny_cfg(kv_quant=kv_quant, kv_pool_blocks=12)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    max_len = 48
    rng = np.random.default_rng(7)
    engine = Engine(cfg, params, batch_size=5, max_len=max_len, chunk_size=8)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(3, 21))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 7)))
            for i in range(14)]
    for r in reqs:
        engine.submit(r)

    # drain in bursts so pool invariants are checked mid-flight too
    while True:
        engine.run(max_steps=3)
        stats = engine.pool_stats()
        assert stats["free"] + stats["leased"] == stats["total"]
        assert stats["reserved_outstanding"] <= stats["free"], \
            "reservation invariant violated: an admitted row could stall"
        if sum(r.done for r in reqs) == len(reqs):
            break
        assert engine.steps < 2000, "engine stopped making progress"

    assert engine.admission_stalls > 0, (
        "soak parameters lost their pool pressure — shrink kv_pool_blocks")
    _assert_pool_intact(engine)
    _assert_oracle_parity(cfg, params, reqs, max_len,
                          ("soak", kv_quant))


ARCHS = ["qwen-7b", "xlstm-1.3b", "zamba2-7b", "whisper-small"]


@pytest.mark.parametrize("arch", ARCHS + ["qwen-7b-int8"])
def test_slot_reuse_readmission(arch):
    """Evict a row mid-decode (staggered finishes force it), readmit a
    DIFFERENT-length prompt into the same slot — and, paged, into recycled
    physical blocks under a different page-table assignment.  Every
    request must match a fresh-cache oracle run exactly."""
    kv_quant = "int8" if arch.endswith("-int8") else "none"
    name = arch.removesuffix("-int8")
    cfg = get_smoke_config(name, kv_quant=kv_quant, kv_layout="paged",
                           kv_block_size=8)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    max_len = 40
    rng = np.random.default_rng(11)

    def mk(rid, plen, max_new):
        frames = None
        if cfg.family == "audio":
            frames = rng.normal(size=(cfg.encoder_frames, cfg.d_model)
                                ).astype(np.float32)
        return Request(rid=rid,
                       prompt=rng.integers(0, cfg.vocab_size, plen
                                           ).astype(np.int32),
                       max_new_tokens=max_new, frames=frames)

    # batch 2, 4 requests of different lengths: rid 0 retires first (short),
    # rid 2 readmits into its slot while rid 1 is still mid-decode; rid 3
    # then reuses whichever slot frees next
    reqs = [mk(0, 4, 2), mk(1, 9, 8), mk(2, 13, 3), mk(3, 6, 4)]
    engine = Engine(cfg, params, batch_size=2, max_len=max_len, chunk_size=6)
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert len(done) == len(reqs)
    if engine.paged:
        _assert_pool_intact(engine)
    _assert_oracle_parity(cfg, params, reqs, max_len, (name, kv_quant))


def test_paged_matches_slot_engine_tokens():
    """Same workload through a slot engine and a paged engine (scrambling
    leases via staggered retirement): identical output streams."""
    cfg_slot = get_smoke_config("qwen-7b", d_model=64, d_ff=128,
                                vocab_size=256)
    cfg_paged = dataclasses.replace(cfg_slot, kv_layout="paged",
                                    kv_block_size=8)
    params = api.init_params(cfg_slot, jax.random.PRNGKey(2))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg_slot.vocab_size,
                            int(rng.integers(3, 15))).astype(np.int32)
               for _ in range(6)]

    def run(cfg):
        engine = Engine(cfg, params, batch_size=3, max_len=32, chunk_size=6)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=3 + (i % 3))
                for i, p in enumerate(prompts)]
        for r in reqs:
            engine.submit(r)
        engine.run()
        return [r.output for r in reqs]

    assert run(cfg_slot) == run(cfg_paged)


# ---------------------------------------------------------------------------
# allocator unit guarantees
# ---------------------------------------------------------------------------

def _alloc_engine(**over):
    cfg = _tiny_cfg(**over)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, batch_size=3, max_len=32, chunk_size=4)


def test_oversized_request_rejected_at_submit():
    engine = _alloc_engine(kv_pool_blocks=2)        # 16-token pool
    with pytest.raises(ValueError, match="KV blocks"):
        engine.submit(Request(rid=0, prompt=np.arange(20, dtype=np.int32),
                              max_new_tokens=8))


def test_double_free_detected():
    engine = _alloc_engine()
    engine._slots[0].req = Request(rid=0, prompt=np.arange(4, dtype=np.int32))
    engine._slot_reserve[0] = 2
    engine._reserve_home[0] = [2]   # single-home engine
    engine._lease_to(0, 9)                 # 2 blocks
    engine._slot_blocks[0].append(engine._free_blocks[0])  # corrupt: alias
    with pytest.raises(RuntimeError, match="double free"):
        engine._free_slot(0)


def test_lease_respects_page_table():
    engine = _alloc_engine()
    engine._slots[0].req = Request(rid=0, prompt=np.arange(4, dtype=np.int32))
    engine._slot_reserve[0] = 3
    engine._reserve_home[0] = [3]   # single-home engine
    engine._lease_to(0, 17)                # 3 blocks (bs=8)
    owned = engine._slot_blocks[0]
    assert len(owned) == 3 and len(set(owned)) == 3
    np.testing.assert_array_equal(engine._page_table[0, :3], owned)
    assert (engine._page_table[0, 3:] == engine._null_block).all()
    assert (engine._page_table[1:] == engine._null_block).all()
    engine._free_slot(0)
    assert (engine._page_table[0] == engine._null_block).all()
    _assert_pool_intact(engine)
