"""Paged-KV parity fuzz: paged pallas-interpret / paged xla / gathered ref
against the contiguous slot layout, BIT for bit, over randomized
(B, lengths, q_lens, GQA ratio, block_size, kv_quant) draws — including page
tables with deliberately scrambled (non-identity, fragmented) physical
orderings.

Paging is a LAYOUT change, not a numerics change: every impl walks the same
logical blocks in the same order with the same tile size, so each paged impl
must reproduce its contiguous twin exactly when the contiguous walk is
pinned to the page size as its KV tile (ref needs no pinning — the paged
oracle gathers the pool contiguous first).  The deterministic parametrized
cases below run everywhere; the hypothesis harness widens the draw space in
CI.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels import ops
from repro.kernels.decode_flash import mixed_flash_attention_pallas
from repro.kernels.xla_attention import mixed_attention_blocked
from repro.models import api
from repro.models.attention import quantize_kv


def _scrambled_pool(k, v, block_size, rng, *, quant, extra_blocks=3):
    """Scatter a contiguous (B, hkv, S, d) cache into a shared pool under a
    random (fragmented, non-identity) block assignment.  Returns
    (pool_leaves, pool_scales, page_table).  Unassigned pool blocks hold
    nonzero garbage so any aliasing/gather bug surfaces as a mismatch; the
    null block (last) is garbage too — it must never be read unmasked."""
    B, hkv, S, d = np.asarray(k).shape
    n_pages = S // block_size
    total = B * n_pages + extra_blocks
    perm = rng.permutation(total)[: B * n_pages]
    table = perm.reshape(B, n_pages).astype(np.int32)

    def scatter(src, fill):
        pool = np.full((total + 1, hkv, block_size) + src.shape[3:],
                       fill, np.asarray(src).dtype)
        s = np.asarray(src)
        for b in range(B):
            for p in range(n_pages):
                pool[table[b, p]] = s[b, :, p * block_size:(p + 1) * block_size]
        return jnp.asarray(pool)

    scales = {}
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        leaves = {"k": scatter(kq, 17), "v": scatter(vq, -23)}
        scales = {"k_scale": scatter(ks, 0.5), "v_scale": scatter(vs, 0.5)}
    else:
        leaves = {"k": scatter(k, 3.25), "v": scatter(v, -7.5)}
    return leaves, scales, jnp.asarray(table)


def _check_paged_parity(*, B, hq, hkv, S, d, block_size, quant, seed,
                        chunk=None, window=None):
    """The fuzz property: for random operands and a scrambled pool, each
    paged impl is BITWISE equal to its contiguous twin, and all impls agree
    with the dense ref to float tolerance."""
    rng = np.random.default_rng(seed)
    sq = chunk or 1
    q = jnp.asarray(rng.normal(size=(B, hq, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, hkv, S, d)), jnp.float32)
    lengths = jnp.asarray(
        rng.integers(max(sq, 1), S + 1, size=B).astype(np.int32))
    q_lens = jnp.asarray(
        rng.integers(0, sq + 1, size=B).astype(np.int32))
    lengths = jnp.maximum(lengths, q_lens)   # chunk included in context
    kc, vc = k, v
    sc = {}
    if quant:
        kc, ks = quantize_kv(k)
        vc, vs = quantize_kv(v)
        sc = {"k_scale": ks, "v_scale": vs}
    pool, pool_sc, table = _scrambled_pool(k, v, block_size, rng, quant=quant)

    if chunk is None:
        q_lens = jnp.ones((B,), jnp.int32)

    def contiguous(impl):
        if impl == "ref":
            return ops.mixed_attention(q, kc, vc, lengths, q_lens,
                                       window=window, impl="ref", **sc)
        if impl == "xla":
            return mixed_attention_blocked(q, kc, vc, lengths, q_lens,
                                           window=window, block_kv=block_size,
                                           **sc)
        return mixed_flash_attention_pallas(q, kc, vc, lengths, q_lens,
                                            window=window,
                                            block_kv=block_size,
                                            interpret=True, **sc)

    def paged(impl):
        return ops.mixed_attention(q, pool["k"], pool["v"], lengths, q_lens,
                                   window=window, impl=impl,
                                   page_table=table, **pool_sc)

    outs = {}
    for impl in ("ref", "xla", "pallas"):
        got, want = np.asarray(paged(impl)), np.asarray(contiguous(impl))
        np.testing.assert_array_equal(
            got, want,
            err_msg=f"paged {impl} != contiguous {impl} at matched KV tile "
                    "(physical layout must be invisible to numerics)")
        outs[impl] = got
    np.testing.assert_allclose(outs["xla"], outs["ref"], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["pallas"], outs["ref"], rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("quant", [False, True])
@pytest.mark.parametrize("B,hq,hkv,block_size", [
    (1, 4, 4, 8),            # MHA, batch 1
    (3, 8, 2, 16),           # GQA
    (4, 4, 1, 32),           # MQA
])
def test_decode_paged_parity(B, hq, hkv, block_size, quant):
    _check_paged_parity(B=B, hq=hq, hkv=hkv, S=64, d=32,
                        block_size=block_size, quant=quant, seed=B + hq)


@pytest.mark.parametrize("quant", [False, True])
@pytest.mark.parametrize("B,hq,hkv,block_size,chunk", [
    (3, 8, 2, 16, 8),
    (2, 4, 1, 8, 4),
])
def test_mixed_paged_parity(B, hq, hkv, block_size, chunk, quant):
    _check_paged_parity(B=B, hq=hq, hkv=hkv, S=64, d=32,
                        block_size=block_size, quant=quant, chunk=chunk,
                        seed=3 * B + hq)


def test_windowed_paged_parity():
    _check_paged_parity(B=3, hq=8, hkv=2, S=64, d=32, block_size=8,
                        quant=False, chunk=4, window=24, seed=11)


def test_fragmented_reuse_bitwise():
    """Two different scrambles of the SAME logical cache agree bitwise —
    physical placement is pure routing."""
    rng = np.random.default_rng(0)
    B, hkv, S, d, bs = 2, 2, 64, 32, 8
    q = jnp.asarray(rng.normal(size=(B, 4, 1, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, hkv, S, d)), jnp.float32)
    lengths = jnp.asarray([50, 9], jnp.int32)
    outs = []
    for seed in (1, 2):
        pool, _, table = _scrambled_pool(
            k, v, bs, np.random.default_rng(seed), quant=False)
        outs.append(np.asarray(ops.decode_attention(
            q, pool["k"], pool["v"], lengths, impl="xla", page_table=table)))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_model_level_paged_equals_slot_tokens():
    """Full model: batch-1 greedy decode, paged cfg vs slot cfg — identical
    token stream (bit-level logits may differ: block-walk tile sizes)."""
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256)
    cfg_p = dataclasses.replace(cfg, kv_layout="paged", kv_block_size=8)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)

    def greedy(c):
        cache = api.init_cache(c, 1, 32)
        step = jax.jit(lambda p, ca, t, n: api.decode_step(c, p, ca, t, n))
        logits, n, out = None, 0, []
        for t in prompt.tolist():
            n += 1
            logits, cache = step(params, cache,
                                 jnp.asarray([[t]], jnp.int32),
                                 jnp.asarray([n], jnp.int32))
        for _ in range(6):
            tok = int(np.argmax(np.asarray(logits[0])))
            out.append(tok)
            n += 1
            logits, cache = step(params, cache,
                                 jnp.asarray([[tok]], jnp.int32),
                                 jnp.asarray([n], jnp.int32))
        return out

    assert greedy(cfg) == greedy(cfg_p)


# ---------------------------------------------------------------------------
# hypothesis harness (CI: hypothesis ships in requirements-dev)
# ---------------------------------------------------------------------------

try:        # guarded, NOT importorskip: the deterministic cases above must
    from hypothesis import given, settings, strategies as st  # noqa: E402
    _HAVE_HYPOTHESIS = True       # run even without hypothesis installed
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def _paged_case(draw):
        hkv = draw(st.sampled_from([1, 2, 4]))
        rep = draw(st.sampled_from([1, 2, 4]))
        block_size = draw(st.sampled_from([8, 16, 32]))
        n_pages = draw(st.integers(1, 4))
        chunk = draw(st.sampled_from([None, 2, 4]))
        return {
            "B": draw(st.integers(1, 4)),
            "hq": hkv * rep,
            "hkv": hkv,
            "S": block_size * n_pages,
            "d": draw(st.sampled_from([16, 32])),
            "block_size": block_size,
            "quant": draw(st.booleans()),
            "chunk": chunk,
            "seed": draw(st.integers(0, 2**16)),
        }

    @settings(max_examples=12, deadline=None)
    @given(case=_paged_case())
    def test_paged_parity_fuzz(case):
        if case["chunk"] is not None and case["S"] < case["chunk"]:
            case["chunk"] = None
        _check_paged_parity(**case)
else:
    @pytest.mark.skip(reason="property fuzz needs hypothesis "
                             "(pip install -r requirements-dev.txt)")
    def test_paged_parity_fuzz():
        pass
