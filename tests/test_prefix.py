"""Prefix-sharing tests: the refcounted ``BlockAllocator`` and
``RadixPrefixCache`` units, a host-level fuzz of interleaved
admit/evict/rewind/CoW schedules against a brute-force dict oracle
(refcount-leak and double-free invariants), and the engine-level
guarantees — token streams with the prefix cache ON are BITWISE equal to
the cache-OFF engine and ``reference_decode`` (sharing is exact), the
radix-admission paths (aligned hit, mid-block CoW, full-coverage CoW)
all fire, LRU leaf eviction relieves pool pressure, and the pool comes
back whole after the cache is dropped.

The deterministic cases run everywhere; the hypothesis harness widens the
draw space in CI.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache
from repro.models import api
from repro.serving.engine import Engine, Request, reference_decode
from repro.serving.prefix import BlockAllocator, RadixPrefixCache

# shared so the oracle compiles once per (family, kv_quant) key
_REF_CC = {}


def _oracle_cc(key):
    return _REF_CC.setdefault(key, CompileCache())


def _tiny_cfg(**over):
    return get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                            kv_layout="paged", kv_block_size=8, **over)


# ---------------------------------------------------------------------------
# BlockAllocator units
# ---------------------------------------------------------------------------

def test_allocator_lease_share_decref_roundtrip():
    a = BlockAllocator(4)
    assert a.n_free == 4 and a.n_live == 0
    blk = a.lease()
    assert a.ref(blk) == 1 and a.n_live == 1
    a.incref(blk)                      # second holder (a shared mapping)
    assert a.ref(blk) == 2 and a.n_shared() == 1
    assert a.decref(blk) is False      # still held: NOT freed
    assert a.n_shared() == 0 and a.n_live == 1
    assert a.decref(blk) is True       # last holder: back on the free list
    assert a.n_free == 4 and a.n_live == 0
    a.check()


def test_allocator_double_free_and_dead_incref_rejected():
    a = BlockAllocator(2)
    blk = a.lease()
    a.decref(blk)
    with pytest.raises(RuntimeError, match="double free"):
        a.decref(blk)
    with pytest.raises(RuntimeError, match="incref of dead"):
        a.incref(blk)


def test_allocator_check_catches_corruption():
    a = BlockAllocator(3)
    blk = a.lease()
    a.free.append(blk)                 # corrupt: live block on the free list
    with pytest.raises(AssertionError):
        a.check()
    a = BlockAllocator(3)
    a.refs[1] = 1                      # corrupt: leaked refcount
    with pytest.raises(AssertionError):
        a.check()


def test_allocator_exhaustion_raises():
    a = BlockAllocator(1)
    a.lease()
    with pytest.raises(RuntimeError, match="exhausted"):
        a.lease()


# ---------------------------------------------------------------------------
# RadixPrefixCache units
# ---------------------------------------------------------------------------

def test_radix_match_full_chain_and_partial_head():
    c = RadixPrefixCache(4)
    toks = list(range(12))             # 3 full blocks
    assert c.insert(toks, [10, 11, 12]) == [10, 11, 12]
    # full hit on a longer prompt
    full, partial = c.match(toks + [99, 98])
    assert full == [10, 11, 12] and partial is None
    # divergence mid second block: one full block + partial head of block 11
    full, partial = c.match([0, 1, 2, 3, 4, 5, 77, 77])
    assert full == [10] and partial == (11, 2)
    # divergence at the first token of a block: no partial (nothing to CoW)
    full, partial = c.match([0, 1, 2, 3, 66, 66, 66, 66])
    assert full == [10] and partial is None
    # cold prompt: nothing
    assert c.match([50, 51, 52, 53]) == ([], None)


def test_radix_insert_dedup_keeps_first_author():
    c = RadixPrefixCache(4)
    assert c.insert([0, 1, 2, 3], [7]) == [7]
    # identical chunk from a second author: dedup, duplicate stays private
    assert c.insert([0, 1, 2, 3, 9, 9, 9, 9], [8, 5]) == [5]
    full, _ = c.match([0, 1, 2, 3])
    assert full == [7]                 # the first author's block won
    assert len(c) == 2 and sorted(c.blocks()) == [5, 7]


def test_radix_insert_rejects_partial_blocks():
    c = RadixPrefixCache(4)
    with pytest.raises(ValueError, match="fully-written"):
        c.insert([0, 1, 2], [7])       # 3 tokens cannot fill a 4-token block


def test_radix_lru_leaf_eviction():
    c = RadixPrefixCache(2)
    c.insert([0, 1, 2, 3], [10, 11])   # chain root -> 10 -> 11
    c.insert([0, 1, 8, 9], [10, 12])   # sibling leaf 12 under 10
    c.match([0, 1, 2, 3])              # touches the 10 -> 11 path (12 is LRU)
    assert c.evict_lru() == 12         # leaf-only AND least recently used
    assert c.evict_lru(keep=lambda b: b == 11) is None  # 10 is no leaf; 11 kept
    assert c.evict_lru() == 11         # the chain peels back from its tip
    assert c.evict_lru() == 10
    assert c.evict_lru() is None and len(c) == 0


def test_radix_clear_returns_every_block():
    c = RadixPrefixCache(2)
    c.insert([0, 1, 2, 3], [4, 5])
    c.insert([0, 1, 6, 7], [4, 6])
    assert sorted(c.clear()) == [4, 5, 6]
    assert len(c) == 0 and c.match([0, 1]) == ([], None)


# ---------------------------------------------------------------------------
# host-level fuzz: interleaved admit/evict/rewind/CoW vs a dict oracle
# ---------------------------------------------------------------------------

def _check_host_property(seed: int, n_ops: int = 120, n_blocks: int = 12,
                         block_size: int = 4):
    """Drive the allocator + radix cache through a random interleaving of
    the engine's host operations — admit (match -> incref shared, lease the
    suffix, CoW-lease on a mid-block hit), retire (decref all), rewind
    (decref the tail), cache-insert (incref fresh nodes), evict — and check
    after EVERY op against a brute-force dict oracle of per-block
    refcounts.  Then drain everything and require the pool back whole:
    zero refcount leaks, zero double frees."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(n_blocks)
    cache = RadixPrefixCache(block_size)
    oracle: dict[int, int] = {}        # block -> expected refcount
    slots: list[dict] = []             # live "requests"
    vocab = 6                          # small: collisions make hits likely

    def oracle_lease(blk):
        assert oracle.get(blk, 0) == 0
        oracle[blk] = 1

    def oracle_decref(blk):
        assert oracle.get(blk, 0) >= 1, f"double free of {blk} in schedule"
        oracle[blk] -= 1

    for _ in range(n_ops):
        op = rng.choice(["admit", "retire", "rewind", "insert", "evict"])
        if op == "admit" and len(slots) < 4:
            want = int(rng.integers(1, 4 * block_size))
            prompt = rng.integers(0, vocab, want).tolist()
            full, partial = cache.match(prompt)
            consumed = len(full) * block_size
            cow = None
            if partial is not None:
                n = min(partial[1], len(prompt) - 1 - consumed)
                if n > 0:
                    cow, consumed = partial[0], consumed + n
            elif consumed >= len(prompt):
                cow = full.pop()
                consumed = len(prompt) - 1
            need = -(-len(prompt) // block_size) - len(full)
            if alloc.n_free < need:
                continue               # admission stall
            owned = list(full)
            for blk in full:
                alloc.incref(blk)
                oracle[blk] = oracle.get(blk, 0) + 1
            if cow is not None:        # the CoW copy leases a private block
                blk = alloc.lease()
                oracle_lease(blk)
                owned.append(blk)
            for _ in range(len(owned),
                           -(-len(prompt) // block_size)):
                blk = alloc.lease()
                oracle_lease(blk)
                owned.append(blk)
            slots.append({"prompt": prompt, "blocks": owned})
        elif op == "retire" and slots:
            s = slots.pop(int(rng.integers(len(slots))))
            for blk in s["blocks"]:
                alloc.decref(blk)
                oracle_decref(blk)
        elif op == "rewind" and slots:
            s = slots[int(rng.integers(len(slots)))]
            if len(s["blocks"]) > 1:
                blk = s["blocks"].pop()
                alloc.decref(blk)
                oracle_decref(blk)
                s["prompt"] = s["prompt"][:len(s["blocks"]) * block_size]
        elif op == "insert" and slots:
            s = slots[int(rng.integers(len(slots)))]
            nfull = len(s["prompt"]) // block_size
            if nfull:
                fresh = cache.insert(s["prompt"][:nfull * block_size],
                                     s["blocks"][:nfull])
                for blk in fresh:
                    alloc.incref(blk)
                    oracle[blk] = oracle.get(blk, 0) + 1
        elif op == "evict":
            blk = cache.evict_lru(keep=lambda b: alloc.ref(b) > 1)
            if blk is not None:
                assert alloc.decref(blk) is True  # cache was sole holder
                oracle_decref(blk)
        # the brute-force oracle must agree block for block, every step
        alloc.check()
        for blk in range(n_blocks):
            assert alloc.ref(blk) == oracle.get(blk, 0), \
                f"block {blk}: alloc={alloc.ref(blk)} oracle={oracle.get(blk, 0)}"

    for s in slots:                    # drain: every reference accounted for
        for blk in s["blocks"]:
            alloc.decref(blk)
    for blk in cache.clear():
        alloc.decref(blk)
    alloc.check()
    assert alloc.n_free == n_blocks and alloc.n_live == 0, "refcount leak"


@pytest.mark.parametrize("seed", [0, 1, 7, 23, 101])
def test_host_fuzz_deterministic(seed):
    _check_host_property(seed)


# ---------------------------------------------------------------------------
# engine-level guarantees
# ---------------------------------------------------------------------------

def _assert_pool_whole(engine):
    engine.drop_prefix_cache()
    engine.alloc.check()
    stats = engine.pool_stats()
    assert stats["leased"] == 0 and stats["reserved_outstanding"] == 0
    assert stats["free"] == stats["total"], "free list leaked blocks"


def _run_engine(cfg, params, prompts, *, prefix_cache, max_new=5, batch=2,
                max_len=96, chunk_size=8, spec_k=0, frames=None, waves=1):
    engine = Engine(cfg, params, batch_size=batch, max_len=max_len,
                    chunk_size=chunk_size, prefix_cache=prefix_cache,
                    spec_k=spec_k)
    reqs = [Request(rid=i, prompt=np.asarray(p, np.int32),
                    max_new_tokens=max_new,
                    frames=frames[i] if frames else None)
            for i, p in enumerate(prompts)]
    per_wave = -(-len(reqs) // waves)
    for w in range(waves):             # waves let the cache warm between
        for r in reqs[w * per_wave:(w + 1) * per_wave]:
            engine.submit(r)
        engine.run()
    return [r.output for r in reqs], engine


ARCHS = ["qwen-7b", "xlstm-1.3b", "zamba2-7b", "whisper-small"]


@pytest.mark.parametrize("arch", ARCHS + ["qwen-7b-int8"])
def test_prefix_cache_on_matches_oracle_all_families(arch):
    """``prefix_cache=True`` engines match ``reference_decode`` token for
    token in every family: transformer families actually share (second
    wave hits the cache), recurrent/audio families gate sharing OFF via
    ``api.supports_prefix_cache`` and run unchanged."""
    kv_quant = "int8" if arch.endswith("-int8") else "none"
    name = arch.removesuffix("-int8")
    cfg = get_smoke_config(name, kv_quant=kv_quant, kv_layout="paged",
                           kv_block_size=8)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    system = rng.integers(0, cfg.vocab_size, 16).tolist()
    prompts = [system + rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(2, 8))).tolist()
               for _ in range(4)]
    frames = None
    if cfg.family == "audio":
        frames = [rng.normal(size=(cfg.encoder_frames, cfg.d_model)
                             ).astype(np.float32) for _ in prompts]
    outs, engine = _run_engine(cfg, params, prompts, prefix_cache=True,
                               max_len=40, frames=frames, waves=2)
    assert engine.prefix_sharing == api.supports_prefix_cache(cfg)
    if engine.prefix_sharing:
        assert engine.prefix_hits > 0, "second wave should hit the cache"
    for p, out, i in zip(prompts, outs, range(len(prompts))):
        ref = reference_decode(cfg, params, np.asarray(p, np.int32), 5,
                               max_len=40,
                               frames=frames[i] if frames else None,
                               compile_cache=_oracle_cc((name, kv_quant)))
        assert out == ref, f"prompt {i} diverged from the batch-1 oracle"
    if engine.paged:
        _assert_pool_whole(engine)


def test_prefix_on_off_bitwise_equal():
    """The tentpole invariant: sharing changes WHERE K/V lives, never what
    it holds — the cache-ON engine's streams are bitwise the cache-OFF
    engine's, while actually sharing (hits, shared blocks, CoW)."""
    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(9)
    system = rng.integers(0, cfg.vocab_size, 24).tolist()
    prompts = [system + rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(1, 12))).tolist()
               for _ in range(8)]
    off, _ = _run_engine(cfg, params, prompts, prefix_cache=False, waves=3)
    on, engine = _run_engine(cfg, params, prompts, prefix_cache=True, waves=3)
    assert on == off
    stats = engine.pool_stats()
    assert stats["prefix_hits"] > 0 and stats["prefix_hit_tokens"] > 0
    _assert_pool_whole(engine)


def test_cow_admission_paths():
    """All three radix-admission shapes against the oracle: block-aligned
    divergence (pure page-table copy), mid-block divergence (CoW copies
    the partial block), and an identical prompt (full coverage — the last
    matched block demotes to CoW so the final token has a writable page)."""
    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    author = rng.integers(0, cfg.vocab_size, 32).tolist()   # 4 full blocks
    prompts = [
        author,                                  # wave 1: authors the cache
        author[:24] + [7] * 6,                   # aligned divergence: no CoW
        author[:28] + [9, 9],                    # mid-block: CoW block 4
        author,                                  # identical: full-coverage CoW
    ]
    outs, engine = _run_engine(cfg, params, prompts, prefix_cache=True,
                               batch=1, waves=4)
    assert engine.prefix_hits == 3
    assert engine.cow_copies == 2
    assert engine.pool_stats()["cow_copies"] == 2
    assert ("cow", 0) in engine.cache_compiles.keys()
    assert engine.cache_compiles.misses <= engine.compile_budget
    for i, p in enumerate(prompts):
        ref = reference_decode(cfg, params, np.asarray(p, np.int32), 5,
                               max_len=96,
                               compile_cache=_oracle_cc(("cow", "none")))
        assert outs[i] == ref, f"prompt {i} diverged"
    _assert_pool_whole(engine)


def test_shared_blocks_survive_author_retirement():
    """Cache residency holds its own reference: the author's blocks stay
    live (and shareable) after the author retires, and a later admission
    in the same slot in recycled blocks maps them read-only."""
    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    system = rng.integers(0, cfg.vocab_size, 16).tolist()
    outs, engine = _run_engine(
        cfg, params,
        [system + [1, 2, 3], system + [4, 5], system + [6]],
        prefix_cache=True, batch=1, waves=3)
    stats = engine.pool_stats()
    assert engine.prefix_hits == 2
    assert stats["leased"] == stats["cached_blocks"] == 2  # 16 tokens / bs 8
    assert stats["prefix_hit_tokens"] == 2 * 16
    _assert_pool_whole(engine)


def test_lru_eviction_relieves_pool_pressure():
    """A big cold request that does not fit next to the resident cache
    evicts cold leaves (LRU-first) instead of stalling forever — and the
    evicted-prefix request still decodes exactly."""
    cfg = _tiny_cfg(kv_pool_blocks=7)            # 56-token pool
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    small = rng.integers(0, cfg.vocab_size, 16).tolist()     # caches 2 blocks
    big = rng.integers(0, cfg.vocab_size, 40).tolist()       # worst case 7
    outs, engine = _run_engine(cfg, params, [small, big], prefix_cache=True,
                               batch=1, max_len=48, max_new=16, waves=2)
    assert engine.prefix_evictions >= 1
    assert engine.admission_stalls == 0
    for i, p in enumerate([small, big]):
        ref = reference_decode(cfg, params, np.asarray(p, np.int32), 16,
                               max_len=48,
                               compile_cache=_oracle_cc(("evict", "none")))
        assert outs[i] == ref
    _assert_pool_whole(engine)


@pytest.mark.parametrize("kv_quant", ["none", "int8"])
def test_prefix_soak_with_speculation(kv_quant):
    """Randomized soak: shared-prefix traffic under pool pressure with
    speculative decoding layered on top (draft rewinds interleave with
    shared mappings).  Mid-flight pool invariants hold every burst, every
    stream matches the oracle, and the pool comes back whole."""
    cfg = _tiny_cfg(kv_quant=kv_quant, kv_pool_blocks=16)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    system = rng.integers(0, cfg.vocab_size, 16).tolist()
    engine = Engine(cfg, params, batch_size=4, max_len=48, chunk_size=8,
                    prefix_cache=True, spec_k=2)
    reqs = [Request(rid=i,
                    prompt=np.asarray(
                        system + rng.integers(0, cfg.vocab_size,
                                              int(rng.integers(1, 10))
                                              ).tolist(), np.int32),
                    max_new_tokens=int(rng.integers(2, 7)))
            for i in range(10)]
    for r in reqs:
        engine.submit(r)
    while True:
        engine.run(max_steps=3)
        stats = engine.pool_stats()
        assert stats["free"] + stats["leased"] == stats["total"]
        assert stats["reserved_outstanding"] <= stats["free"], \
            "reservation invariant violated: an admitted row could stall"
        engine.alloc.check()
        if sum(r.done for r in reqs) == len(reqs):
            break
        assert engine.steps < 2000, "engine stopped making progress"
    assert engine.prefix_hits > 0
    for r in reqs:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=48,
                               compile_cache=_oracle_cc(("soak", kv_quant)))
        assert r.output == ref, f"req {r.rid} diverged from the oracle"
    _assert_pool_whole(engine)


def test_bulk_prefill_matches_token_loop():
    """Satellite: standalone ``api.prefill`` now runs the whole prompt
    through the bulk chunk writer, returning the TRUE post-prompt state —
    its logits must match teacher-forcing the prompt token by token, for
    the recurrent families especially (the old surface returned a fresh
    state) and for paged transformers (which have no full-seq prefill)."""
    import jax.numpy as jnp
    for name, over in [("qwen-7b", {"kv_layout": "paged",
                                    "kv_block_size": 8}),
                       ("xlstm-1.3b", {}), ("zamba2-7b", {})]:
        cfg = get_smoke_config(name, **over)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        tokens = rng.integers(0, cfg.vocab_size, (2, 11)).astype(np.int32)
        logits, cache = api.prefill(cfg, params,
                                    {"tokens": jnp.asarray(tokens)}, 32)
        for b in range(2):
            dec_cache = api.init_cache(cfg, 1, 32)
            for t_i, t in enumerate(tokens[b].tolist()):
                ref_logits, dec_cache = api.decode_step(
                    cfg, params, dec_cache,
                    jnp.asarray([[t]], jnp.int32),
                    jnp.asarray([t_i + 1], jnp.int32))
            np.testing.assert_allclose(
                np.asarray(logits[b]), np.asarray(ref_logits[0]),
                rtol=2e-5, atol=2e-5,
                err_msg=f"{name} bulk prefill != token loop (row {b})")


# ---------------------------------------------------------------------------
# hypothesis harness (CI: hypothesis ships in requirements-dev)
# ---------------------------------------------------------------------------

try:        # guarded, NOT importorskip: the deterministic cases above must
    from hypothesis import given, settings, strategies as st  # noqa: E402
    _HAVE_HYPOTHESIS = True       # run even without hypothesis installed
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**16),
           n_blocks=st.integers(4, 24),
           block_size=st.sampled_from([1, 2, 4, 8]))
    def test_host_fuzz_property(seed, n_blocks, block_size):
        _check_host_property(seed, n_blocks=n_blocks, block_size=block_size)
else:
    @pytest.mark.skip(reason="property fuzz needs hypothesis "
                             "(pip install -r requirements-dev.txt)")
    def test_host_fuzz_property():
        pass
