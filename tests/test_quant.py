"""Unit + property tests for block INT4 quantization (core/quant.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quant


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


class TestPackUnpack:
    def test_roundtrip_exact(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.integers(-8, 8, (256, 128)).astype(np.int8))
        packed = quant.pack_int4(q)
        assert packed.shape == (128, 128)
        assert packed.dtype == jnp.uint8
        out = quant.unpack_int4(packed)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(q))

    def test_pack_pairs_rows_within_group(self):
        # row r and r+64 of each 128-group share a byte
        q = jnp.zeros((128, 8), jnp.int8).at[3, :].set(5).at[67, :].set(-2)
        packed = quant.pack_int4(q)
        b = np.asarray(packed)[3]
        assert np.all(b == (5 | ((-2 & 0xF) << 4)))

    @given(
        in_f=st.sampled_from([128, 256, 512]),
        out_f=st.sampled_from([8, 128, 256]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_property(self, in_f, out_f, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.integers(-8, 8, (in_f, out_f)).astype(np.int8))
        out = quant.unpack_int4(quant.pack_int4(q))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(q))


class TestQuantize:
    def test_shapes(self):
        w = _rand((512, 256))
        qt = quant.quantize(w)
        assert qt.packed.shape == (256, 256)
        assert qt.scales.shape == (4, 256)
        assert qt.shape == (512, 256)

    def test_roundtrip_error_small(self):
        w = _rand((512, 256), scale=0.02)
        qt = quant.quantize(w, scale_dtype=jnp.float32)
        err = quant.quantization_error(w, qt)
        # int4 symmetric: max error = scale/2 = absmax/14 per group
        assert err["rms"] < 0.02 / 7
        wq = quant.dequantize(qt, jnp.float32)
        assert float(jnp.max(jnp.abs(w - wq))) <= float(jnp.max(jnp.abs(w))) / 7.0 + 1e-6

    def test_exact_on_grid(self):
        # weights already on the int4 grid quantize exactly
        rng = np.random.default_rng(1)
        scale = 0.5
        q = rng.integers(-7, 8, (256, 128)).astype(np.float32)
        q[0, :] = 7  # pin absmax so scale is exact per group
        q[128, :] = 7
        w = jnp.asarray(q * scale)
        qt = quant.quantize(w, scale_dtype=jnp.float32)
        wq = quant.dequantize(qt, jnp.float32)
        np.testing.assert_allclose(np.asarray(wq), np.asarray(w), atol=1e-5)

    def test_group_scales_independent(self):
        # one huge group must not wreck the other group's precision
        w = np.full((256, 8), 0.01, np.float32)
        w[128:, :] = 100.0
        qt = quant.quantize(jnp.asarray(w), scale_dtype=jnp.float32)
        wq = np.asarray(quant.dequantize(qt, jnp.float32))
        np.testing.assert_allclose(wq[:128], w[:128], rtol=0.1)

    def test_rejects_bad_shape(self):
        with pytest.raises(ValueError):
            quant.quantize(_rand((100, 8)))

    @given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 10.0))
    @settings(max_examples=15, deadline=None)
    def test_error_bound_property(self, seed, scale):
        """|w - dq(q(w))| <= group_absmax / 14 + eps, for any input scale."""
        w = _rand((256, 64), seed=seed, scale=scale)
        qt = quant.quantize(w, scale_dtype=jnp.float32)
        wq = quant.dequantize(qt, jnp.float32)
        g = np.abs(np.asarray(w)).reshape(2, 128, 64).max(axis=1)  # (2, 64)
        bound = np.repeat(g / 14.0, 128, axis=0) + 1e-6
        assert np.all(np.abs(np.asarray(w - wq)) <= bound * 1.01)

    def test_pytree_jit(self):
        w = _rand((256, 128))
        qt = quant.quantize(w)

        @jax.jit
        def f(q):
            return quant.dequantize(q, jnp.float32).sum()

        f(qt)  # must trace with QuantizedTensor as pytree
