"""Serving engine tests: chunked-prefill continuous batching over the slot
cache, bounded compile cache, slot insert/evict API, generation metrics.
(Chunked-admission specifics — family parity, chunk invariance, recurrent
prefill — live in test_mixed.py.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache, quantize_model
from repro.models import api
from repro.serving.engine import Engine, Request, reference_decode

# shared across reference_decode calls so the oracle compiles once
_REF_CC = CompileCache()


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen-7b", d_model=128, d_ff=256, vocab_size=512)
    params = quantize_model(
        api.init_params(cfg, jax.random.PRNGKey(0)), "dense")
    return cfg, params


@pytest.fixture(scope="module")
def engine(setup):
    cfg, params = setup
    return Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16)


def test_completes_all_requests(engine):
    rng = np.random.default_rng(0)
    for rid in range(5):
        engine.submit(Request(rid=rid,
                              prompt=rng.integers(0, 512, 6).astype(np.int32),
                              max_new_tokens=4))
    done = engine.run()
    assert len(done) == 5
    assert all(len(r.output) >= 4 for r in done)
    assert all(r.finished_at is not None for r in done)


def test_compile_cache_bounded(engine):
    """Serving executables stay bounded by n_chunk_buckets + 2 no matter
    the traffic — and a warmed engine re-traces nothing."""
    rng = np.random.default_rng(1)
    warm = engine.cache_compiles.misses
    for rid in (10, 11, 12):
        engine.submit(Request(rid=rid,
                              prompt=rng.integers(
                                  0, 512, int(rng.integers(3, 40))
                              ).astype(np.int32),
                              max_new_tokens=2))
    engine.run()
    assert engine.cache_compiles.misses <= engine.compile_budget
    assert engine.compile_budget == \
        len(engine.chunk_buckets.all_buckets()) + 2
    # every key family is shape-bucketed: more traffic, zero new traces
    for rid in (13, 14):
        engine.submit(Request(rid=rid,
                              prompt=rng.integers(0, 512, 9).astype(np.int32),
                              max_new_tokens=2))
    engine.run()
    assert engine.cache_compiles.misses <= max(warm, engine.compile_budget)


def test_continuous_batching_mixed_lengths(setup, engine):
    """Unequal max_new_tokens arriving mid-flight: slots are refilled, one
    dispatch per tick, outputs equal per-request batch-1 greedy."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    reqs = [Request(rid=100 + i,
                    prompt=rng.integers(0, 512,
                                        int(rng.integers(3, 20))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 8)))
            for i in range(8)]
    # 5 up front; 3 "arrive" while decode is in flight, via the sampler hook
    for r in reqs[:5]:
        engine.submit(r)
    late = list(reqs[5:])

    def sample(row):
        if late:
            engine.submit(late.pop())
        return int(np.argmax(row))

    steps0, calls0 = engine.steps, engine.dispatches
    done = engine.run(sample=sample)
    assert len(done) == 8 and all(r.done for r in done)

    # one jitted dispatch per tick, regardless of live-request count
    assert engine.dispatches - calls0 == engine.steps - steps0
    # slots were refilled mid-flight: 8 requests through 2 slots, and the
    # batched schedule beats the serial token count
    total_decode_tokens = sum(len(r.output) - 1 for r in done)
    assert engine.steps - steps0 < total_decode_tokens + \
        sum(-(-len(r.prompt) // engine.chunk_size) for r in done)
    assert engine.slot_occupancy > 0.5

    # compile cache stays bounded whatever the traffic
    assert engine.cache_compiles.misses <= engine.compile_budget

    # numerics oracle: per-request batch-1 greedy decode
    for r in done:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=64, compile_cache=_REF_CC)
        assert r.output == ref, f"req {r.rid} diverged from batch-1 decode"


@pytest.mark.parametrize("arch", ["qwen-7b", "xlstm-1.3b", "zamba2-7b",
                                  "whisper-small"])
def test_slot_insert_evict_roundtrip(arch):
    """insert_request scatters one row; evict_slot restores the pristine
    init state (recurrent families reset m to -1e30, not 0)."""
    cfg = get_smoke_config(arch)
    cache = api.init_cache(cfg, 3, 32)
    row = jax.tree.map(jnp.ones_like, api.init_cache(cfg, 1, 32))
    axes = api.cache_slot_axes(cfg)

    inserted = jax.jit(
        lambda c, r, s: api.insert_request(cfg, c, r, s))(cache, row,
                                                          jnp.int32(1))

    def check_insert(dst, orig, ax):
        got = jnp.take(dst, 1, axis=ax)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.ones_like(np.asarray(got)))
        # neighbors untouched
        np.testing.assert_array_equal(np.asarray(jnp.take(dst, 0, axis=ax)),
                                      np.asarray(jnp.take(orig, 0, axis=ax)))
    jax.tree.map(check_insert, inserted, cache, axes)

    evicted = api.evict_slot(cfg, inserted, jnp.int32(1), 32)

    def check_evict(dst, orig, ax):
        np.testing.assert_array_equal(np.asarray(jnp.take(dst, 1, axis=ax)),
                                      np.asarray(jnp.take(orig, 1, axis=ax)))
    jax.tree.map(check_evict, evicted, cache, axes)


def test_prompt_bucket_at_max_len(setup, engine):
    """A prompt whose power-of-two bucket rounds up to max_len used to be
    dropped at admission; with true-length accounting it decodes in full
    and matches the oracle (see also test_mixed.py admission tests)."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, 512, 40).astype(np.int32)  # bucket(40) = 64
    req = Request(rid=30, prompt=prompt, max_new_tokens=5)
    engine.submit(req)
    done = engine.run()
    got = [r for r in done if r.rid == 30][0]
    assert len(got.output) == 5
    assert got.output == reference_decode(cfg, params, prompt, 5, max_len=64,
                                          compile_cache=_REF_CC)


def test_run_max_steps_is_per_call(engine):
    """max_steps bounds one run() call; a later run() on the same engine
    resumes the in-flight slots (the counter is not cumulative)."""
    rng = np.random.default_rng(5)
    engine.submit(Request(rid=50, prompt=rng.integers(0, 512, 5).astype(np.int32),
                          max_new_tokens=6))
    first = engine.run(max_steps=2)
    assert first == []                    # still in flight after 2 steps
    done = engine.run()                   # resumes and drains
    assert [r.rid for r in done] == [50] and len(done[0].output) == 6


def test_oversized_prompt_rejected_at_submit(engine):
    with pytest.raises(ValueError, match="exceeds engine max_len"):
        engine.submit(Request(rid=40, prompt=np.zeros(65, np.int32)))


def test_metrics_summary(engine):
    rng = np.random.default_rng(3)
    engine.submit(Request(rid=20, prompt=rng.integers(0, 512, 4).astype(np.int32),
                          max_new_tokens=3))
    done = engine.run()
    s = Engine.summarize(done)
    assert s["n"] >= 1 and s["mean_tokens_per_s"] > 0
    assert s["ttft_p99_s"] >= 0 and s["itl_p99_s"] >= 0


def test_summarize_excludes_queue_wait():
    """tokens/s is decode throughput (from first_token_at), so a long queue
    wait must not drag it down."""
    r = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=3)
    r.output = [1, 2, 3]
    r.token_times = [10.0, 10.5, 11.0]
    r.submitted_at = 0.0
    r.first_token_at = 10.0    # waited 10s in the queue
    r.finished_at = 11.0       # then decoded 2 tokens in 1s
    s = Engine.summarize([r])
    assert s["mean_tokens_per_s"] == pytest.approx(2.0)
    assert s["mean_ttft_s"] == pytest.approx(10.0)
    assert s["itl_p50_s"] == pytest.approx(0.5)
