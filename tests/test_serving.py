"""Serving engine tests: request scheduling, bucketed prefill compile
cache, generation metrics."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.compiler import quantize_model
from repro.models import api
from repro.serving.engine import Engine, Request


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("qwen-7b", d_model=128, d_ff=256, vocab_size=512)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, quantize_model(params, "dense"),
                  batch_size=2, max_len=64)


def test_completes_all_requests(engine):
    rng = np.random.default_rng(0)
    for rid in range(5):
        engine.submit(Request(rid=rid,
                              prompt=rng.integers(0, 512, 6).astype(np.int32),
                              max_new_tokens=4))
    done = engine.run()
    assert len(done) == 5
    assert all(len(r.output) >= 4 for r in done)
    assert all(r.finished_at is not None for r in done)


def test_compile_cache_buckets_reused(engine):
    rng = np.random.default_rng(1)
    # same-bucket prompts: prefill compiles once
    before = engine.cache_compiles.misses
    for rid in (10, 11):
        engine.submit(Request(rid=rid,
                              prompt=rng.integers(0, 512, 10).astype(np.int32),
                              max_new_tokens=2))
    engine.run()
    assert engine.cache_compiles.misses - before <= 1


def test_metrics_summary(engine):
    rng = np.random.default_rng(2)
    engine.submit(Request(rid=20, prompt=rng.integers(0, 512, 4).astype(np.int32),
                          max_new_tokens=3))
    done = engine.run()
    s = Engine.summarize(done)
    assert s["n"] >= 1 and s["mean_tokens_per_s"] > 0
