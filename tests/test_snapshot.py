"""Crash-safe serving tests (ISSUE 9): atomic snapshot dirs, the
write-ahead journal, Engine.restore's replay-and-fold recovery, torn
snapshot/journal tolerance, remaining-budget deadlines across restarts,
and a kill/restore soak cell (the full matrix runs as the CI restart-soak
step)."""

import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.atomic import atomic_dir
from repro.core.compiler import CompileCache
from repro.models import api
from repro.serving import snapshot as snaplib
from repro.serving.chaos import run_restart_cell
from repro.serving.engine import Engine, Request, reference_decode

_REF_CC = CompileCache()


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                           kv_layout="paged", kv_block_size=8,
                           kv_pool_blocks=24)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class FakeClock:
    """Injectable engine clock: time moves only when the test says so."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def _reqs(cfg, rng, n, max_new=6):
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 17))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _oracle(cfg, params, reqs):
    return {r.rid: reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                                    max_len=64, compile_cache=_REF_CC)
            for r in reqs}


def _free_expected(eng):
    """Blocks that must be free after a drain: everything except what the
    prefix cache legitimately holds."""
    held = len(eng.prefix.blocks()) if eng.prefix is not None else 0
    return eng.pool_blocks - held


# -- atomic directory helper ------------------------------------------------

def test_atomic_dir_commit_and_replace(tmp_path):
    final = str(tmp_path / "out")
    with atomic_dir(final) as tmp:
        with open(os.path.join(tmp, "a.txt"), "w") as f:
            f.write("one")
    assert open(os.path.join(final, "a.txt")).read() == "one"
    assert not os.path.exists(final + ".tmp")
    # a second commit REPLACES the first atomically
    with atomic_dir(final) as tmp:
        with open(os.path.join(tmp, "b.txt"), "w") as f:
            f.write("two")
    assert os.listdir(final) == ["b.txt"]


def test_atomic_dir_abort_leaves_previous(tmp_path):
    final = str(tmp_path / "out")
    with atomic_dir(final) as tmp:
        with open(os.path.join(tmp, "a.txt"), "w") as f:
            f.write("good")
    with pytest.raises(RuntimeError):
        with atomic_dir(final) as tmp:
            with open(os.path.join(tmp, "a.txt"), "w") as f:
                f.write("torn")
            raise RuntimeError("die mid-write")
    assert open(os.path.join(final, "a.txt")).read() == "good"
    assert not os.path.exists(final + ".tmp")


# -- torn stores are never observed -----------------------------------------

def test_snapshots_ignore_torn_dirs(setup, tmp_path):
    cfg, params = setup
    wd = str(tmp_path / "snaps")
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 snapshot_dir=wd)
    good_epoch, good_path = snaplib.latest_snapshot(wd)
    # a .tmp turd and a higher-epoch dir missing its device manifest must
    # both be invisible to restore
    os.makedirs(os.path.join(wd, "snap_000099.tmp"))
    torn = os.path.join(wd, "snap_000007")
    os.makedirs(torn)
    with open(os.path.join(torn, "host.json"), "w") as f:
        f.write("{}")
    assert snaplib.latest_snapshot(wd) == (good_epoch, good_path)
    restored = Engine.restore(wd, params,
                              compile_cache=eng.cache_compiles)
    assert restored.run().drained          # empty engine, clean drain
    assert snaplib.latest_snapshot(wd) == (good_epoch, good_path)


def test_journal_torn_tail_ignored(tmp_path):
    path = str(tmp_path / "journal_000000.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"ev": "submit", "rid": 0}) + "\n")
        f.write(json.dumps({"ev": "emit", "rid": 0, "tok": 7}) + "\n")
        f.write('{"ev": "emit", "rid": 0, "to')      # kill mid-write
    events = snaplib.read_journal(path)
    assert [e["ev"] for e in events] == ["submit", "emit"]


# -- mid-flight snapshot + restore ------------------------------------------

def test_midflight_restore_drains_bitwise(setup, tmp_path):
    """Kill the engine mid-flight after a snapshot: the restored engine
    drains every request with the exact tokens the never-killed engine
    would have emitted, audits green, and leaks nothing."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = _reqs(cfg, rng, 6)
    oracle = _oracle(cfg, params, reqs)
    wd = str(tmp_path / "snaps")
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 audit_every=1, snapshot_dir=wd)
    for r in reqs:
        eng.submit(r)
    mid = eng.run(max_steps=5)
    assert not mid.drained                  # work genuinely in flight
    eng.snapshot()
    # ...three more ticks AFTER the snapshot land in the journal only, so
    # restore must replay + fold them
    eng.run(max_steps=3)

    restored = Engine.restore(wd, params,
                              compile_cache=eng.cache_compiles)
    res = restored.run()
    assert res.drained
    restored.audit()
    streams, status = snaplib.journaled_streams(wd)
    for r in reqs:
        assert status[r.rid] == "done"
        assert streams[r.rid] == oracle[r.rid], f"rid {r.rid} diverged"
    assert restored.alloc.n_free == _free_expected(restored)


def test_restore_replays_journal_tail(setup, tmp_path):
    """With only the baseline snapshot on disk, the ENTIRE run lives in
    the journal: restore replays it and reports every request as already
    terminal."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    reqs = _reqs(cfg, rng, 4)
    oracle = _oracle(cfg, params, reqs)
    wd = str(tmp_path / "snaps")
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 snapshot_dir=wd, snapshot_every=0)
    for r in reqs:
        eng.submit(r)
    assert eng.run().drained

    restored = Engine.restore(wd, params,
                              compile_cache=eng.cache_compiles)
    assert len(restored.restored_terminal) == 4
    assert {r.rid for r in restored.restored_terminal} == {0, 1, 2, 3}
    assert all(r.status == "done" for r in restored.restored_terminal)
    for r in restored.restored_terminal:
        assert r.output == oracle[r.rid]
    assert restored.run().drained           # nothing left to do
    assert restored.alloc.n_free == _free_expected(restored)


def test_counters_and_cfg_roundtrip(setup, tmp_path):
    cfg, params = setup
    assert snaplib.cfg_from_dict(snaplib.cfg_to_dict(cfg)) == cfg
    rng = np.random.default_rng(3)
    wd = str(tmp_path / "snaps")
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 snapshot_dir=wd)
    for r in _reqs(cfg, rng, 3):
        eng.submit(r)
    eng.run()
    eng.snapshot()
    restored = Engine.restore(wd, params,
                              compile_cache=eng.cache_compiles)
    for k in snaplib._COUNTERS:
        if k == "snapshots_taken":
            continue                        # restore does not snapshot
        if k == "audits":
            continue                        # restore runs one audit itself
        assert getattr(restored, k) == getattr(eng, k), k
    assert restored.audits == eng.audits + 1
    assert restored.steps == eng.steps


# -- prefix cache survives the crash ----------------------------------------

def test_restored_prefix_cache_drop_returns_all_blocks(setup, tmp_path):
    cfg, params = setup
    rng = np.random.default_rng(4)
    system = rng.integers(0, cfg.vocab_size, 16)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [system, rng.integers(0, cfg.vocab_size, 4)]
                    ).astype(np.int32),
                    max_new_tokens=4)
            for i in range(4)]
    oracle = _oracle(cfg, params, reqs)
    wd = str(tmp_path / "snaps")
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 prefix_cache=True, audit_every=1, snapshot_dir=wd)
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=6)
    eng.snapshot()

    restored = Engine.restore(wd, params,
                              compile_cache=eng.cache_compiles)
    assert restored.run().drained
    restored.audit()
    streams, _ = snaplib.journaled_streams(wd)
    assert all(streams[r.rid] == oracle[r.rid] for r in reqs)
    # the radix cache holds exactly one reference per cached block: flushing
    # it must return the pool to fully free
    assert restored.prefix.blocks()         # something was actually cached
    dropped = restored.drop_prefix_cache()
    assert dropped > 0
    assert restored.alloc.n_free == restored.pool_blocks
    restored.audit()


# -- deadlines restore as remaining budget ----------------------------------

def test_deadline_restored_as_remaining_budget(setup, tmp_path):
    """50 s deadline, 20 s consumed pre-kill, arbitrary downtime: the
    restored request has exactly 30 s left, and downtime never counts."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    wd = str(tmp_path / "snaps")
    clock = FakeClock(100.0)
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 snapshot_dir=wd, clock=clock)
    eng.submit(Request(rid=0,
                       prompt=rng.integers(0, 256, 8).astype(np.int32),
                       max_new_tokens=4, deadline_s=50.0))
    clock.t = 120.0                          # 20 s burned while queued
    eng.snapshot()

    clock2 = FakeClock(5000.0)               # the process was dead a while
    restored = Engine.restore(wd, params, clock=clock2,
                              compile_cache=eng.cache_compiles)
    (req,) = restored._queue
    remaining = req.deadline_s - (clock2() - req.submitted_at)
    assert remaining == pytest.approx(30.0)
    # past the remaining budget the miss fires on the next tick
    clock2.t = 5000.0 + 30.0 + 1e-3
    res = restored.run()
    assert res.drained
    assert req.status == "deadline_missed"


def test_fresh_deadline_not_aged_by_fake_clock(setup, tmp_path):
    """Control: the same deadline with NO consumed budget survives a
    snapshot/restore with its full allowance."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    wd = str(tmp_path / "snaps")
    clock = FakeClock(7.0)
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 snapshot_dir=wd, clock=clock)
    eng.submit(Request(rid=0,
                       prompt=rng.integers(0, 256, 8).astype(np.int32),
                       max_new_tokens=4, deadline_s=50.0))
    eng.snapshot()                           # zero time consumed
    clock2 = FakeClock(0.0)
    restored = Engine.restore(wd, params, clock=clock2,
                              compile_cache=eng.cache_compiles)
    (req,) = restored._queue
    assert (req.deadline_s -
            (clock2() - req.submitted_at)) == pytest.approx(50.0)
    res = restored.run()                     # clock frozen: plenty of budget
    assert res.drained and req.status == "done"


# -- accounting across the boundary -----------------------------------------

def test_summarize_consistent_across_boundary(setup, tmp_path):
    """restored_terminal + the post-restore RunResult together cover every
    request exactly once, and summarize() over the union is coherent."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    reqs = _reqs(cfg, rng, 6)
    wd = str(tmp_path / "snaps")
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 snapshot_dir=wd, snapshot_every=4)
    for r in reqs:
        eng.submit(r)
    pre = eng.run(max_steps=9)               # some finished, some not
    assert pre and not pre.drained

    restored = Engine.restore(wd, params,
                              compile_cache=eng.cache_compiles)
    res = restored.run()
    assert res.drained
    # partition: pre-kill terminals and post-restore terminals are disjoint
    # and together cover every request; terminals that landed after the
    # LAST snapshot also replay into restored_terminal (a subset of pre)
    pre_rids = {r.rid for r in pre}
    post_rids = {r.rid for r in res}
    replay_rids = {r.rid for r in restored.restored_terminal}
    assert not pre_rids & post_rids
    assert sorted(pre_rids | post_rids) == [0, 1, 2, 3, 4, 5]
    assert replay_rids <= pre_rids
    union = ([r for r in pre if r.rid not in replay_rids] +
             list(restored.restored_terminal) + list(res))
    assert sorted(r.rid for r in union) == [0, 1, 2, 3, 4, 5]
    summary = Engine.summarize(union)
    assert summary["n"] == 6
    assert summary["completed"] == 6
    streams, _ = snaplib.journaled_streams(wd)
    assert summary["total_tokens"] == float(
        sum(len(streams[r.rid]) for r in reqs))
    assert summary["mean_ttft_s"] >= 0.0
    assert all(r.finished_at is not None for r in union)


# -- warm re-jit --------------------------------------------------------------

def test_restore_warms_saved_compile_keys(setup, tmp_path):
    cfg, params = setup
    rng = np.random.default_rng(8)
    reqs = _reqs(cfg, rng, 4)
    wd = str(tmp_path / "snaps")
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 snapshot_dir=wd)
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=4)
    eng.snapshot()
    saved = {tuple(k) for k in
             json.load(open(os.path.join(
                 snaplib.latest_snapshot(wd)[1], "host.json")))
             ["compile_keys"]}
    assert ("mixed", 32) in saved or any(n == "mixed" for n, _ in saved)

    cc = eng.cache_compiles
    before = cc.misses
    restored = Engine.restore(wd, params, compile_cache=cc)
    # every saved executable was re-bound through the SHARED cache: zero
    # recompiles, and the keys are live before the first real tick
    assert cc.misses == before
    assert saved <= set(restored.cache_compiles.keys())
    assert restored.run().drained


# -- store hygiene -----------------------------------------------------------

def test_prune_keeps_journals(setup, tmp_path):
    cfg, params = setup
    rng = np.random.default_rng(9)
    reqs = _reqs(cfg, rng, 3)
    oracle = _oracle(cfg, params, reqs)
    wd = str(tmp_path / "snaps")
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 snapshot_dir=wd, snapshot_keep=2)
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=3)
    for _ in range(4):
        eng.snapshot()
    eng.run()
    assert len(snaplib.snapshots(wd)) == 2   # pruned to keep
    journals = [d for d in os.listdir(wd) if d.startswith("journal_")]
    assert len(journals) == 5                # baseline + 4: never pruned
    streams, status = snaplib.journaled_streams(wd)
    for r in reqs:                           # concatenation is still whole
        assert status[r.rid] == "done"
        assert streams[r.rid] == oracle[r.rid]


# -- drafter state ------------------------------------------------------------

def test_drafter_history_survives_restore(setup, tmp_path):
    cfg, params = setup
    rng = np.random.default_rng(10)
    pat = rng.integers(0, cfg.vocab_size, 4)
    reqs = [Request(rid=i, prompt=np.tile(pat, 3).astype(np.int32),
                    max_new_tokens=24) for i in range(3)]
    oracle = _oracle(cfg, params, reqs)
    wd = str(tmp_path / "snaps")
    eng = Engine(cfg, params, batch_size=2, max_len=64, chunk_size=16,
                 spec_k=3, snapshot_dir=wd)
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=3)
    eng.snapshot()
    assert eng.drafter.dump()["history"]     # mid-flight rows have history

    restored = Engine.restore(wd, params,
                              compile_cache=eng.cache_compiles)
    assert restored.drafter.dump() == eng.drafter.dump()
    assert restored.run().drained
    streams, _ = snaplib.journaled_streams(wd)
    assert all(streams[r.rid] == oracle[r.rid] for r in reqs)


# -- kill/restore soak cell (full matrix = CI restart-soak step) --------------

def test_restart_soak_cell_smoke():
    stats = run_restart_cell("slot", "slot", "none", 0, False,
                             seed=1, n_requests=6)
    assert stats["kills"] >= 1
    assert sum(stats["outcomes"].values()) == 6
