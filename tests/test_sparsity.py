"""Tests for log-scale structured sparsity (core/sparsity.py)."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import sparsity
from repro.core.quant import quantize, dequantize


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 1, shape).astype(np.float32))


class TestPackingCostFig5:
    """Fig. 5 table, reproduced bit for bit."""

    def test_dense(self):
        c = sparsity.packing_cost(1.0)
        assert (c.scale_bits, c.mask_bits, c.wt_bits) == (256, 0, 8192)
        assert c.total_bits == 8448
        assert c.effective_bitwidth() == pytest.approx(4.125)

    def test_50pct_one_hot(self):
        c = sparsity.packing_cost(0.5, "one-hot")
        assert (c.scale_bits, c.mask_bits, c.wt_bits) == (256, 2048, 4096)
        assert c.total_bits == 6400
        assert c.effective_bitwidth() == pytest.approx(3.125)

    def test_50pct_addr_in_block_is_worse(self):
        c = sparsity.packing_cost(0.5, "addr-in-block")
        assert c.mask_bits == 4096  # paper: "not efficient here"
        auto = sparsity.packing_cost(0.5, "auto")
        assert auto.encoding == "one-hot"

    def test_75pct_addr_in_block(self):
        c = sparsity.packing_cost(0.75 and 0.25)  # density 0.25 = 75% sparse
        c = sparsity.packing_cost(0.25, "addr-in-block")
        assert (c.scale_bits, c.mask_bits, c.wt_bits) == (256, 1536, 2048)
        assert c.total_bits == 3840
        assert c.effective_bitwidth() == pytest.approx(1.875)

    def test_875pct_both_encodings(self):
        one_hot = sparsity.packing_cost(0.125, "one-hot")
        assert one_hot.total_bits == 3328
        assert one_hot.effective_bitwidth() == pytest.approx(1.625)
        addr = sparsity.packing_cost(0.125, "addr-in-block")
        assert addr.mask_bits == 1024
        assert addr.total_bits == 2304
        assert addr.effective_bitwidth() == pytest.approx(1.125)
        assert sparsity.packing_cost(0.125, "auto").encoding == "addr-in-block"

    def test_enhancement_ratios(self):
        # paper: 1.32x, 2.2x, 2.54x (one-hot) and 3.67x at 87.5%
        assert sparsity.enhancement_ratio(0.5) == pytest.approx(8448 / 6400, rel=1e-6)
        assert sparsity.enhancement_ratio(0.25) == pytest.approx(2.2, abs=0.01)
        assert sparsity.packing_cost(1.0).total_bits / sparsity.packing_cost(
            0.125, "one-hot").total_bits == pytest.approx(2.54, abs=0.01)
        assert sparsity.enhancement_ratio(0.125) == pytest.approx(3.67, abs=0.01)


class TestNMMask:
    @given(
        density=st.sampled_from([0.5, 0.25, 0.125]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=15, deadline=None)
    def test_density_exact(self, density, seed):
        w = _rand((256, 64), seed)
        mask = sparsity.nm_magnitude_mask(w, density)
        m = np.asarray(mask).reshape(-1, 8, 64)
        counts = m.sum(axis=1)
        assert np.all(counts == int(density * 8))

    def test_keeps_largest(self):
        w = np.zeros((8, 1), np.float32)
        w[2, 0], w[5, 0] = 3.0, -9.0
        mask = np.asarray(sparsity.nm_magnitude_mask(jnp.asarray(w), 0.25))
        assert mask[5, 0] and mask[2, 0]
        assert mask.sum() == 2

    def test_masked_error_below_unstructured_bound(self):
        """Pruning 50% k-of-8 must retain at least 50% of L1 mass (it keeps
        the largest half of every group)."""
        w = _rand((512, 128), 3)
        sw = sparsity.apply_nm_sparsity(w, 0.5)
        assert float(jnp.abs(sw).sum()) >= 0.5 * float(jnp.abs(w).sum())


class TestBlockSparse:
    def test_shapes_and_indices(self):
        w = _rand((2048, 256), 7)
        st_ = sparsity.block_sparsify_quantize(w, 0.25)
        out_tiles, S = 2, 2 * 2  # 16 blocks -> 2 groups, k=2 each
        assert st_.packed.shape == (out_tiles, S, 64, 128)
        assert st_.scales.shape == (out_tiles, S, 128)
        assert st_.block_idx.shape == (out_tiles, S)
        idx = np.asarray(st_.block_idx)
        # ascending within each out tile, and within the right group range
        assert np.all(np.diff(idx, axis=1) > 0)
        assert np.all(idx[:, :2] < 8) and np.all(idx[:, 2:] >= 8)

    def test_dense_density_matches_plain_quant(self):
        w = _rand((1024, 128), 11)
        st_ = sparsity.block_sparsify_quantize(w, 1.0)
        wd = sparsity.sparse_dequantize(st_, jnp.float32)
        qt = quantize(w, scale_dtype=jnp.bfloat16)
        np.testing.assert_allclose(
            np.asarray(wd), np.asarray(dequantize(qt, jnp.float32)), atol=1e-6)

    @given(density=st.sampled_from([0.5, 0.25, 0.125]), seed=st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_sparse_dequant_supported_on_kept_blocks_only(self, density, seed):
        w = _rand((1024, 128), seed)
        st_ = sparsity.block_sparsify_quantize(w, density)
        wd = np.asarray(sparsity.sparse_dequantize(st_, jnp.float32))
        blocks = wd.reshape(8, 128, 128)
        nz = np.array([np.abs(b).sum() > 0 for b in blocks])
        assert nz.sum() == int(density * 8)
        # kept blocks match the plain dense quantization of those blocks
        idx = np.asarray(st_.block_idx)[0]
        qt = quantize(w, scale_dtype=jnp.bfloat16)
        wq = np.asarray(dequantize(qt, jnp.float32)).reshape(8, 128, 128)
        for i in idx:
            np.testing.assert_allclose(blocks[i], wq[i], atol=1e-6)

    def test_importance_selection(self):
        # make block 3 of group 0 overwhelmingly important
        w = np.full((1024, 128), 0.01, np.float32)
        w[3 * 128:4 * 128, :] = 5.0
        st_ = sparsity.block_sparsify_quantize(jnp.asarray(w), 0.125)
        assert int(np.asarray(st_.block_idx)[0, 0]) == 3

    def test_nbytes_tracks_density(self):
        w = _rand((2048, 256), 13)
        dense_b = sparsity.block_sparsify_quantize(w, 1.0).nbytes_model
        half_b = sparsity.block_sparsify_quantize(w, 0.5).nbytes_model
        assert half_b < 0.56 * dense_b
