"""Speculative-decoding tests: prompt-lookup drafter unit behavior, engine
speculation vs the batch-1 oracle for every supporting family (incl. int8-KV
and the paged layout), plain-decode fallback for recurrent families, the
``_rewind_slot`` rollback primitive's free-list invariants, and compile-key
boundedness (speculation adds NO new executable shapes).

The core property — after any schedule of partial accepts and rewinds the
engine's token stream is BITWISE equal to a never-speculated run and the
block pool comes back whole — runs here as deterministic parametrized cases;
the hypothesis harness widens the draw space in CI.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.compiler import CompileCache
from repro.models import api
from repro.serving.draft import PromptLookupDrafter, make_drafter
from repro.serving.engine import Engine, Request, reference_decode

# shared so the oracle / engines compile once per (family, layout, quant) key
_REF_CC = {}
_ENGINE_CC = {}


def _oracle_cc(key):
    return _REF_CC.setdefault(key, CompileCache())


def _engine_cc(key):
    # NB spec and non-spec engines bind DIFFERENT executables under the same
    # ("mixed", W) keys — the key must carry spec on/off (and layout/quant)
    return _ENGINE_CC.setdefault(key, CompileCache())


def _rep_reqs(cfg, n, rng, *, max_new=(4, 12), rid0=0):
    """Repetition-heavy requests: prompts are a short pattern tiled, so the
    prompt-lookup drafter fires from the first decode tick — and greedy
    decode of a deterministic model run long enough falls into cycles it
    then predicts from emitted history."""
    out = []
    for i in range(n):
        frames = None
        if cfg.family == "audio":
            frames = rng.normal(
                size=(cfg.encoder_frames, cfg.d_model)).astype(np.float32)
        pat = rng.integers(0, cfg.vocab_size, int(rng.integers(2, 6)))
        out.append(Request(
            rid=rid0 + i, prompt=np.tile(pat, 3).astype(np.int32),
            max_new_tokens=int(rng.integers(*max_new)), frames=frames))
    return out


def _assert_oracle_parity(cfg, params, done, max_len, key):
    for r in done:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=max_len, frames=r.frames,
                               compile_cache=_oracle_cc(key))
        assert r.output == ref, \
            f"req {r.rid} diverged from the batch-1 oracle under speculation"


def _assert_pool_intact(engine):
    stats = engine.pool_stats()
    assert stats["leased"] == 0 and stats["reserved_outstanding"] == 0
    free = engine._free_blocks
    assert len(free) == engine.pool_blocks, "free list leaked blocks"
    assert sorted(free) == list(range(engine.pool_blocks)), \
        "free list holds duplicate or foreign block ids"


def _assert_bounded_compiles(engine):
    assert engine.cache_compiles.misses <= engine.compile_budget
    names = {name for name, _ in engine.cache_compiles.keys()}
    assert names <= {"mixed", "decode", "insert", "admit"}, \
        f"speculation introduced new executable kinds: {names}"


# ---------------------------------------------------------------------------
# drafter unit behavior
# ---------------------------------------------------------------------------

class TestPromptLookupDrafter:
    def test_cycle_match(self):
        """``a b a b`` must match itself — the suffix's own occurrence is
        skipped in favor of the one before it."""
        d = PromptLookupDrafter(ngram_max=2)
        d.observe(0, [1, 2, 1, 2])
        assert d.draft(0, 2) == [1, 2]

    def test_prompt_continuation(self):
        d = PromptLookupDrafter(ngram_max=2)
        d.observe(0, [5, 6, 7, 8, 5, 6])
        # suffix (5, 6) last occurred ending at 2 -> copy what followed it
        assert d.draft(0, 3) == [7, 8, 5]

    def test_longest_ngram_wins(self):
        d = PromptLookupDrafter(ngram_max=2)
        d.observe(0, [2, 5, 1, 2, 8, 2, 9, 1, 2])
        # bigram (1, 2) ends at 4 -> [8, 2, 9]; the unigram (2) alone would
        # have matched its own later occurrence at 6 -> [9, 1, 2]
        assert d.draft(0, 3) == [8, 2, 9]

    def test_periodic_extension(self):
        """A match overlapping the current position defines a cycle; the
        draft continues it past the end of history instead of truncating —
        greedy loops (constant runs, short cycles) are the dominant
        accept source."""
        d = PromptLookupDrafter(ngram_max=3)
        d.observe(0, [4, 4, 4, 4])
        assert d.draft(0, 5) == [4, 4, 4, 4, 4]      # period 1
        d.observe(1, [7, 1, 5, 1, 5, 1, 5])
        assert d.draft(1, 5) == [1, 5, 1, 5, 1]      # period 2

    def test_no_match_returns_empty(self):
        d = PromptLookupDrafter()
        d.observe(0, [1, 2, 3, 4, 5])
        assert d.draft(0, 4) == []
        assert d.draft(0, 0) == []
        assert d.draft(7, 4) == []           # never-observed slot

    def test_slots_isolated_and_reset(self):
        d = PromptLookupDrafter(ngram_max=2)
        d.observe(0, [1, 2, 1, 2])
        d.observe(1, [9, 9, 9])
        assert d.draft(0, 2) == [1, 2]
        assert d.draft(1, 2) == [9, 9]       # period-1 extension
        d.reset(0)
        assert d.draft(0, 2) == [] and d.history_len(0) == 0
        assert d.draft(1, 2) == [9, 9]       # slot 1 untouched

    def test_incremental_observe_equals_bulk(self):
        bulk, inc = PromptLookupDrafter(), PromptLookupDrafter()
        rng = np.random.default_rng(0)
        toks = rng.integers(0, 7, 40).tolist()
        bulk.observe(0, toks)
        for t in toks:
            inc.observe(0, [t])
        assert bulk.draft(0, 5) == inc.draft(0, 5)

    def test_registry(self):
        assert isinstance(make_drafter("plookup"), PromptLookupDrafter)
        with pytest.raises(ValueError, match="unknown drafter"):
            make_drafter("oracle")
        with pytest.raises(ValueError, match="ngram_min"):
            PromptLookupDrafter(ngram_max=0)


# ---------------------------------------------------------------------------
# engine level: speculation is lossless for every supporting family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,overrides", [
    ("qwen-7b", {}),
    ("qwen-7b", {"kv_quant": "int8"}),
    ("qwen-7b", {"kv_layout": "paged", "kv_block_size": 8}),
    ("qwen-7b", {"kv_quant": "int8", "kv_layout": "paged",
                 "kv_block_size": 8}),
    ("whisper-small", {}),
], ids=["dense", "int8kv", "paged", "paged-int8", "audio"])
def test_spec_engine_matches_oracle(name, overrides):
    """Engine with speculation ON emits token-for-token what the sequential
    batch-1 oracle emits — drafts only change the dispatch count.  Compile
    misses stay within the plain engine's budget (no new shapes)."""
    cfg = get_smoke_config(name, **overrides)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    engine = Engine(cfg, params, batch_size=2, max_len=48, chunk_size=8,
                    spec_k=4)
    reqs = _rep_reqs(cfg, 5, rng)
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert len(done) == 5 and all(r.done for r in done)
    assert engine.dispatches == engine.steps     # still one per tick
    assert engine.spec_drafted > 0, "workload never produced a verify row"
    assert engine.spec_accepted <= engine.spec_drafted
    _assert_bounded_compiles(engine)
    key = (name, tuple(sorted(overrides.items())))
    _assert_oracle_parity(cfg, params, done, 48, key)
    if engine.paged:
        _assert_pool_intact(engine)


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "zamba2-7b"])
def test_recurrent_families_fall_back(arch):
    """ssm/hybrid rows carry irreversible O(1) recurrent state — no rewind,
    so speculation degrades to plain decode (and says so in the stats)
    instead of corrupting outputs."""
    cfg = get_smoke_config(arch)
    assert not api.supports_speculation(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    engine = Engine(cfg, params, batch_size=2, max_len=32, chunk_size=8,
                    spec_k=4)
    assert engine.spec_k == 0 and engine.drafter is None
    stats = engine.spec_stats()
    assert stats["spec_requested"] == 4 and not stats["spec_supported"]
    reqs = _rep_reqs(cfg, 3, rng)
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert engine.spec_ticks == 0
    _assert_oracle_parity(cfg, params, done, 32, arch)


def test_sample_hook_disables_drafting():
    """Acceptance is defined against greedy argmax, so a sampling hook must
    suppress verify rows for the tick — outputs follow the hook, not K."""
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    def second_best(logits):            # maps one logits row (V,) -> token
        return int(np.argsort(np.asarray(logits))[-2])

    outs = []
    for spec_k in (0, 4):
        engine = Engine(cfg, params, batch_size=2, max_len=32, chunk_size=8,
                        spec_k=spec_k)
        rng = np.random.default_rng(4)
        for r in _rep_reqs(cfg, 3, rng):
            engine.submit(r)
        done = engine.run(sample=second_best)
        assert engine.spec_ticks == 0 and engine.spec_drafted == 0
        outs.append({r.rid: r.output for r in done})
    assert outs[0] == outs[1]


class _GarbageDrafter:
    """Adversarial drafter: always proposes in-vocab but (almost surely)
    wrong continuations, so nearly every verify row degenerates to one real
    token plus a rewind — acceptance must keep outputs lossless anyway."""

    def __init__(self, vocab: int):
        self.vocab = vocab
        self._n = 0

    def reset(self, slot):
        pass

    def observe(self, slot, tokens):
        pass

    def draft(self, slot, k):
        self._n += 1
        return [(self._n * 7 + j * 3 + 1) % self.vocab for j in range(k)]


def test_garbage_drafts_cost_throughput_not_correctness():
    """Draft quality is a THROUGHPUT knob only: a pure-garbage drafter
    forces rewinds on nearly every verify tick and the paged pool still
    comes back whole with oracle-exact outputs."""
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                           kv_layout="paged", kv_block_size=8)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    engine = Engine(cfg, params, batch_size=3, max_len=48, chunk_size=8,
                    spec_k=4, drafter=_GarbageDrafter(cfg.vocab_size))
    reqs = _rep_reqs(cfg, 6, rng, max_new=(6, 12))
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert engine.spec_rewinds > 0, "garbage drafts must trigger rollback"
    _assert_pool_intact(engine)
    _assert_bounded_compiles(engine)
    _assert_oracle_parity(cfg, params, done, 48, "garbage")


# ---------------------------------------------------------------------------
# rewind primitive: allocator unit guarantees
# ---------------------------------------------------------------------------

def _paged_engine(**over):
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                           kv_layout="paged", kv_block_size=8, **over)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, batch_size=3, max_len=32, chunk_size=4)


def test_rewind_returns_whole_tail_blocks():
    engine = _paged_engine()
    engine._slots[0].req = Request(rid=0, prompt=np.arange(4, dtype=np.int32))
    engine._slot_reserve[0] = 3
    engine._reserve_home[0] = [3]   # single-home engine
    engine._lease_to(0, 17)                  # 3 blocks at block_size=8
    engine._slots[0].length = 17
    freed_order = list(engine._slot_blocks[0])

    engine._rewind_slot(0, 9)                # ceil(9/8) = 2 blocks survive
    assert engine._slots[0].length == 9
    assert engine._slot_blocks[0] == freed_order[:2]
    assert engine._page_table[0, 2] == engine._null_block
    assert freed_order[2] in engine._free_blocks
    # leasing consumed the 3-block reservation; the freed block goes BACK
    # into it (the slot may legitimately lease it again)
    assert engine._slot_reserve[0] == 1

    engine._rewind_slot(0, 9)                # same length: no-op
    assert engine._slot_blocks[0] == freed_order[:2]

    engine._rewind_slot(0, 8)                # exact block boundary: 1 block
    assert engine._slot_blocks[0] == freed_order[:1]
    assert engine._slot_reserve[0] == 2
    with pytest.raises(ValueError, match="exceeds max_len"):
        engine._rewind_slot(0, engine.max_len + 1)

    engine._free_slot(0)
    _assert_pool_intact(engine)


def test_rewind_double_free_detected():
    engine = _paged_engine()
    engine._slots[0].req = Request(rid=0, prompt=np.arange(4, dtype=np.int32))
    engine._slot_reserve[0] = 2
    engine._reserve_home[0] = [2]   # single-home engine
    engine._lease_to(0, 16)                  # 2 blocks
    engine._slot_blocks[0][-1] = engine._free_blocks[0]   # corrupt: alias
    with pytest.raises(RuntimeError, match="double free"):
        engine._rewind_slot(0, 1)


# ---------------------------------------------------------------------------
# the rollback property: spec run == never-speculated run, leak-free
# ---------------------------------------------------------------------------

def _check_spec_property(*, seed, spec_k, kv_quant, ngram_max, paged=True):
    """For a random repetition-heavy workload: the speculating engine's
    token streams are BITWISE equal to a never-speculated engine's, the
    pool free list comes back whole (no leak, no double free), and compile
    misses stay within the plain budget."""
    over = ({"kv_layout": "paged", "kv_block_size": 8} if paged else {})
    cfg = get_smoke_config("qwen-7b", d_model=64, d_ff=128, vocab_size=256,
                           kv_quant=kv_quant, **over)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    def run(k):
        engine = Engine(
            cfg, params, batch_size=3, max_len=48, chunk_size=8, spec_k=k,
            drafter=PromptLookupDrafter(ngram_max=ngram_max),
            compile_cache=_engine_cc((kv_quant, paged, bool(k))))
        rng = np.random.default_rng(seed)
        reqs = _rep_reqs(cfg, 7, rng, max_new=(4, 12))
        for r in reqs:
            engine.submit(r)
        done = engine.run()
        assert len(done) == len(reqs)
        return engine, {r.rid: r.output for r in done}

    spec_engine, spec_out = run(spec_k)
    plain_engine, plain_out = run(0)
    assert spec_out == plain_out, \
        "speculation changed the token stream (must be lossless)"
    assert spec_engine.spec_drafted > 0
    _assert_bounded_compiles(spec_engine)
    if paged:
        _assert_pool_intact(spec_engine)
        _assert_pool_intact(plain_engine)


@pytest.mark.parametrize("kv_quant", ["none", "int8"])
@pytest.mark.parametrize("seed,spec_k", [(0, 4), (1, 2), (2, 3)])
def test_spec_rollback_leakfree_bitwise(seed, spec_k, kv_quant):
    _check_spec_property(seed=seed, spec_k=spec_k, kv_quant=kv_quant,
                         ngram_max=3)


def test_spec_rollback_slot_layout():
    _check_spec_property(seed=3, spec_k=4, kv_quant="none", ngram_max=2,
                         paged=False)


# ---------------------------------------------------------------------------
# hypothesis harness (CI: hypothesis ships in requirements-dev)
# ---------------------------------------------------------------------------

try:        # guarded, NOT importorskip: the deterministic cases above must
    from hypothesis import given, settings, strategies as st  # noqa: E402
    _HAVE_HYPOTHESIS = True       # run even without hypothesis installed
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16),
           spec_k=st.integers(1, 6),
           kv_quant=st.sampled_from(["none", "int8"]),
           ngram_max=st.sampled_from([1, 2, 3]))
    def test_spec_rollback_property_fuzz(seed, spec_k, kv_quant, ngram_max):
        _check_spec_property(seed=seed, spec_k=spec_k, kv_quant=kv_quant,
                             ngram_max=ngram_max)
else:
    @pytest.mark.skip(reason="property fuzz needs hypothesis "
                             "(pip install -r requirements-dev.txt)")
    def test_spec_rollback_property_fuzz():
        pass
