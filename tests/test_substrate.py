"""Substrate tests: data pipeline, checkpointing, fault tolerance,
instruction pipeline, op-graph, compile cache, model quantization."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core import compiler as cc
from repro.core import opgraph
from repro.core.pipeline import InstructionStream, PipelinedRunner
from repro.core.quant import QuantizedTensor
from repro.core.sparsity import SparseQuantizedTensor
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.models import api
from repro.train import checkpoint as ckpt
from repro.train.fault import (PreemptionGuard, RestartPolicy,
                               StragglerWatchdog, run_resumable)


class TestDataPipeline:
    def test_deterministic_resume(self):
        gen = SyntheticTokens(DataConfig(vocab_size=100, seq_len=32,
                                         global_batch=4, seed=7))
        a = gen.batch(13)
        b = gen.batch(13)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = gen.batch(14)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        gen = SyntheticTokens(DataConfig(vocab_size=100, seq_len=32,
                                         global_batch=4))
        b = gen.batch(0)
        assert b["tokens"].shape == (4, 32)
        assert b["labels"].shape == (4, 32)

    def test_host_slicing_partitions(self):
        gen = SyntheticTokens(DataConfig(vocab_size=100, seq_len=16,
                                         global_batch=8))
        full = gen.batch(3)["tokens"]
        parts = [gen.host_slice(3, h, 4)["tokens"] for h in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), full)

    def test_prefetcher_orders_and_closes(self):
        gen = SyntheticTokens(DataConfig(vocab_size=50, seq_len=8,
                                         global_batch=2))
        pf = Prefetcher(gen.batch, start_step=5)
        steps = [next(pf)[0] for _ in range(4)]
        assert steps == [5, 6, 7, 8]
        pf.close()

    def test_motifs_make_data_learnable(self):
        """Repeated motifs => the stream has lower entropy than uniform."""
        gen = SyntheticTokens(DataConfig(vocab_size=1000, seq_len=256,
                                         global_batch=8, motif_prob=1.0))
        toks = gen.batch(0)["tokens"].ravel()
        _, counts = np.unique(toks, return_counts=True)
        p = counts / counts.sum()
        entropy = -(p * np.log(p)).sum()
        assert entropy < 0.9 * np.log(1000)


class TestCheckpoint:
    def _state(self):
        return {
            "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7),
        }

    def test_roundtrip(self, tmp_path):
        s = self._state()
        ckpt.save(str(tmp_path), 10, s, extra={"data_step": 10})
        like = jax.tree.map(lambda x: jnp.zeros_like(x), s)
        restored, extra = ckpt.restore(str(tmp_path), 10, like)
        assert extra == {"data_step": 10}
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)), s, restored)

    def test_quantized_leaves_roundtrip(self, tmp_path):
        from repro.core.quant import quantize
        from repro.core.sparsity import block_sparsify_quantize
        w = jnp.asarray(np.random.default_rng(0).normal(0, 1, (1024, 128)),
                        jnp.float32)
        s = {"q": quantize(w), "sq": block_sparsify_quantize(w, 0.5)}
        ckpt.save(str(tmp_path), 1, s)
        restored, _ = ckpt.restore(str(tmp_path), 1, s)
        assert isinstance(restored["q"], QuantizedTensor)
        assert isinstance(restored["sq"], SparseQuantizedTensor)
        np.testing.assert_array_equal(np.asarray(s["q"].packed),
                                      np.asarray(restored["q"].packed))
        assert restored["sq"].density == 0.5

    def test_atomic_latest_and_prune(self, tmp_path):
        s = self._state()
        for step in (1, 2, 3, 4):
            ckpt.save(str(tmp_path), step, s, keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 4
        dirs = sorted(os.listdir(tmp_path))
        assert dirs == ["step_000000003", "step_000000004"]

    def test_elastic_restore_dtype_cast(self, tmp_path):
        """Restore casts to the target tree's dtypes (e.g. f32 master ->
        bf16 serving)."""
        s = {"w": jnp.ones((4, 4), jnp.float32)}
        ckpt.save(str(tmp_path), 1, s)
        like = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
        restored, _ = ckpt.restore(str(tmp_path), 1, like)
        assert restored["w"].dtype == jnp.bfloat16


class TestFaultTolerance:
    def test_resume_replays_to_completion(self, tmp_path):
        calls = []

        def step_fn(state, step):
            calls.append(step)
            return {"x": state["x"] + 1}, {"loss": 0.0}

        init = lambda: {"x": jnp.float32(0)}
        state, last, done = run_resumable(
            ckpt_dir=str(tmp_path), total_steps=7, init_state=init,
            step_fn=step_fn, ckpt_every=3)
        assert done and last == 7 and float(state["x"]) == 7

        # crash-resume: wipe nothing; a rerun resumes from step 6 checkpoint
        calls.clear()
        state2, last2, done2 = run_resumable(
            ckpt_dir=str(tmp_path), total_steps=9, init_state=init,
            step_fn=step_fn, ckpt_every=3)
        assert done2 and last2 == 9
        assert calls[0] == 7  # resumed, not restarted

    def test_preemption_checkpoints_and_exits(self, tmp_path):
        guard = PreemptionGuard(signals=())
        seen = []

        def step_fn(state, step):
            seen.append(step)
            if step == 2:
                guard.request()
            return {"x": state["x"] + 1}, {}

        state, last, done = run_resumable(
            ckpt_dir=str(tmp_path), total_steps=100,
            init_state=lambda: {"x": jnp.float32(0)},
            step_fn=step_fn, ckpt_every=50, guard=guard)
        assert not done and last == 3
        assert ckpt.latest_step(str(tmp_path)) == 3

    def test_straggler_watchdog_escalates(self):
        wd = StragglerWatchdog(threshold=2.0, trip_limit=2, warmup_steps=2)
        hits = []
        for _ in range(5):
            wd.observe(1.0, on_escalate=lambda: hits.append(1))
        assert wd.incidents == 0
        wd.observe(5.0, on_escalate=lambda: hits.append(1))
        wd.observe(5.0, on_escalate=lambda: hits.append(1))
        assert wd.incidents == 2 and len(hits) == 1
        # recovery resets the consecutive counter
        wd.observe(1.0, on_escalate=lambda: hits.append(1))
        wd.observe(5.0, on_escalate=lambda: hits.append(1))
        assert len(hits) == 1

    def test_restart_policy_budget(self):
        rp = RestartPolicy(max_restarts=2, window_s=100, base_backoff_s=1)
        assert rp.record_failure(now=0.0) == 1
        assert rp.record_failure(now=1.0) == 2
        assert rp.record_failure(now=2.0) is None      # budget exhausted
        assert rp.record_failure(now=200.0) is not None  # window expired


class TestInstructionPipeline:
    def test_latency_hiding(self):
        """Host work overlaps device execution (paper Fig. 9)."""
        @jax.jit
        def device_step(x, args):
            # a deliberately slow device op
            y = x
            for _ in range(10):
                y = (y @ y) / jnp.linalg.norm(y)
            return y + args

        def host_work(k):
            time.sleep(0.01)
            return jnp.float32(k * 1e-6)

        x = jnp.eye(400) + 0.01
        device_step(x, jnp.float32(0)).block_until_ready()  # warm up

        serial = PipelinedRunner(device_step, host_work, pipelined=False)
        serial.run(x, 20)
        piped = PipelinedRunner(device_step, host_work, pipelined=True)
        piped.run(x, 20)
        # pipelined wall time must hide a meaningful part of host work
        assert piped.wall_time < serial.wall_time
        assert piped.host_time > 0.15  # host work actually happened

    def test_instruction_stream_double_buffer(self):
        stream = InstructionStream(lambda k: (lambda: k), depth=3)
        assert stream.prepared == 3
        assert stream.pop()() == 0
        assert stream.prepared == 4  # refilled


class TestOpGraph:
    def test_glm_block_is_17_steps(self):
        cfg = get_config("chatglm-6b")
        g = opgraph.block_graph(cfg)
        assert len(g) == 17
        assert [op.name.split(":")[0] for op in g][:2] == ["step1", "step2"]
        assert len(opgraph.epilogue_graph(cfg)) == 2

    def test_decode_weight_bytes_match_table2(self):
        """Dense GLM-6B block weight ~100.33 MB (paper Table II)."""
        cfg = get_config("chatglm-6b")
        g = opgraph.block_graph(cfg, tokens=1, context=128, wt_bits=4.125)
        wt = sum(op.weight_bytes for op in g if op.kind == "vmm")
        assert wt / 1e6 == pytest.approx(100.33, rel=0.12)

    def test_hbm_faster_than_ddr(self):
        cfg = get_config("chatglm-6b")
        g = opgraph.model_graph(cfg, tokens=1, context=128)
        t_hbm = opgraph.total_time_s(g, hbm_bw=460e9, ddr_bw=60e9)
        t_ddr = opgraph.total_time_s(g, hbm_bw=60e9, ddr_bw=60e9)
        # paper: decode on DDR ≈ 4x slower
        assert 2.5 < t_ddr / t_hbm < 6.0

    def test_layout_check(self):
        opgraph.check_layouts(get_config("chatglm-6b"))
        opgraph.check_layouts(get_config("qwen3-8b"))


class TestCompileCacheBuckets:
    def test_bucket_rounding(self):
        tb = cc.TokenBuckets(max_tokens=512, min_bucket=16)
        assert tb.bucket(1) == 16
        assert tb.bucket(17) == 32
        assert tb.bucket(512) == 512
        with pytest.raises(ValueError):
            tb.bucket(513)
        assert tb.all_buckets() == [16, 32, 64, 128, 256, 512]

    def test_cache_hit_miss(self):
        cache = cc.CompileCache()
        builds = []
        for n in (10, 20, 10):
            cache.get("f", cc.TokenBuckets(64).bucket(n),
                      lambda: builds.append(1) or len(builds))
        assert cache.misses == 2 and cache.hits == 1


class TestQuantizeModel:
    def test_quantizes_expected_leaves(self):
        cfg = get_smoke_config("qwen3-8b")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        q = cc.quantize_model(params, "dense")
        blk = q["blocks"]
        assert isinstance(blk["attn"]["wq"], QuantizedTensor)
        assert isinstance(blk["mlp"]["down"], QuantizedTensor)
        assert isinstance(q["lm_head"], QuantizedTensor)
        # never-quantized leaves stay arrays
        assert not isinstance(q["embed"], QuantizedTensor)
        assert not isinstance(blk["ln_attn"]["gamma"], QuantizedTensor)

    def test_sparse_strategy_changes_types_and_bytes(self):
        # d_model 512 -> 4 contraction blocks, enough for k-of-4 sparsity
        cfg = get_smoke_config("chatglm-6b", d_model=512, d_ff=1024,
                               n_heads=2, n_kv_heads=1, head_dim=128)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        dense = cc.quantize_model(params, "dense")
        s3 = cc.quantize_model(params, "strategy3")
        assert isinstance(s3["blocks"]["mlp"]["gate"], SparseQuantizedTensor)
        assert cc.quantized_bytes(s3) < cc.quantized_bytes(dense)

    def test_quantized_forward_close_to_dense(self):
        cfg = get_smoke_config("qwen1.5-4b")
        params = api.init_params(cfg, jax.random.PRNGKey(1))
        tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                    cfg.vocab_size)
        ref_logits, _ = api.forward(cfg, params, {"tokens": tokens})
        q = cc.quantize_model(params, "dense")
        q_logits, _ = api.forward(cfg, q, {"tokens": tokens})
        # int4 quantization error is bounded; correlation must stay high.
        # (Random-init weights are the worst case — no outlier structure for
        # the block scales to absorb; trained weights track much tighter.)
        a = np.asarray(ref_logits, np.float32).ravel()
        b = np.asarray(q_logits, np.float32).ravel()
        corr = np.corrcoef(a, b)[0, 1]
        assert corr > 0.9
